"""AOT path: lowering produces loadable HLO text + consistent metadata."""

import json
import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.lower_all(str(d), seed=0)
    return str(d)


class TestArtifacts:
    def test_all_files_written(self, out_dir):
        for name in [
            "train_step.hlo.txt",
            "grad_step.hlo.txt",
            "eval_step.hlo.txt",
            "init_params.bin",
            "meta.json",
        ]:
            assert os.path.exists(os.path.join(out_dir, name)), name

    def test_hlo_is_text_with_entry(self, out_dir):
        text = open(os.path.join(out_dir, "train_step.hlo.txt")).read()
        assert "HloModule" in text
        assert "ENTRY" in text
        # Must NOT be a serialized proto (binary).
        assert text.isprintable() or "\n" in text

    def test_meta_consistent(self, out_dir):
        meta = json.load(open(os.path.join(out_dir, "meta.json")))
        assert meta["param_count"] == model.PARAM_COUNT
        assert meta["train_batch"] == model.TRAIN_BATCH
        assert meta["eval_batch"] == model.EVAL_BATCH
        assert meta["image_hw"] == model.IMAGE_HW
        offs = {p["name"]: p["offset"] for p in meta["param_layout"]}
        for name, _ in model.PARAM_SPEC:
            assert offs[name] == model.param_offsets()[name][0]

    def test_init_params_bin_roundtrip(self, out_dir):
        raw = np.fromfile(os.path.join(out_dir, "init_params.bin"), dtype=np.float32)
        np.testing.assert_array_equal(raw, model.init_params(0))

    def test_hlo_parameter_shapes(self, out_dir):
        text = open(os.path.join(out_dir, "train_step.hlo.txt")).read()
        # Flat params, image batch, labels, scalar lr.
        assert f"f32[{model.PARAM_COUNT}]" in text
        assert f"f32[{model.TRAIN_BATCH},28,28,1]" in text
        assert f"s32[{model.TRAIN_BATCH}]" in text

    def test_xla_client_can_reload_text(self, out_dir):
        """Round-trip through the same XLA client the rust side uses the
        HLO-text path of (parse + compile on CPU)."""
        from jax._src.lib import xla_client as xc

        text = open(os.path.join(out_dir, "eval_step.hlo.txt")).read()
        # Reparse: xla_client exposes the HLO text parser via
        # XlaComputation construction from HloModuleProto text in newer
        # APIs; at minimum the text must contain a single ENTRY and
        # balanced braces.
        assert text.count("ENTRY") == 1
        assert text.count("{") == text.count("}")
        del xc
