"""L1 correctness: Pallas matmul kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled stack — every FLOP of
the exported model flows through this kernel (forward via `matmul`,
backward via the custom-VJP matmuls).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import matmul, matmul_pallas_raw, matmul_ref, mxu_utilization, vmem_bytes

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


class TestMatmulBasics:
    def test_small_exact(self):
        x = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
        w = jnp.ones((2, 2), jnp.float32)
        np.testing.assert_allclose(matmul(x, w), [[3.0, 3.0], [7.0, 7.0]])

    def test_matches_ref_square(self):
        x, w = rand((64, 64), 0), rand((64, 64), 1)
        np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_matches_ref_tall_skinny(self):
        x, w = rand((300, 25), 2), rand((25, 6), 3)
        np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_larger_than_one_block(self):
        # Forces a multi-tile grid in every dimension.
        x, w = rand((200, 300), 4), rand((300, 150), 5)
        np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_mismatched_shapes_rejected(self):
        with pytest.raises(ValueError):
            matmul_pallas_raw(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
        with pytest.raises(ValueError):
            matmul_pallas_raw(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


class TestMatmulGradients:
    def test_custom_vjp_matches_ref_grad(self):
        x, w = rand((17, 33), 6), rand((33, 9), 7)

        def f_pallas(x, w):
            return jnp.sum(matmul(x, w) ** 2)

        def f_ref(x, w):
            return jnp.sum(matmul_ref(x, w) ** 2)

        gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
        gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)

    def test_grad_through_chain(self):
        # Two chained kernels (like fc1 -> fc2) differentiate correctly.
        x = rand((8, 16), 8)
        w1, w2 = rand((16, 12), 9), rand((12, 4), 10)

        def f(w1, w2):
            return jnp.sum(jax.nn.relu(matmul(jax.nn.relu(matmul(x, w1)), w2)))

        def f_ref(w1, w2):
            return jnp.sum(
                jax.nn.relu(matmul_ref(jax.nn.relu(matmul_ref(x, w1)), w2))
            )

        g1, g2 = jax.grad(f, argnums=(0, 1))(w1, w2)
        r1, r2 = jax.grad(f_ref, argnums=(0, 1))(w1, w2)
        np.testing.assert_allclose(g1, r1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(g2, r2, rtol=1e-4, atol=1e-4)


@hypothesis.given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_hypothesis(m, k, n, seed):
    """Shape sweep: arbitrary (m, k, n) must match the oracle."""
    x, w = rand((m, k), seed), rand((k, n), seed + 1)
    np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@hypothesis.given(
    bm=st.sampled_from([8, 16, 64, 128]),
    bk=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 16, 128]),
)
def test_block_shape_invariance(bm, bk, bn):
    """The result must be independent of the chosen block decomposition."""
    x, w = rand((50, 70), 11), rand((70, 30), 12)
    out = matmul_pallas_raw(x, w, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(out, matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_dtype_promotion_bf16(seed):
    """bf16 inputs accumulate in f32 and return bf16, matching the oracle."""
    x = rand((32, 32), seed).astype(jnp.bfloat16)
    w = rand((32, 32), seed + 1).astype(jnp.bfloat16)
    out = matmul(x, w)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(jnp.float32),
        matmul_ref(x, w).astype(jnp.float32),
        rtol=2e-2,
        atol=2e-2,
    )


class TestPerfModel:
    def test_vmem_footprint_fits(self):
        # The EXPERIMENTS.md §Perf claim: 3 f32 128x128 tiles = 192 KiB.
        assert vmem_bytes() == 3 * 128 * 128 * 4
        assert vmem_bytes() < 16 * 1024 * 1024  # VMEM budget

    def test_mxu_utilization_model(self):
        assert mxu_utilization(128, 128, 128) == 1.0
        assert mxu_utilization(64, 128, 128) == pytest.approx(0.5)
        # LeNet conv1 im2col (per 32-batch): util with adaptive blocks.
        util = mxu_utilization(32 * 576, 25, 6, bm=128, bk=32, bn=8)
        assert util > 0.5
