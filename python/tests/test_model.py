"""L2 correctness: the flat-parameter LeNet model and its exported steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def batch(n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 1, size=(n, 28, 28, 1)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(n,)).astype(np.int32))
    return x, y


@pytest.fixture(scope="module")
def flat():
    return jnp.asarray(model.init_params(0))


class TestParamLayout:
    def test_param_count(self):
        assert model.PARAM_COUNT == 44426

    def test_offsets_contiguous(self):
        off = 0
        for name, shape in model.PARAM_SPEC:
            o, s = model.param_offsets()[name]
            assert o == off
            assert s == int(np.prod(shape))
            off += s
        assert off == model.PARAM_COUNT

    def test_pack_unpack_roundtrip(self, flat):
        params = model.unpack(flat)
        assert params["conv1_w"].shape == (25, 6)
        assert params["fc3_b"].shape == (10,)
        repacked = model.pack(params)
        np.testing.assert_array_equal(repacked, flat)

    def test_init_deterministic(self):
        a, b = model.init_params(7), model.init_params(7)
        np.testing.assert_array_equal(a, b)
        c = model.init_params(8)
        assert not np.array_equal(a, c)

    def test_init_biases_zero(self):
        flat = model.init_params(0)
        off, size = model.param_offsets()["conv1_b"]
        np.testing.assert_array_equal(flat[off : off + size], 0.0)


class TestForward:
    def test_logit_shape(self, flat):
        x, _ = batch(4)
        assert model.forward(flat, x).shape == (4, 10)

    def test_pallas_matches_ref(self, flat):
        x, _ = batch(8, 1)
        lp = model.forward(flat, x)
        lr = model.forward_ref(flat, x)
        np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-4)

    def test_loss_is_near_uniform_at_init(self, flat):
        x, y = batch(32, 2)
        loss = model.loss_fn(flat, x, y)
        # Random init ≈ uniform predictions: CE ≈ ln 10 ≈ 2.30.
        assert 1.8 < float(loss) < 3.2

    def test_batch_independence(self, flat):
        # Each example's logits must not depend on the rest of the batch.
        x, _ = batch(8, 3)
        full = model.forward(flat, x)
        single = model.forward(flat, x[:1])
        np.testing.assert_allclose(full[:1], single, rtol=1e-4, atol=1e-5)


class TestTrainStep:
    def test_matches_reference_step(self, flat):
        x, y = batch(model.TRAIN_BATCH, 4)
        p1, l1 = model.train_step(flat, x, y, jnp.float32(0.05))
        p2, l2 = model.train_step_ref(flat, x, y, jnp.float32(0.05))
        np.testing.assert_allclose(l1, l2, rtol=1e-4)
        np.testing.assert_allclose(p1, p2, rtol=1e-3, atol=1e-6)

    def test_loss_decreases_over_steps(self, flat):
        x, y = batch(model.TRAIN_BATCH, 5)
        w = flat
        losses = []
        step = jax.jit(model.train_step)
        for _ in range(12):
            w, loss = step(w, x, y, jnp.float32(0.1))
            losses.append(float(loss))
        # Random-label memorization is slow; demand a clear downward trend.
        assert losses[-1] < losses[0] * 0.9, losses

    def test_zero_lr_is_identity(self, flat):
        x, y = batch(model.TRAIN_BATCH, 6)
        w, _ = model.train_step(flat, x, y, jnp.float32(0.0))
        np.testing.assert_array_equal(w, flat)

    def test_grad_step_consistent_with_train_step(self, flat):
        x, y = batch(model.TRAIN_BATCH, 7)
        grad, loss_g = model.grad_step(flat, x, y)
        w, loss_t = model.train_step(flat, x, y, jnp.float32(0.05))
        np.testing.assert_allclose(loss_g, loss_t, rtol=1e-6)
        np.testing.assert_allclose(w, flat - 0.05 * grad, rtol=1e-5, atol=1e-7)


class TestEvalStep:
    def test_counts_match_numpy(self, flat):
        x, y = batch(model.EVAL_BATCH, 8)
        loss_sum, correct = model.eval_step(flat, x, y)
        logits = np.asarray(model.forward(flat, x))
        pred = logits.argmax(-1)
        np.testing.assert_allclose(float(correct), (pred == np.asarray(y)).sum())
        logp = jax.nn.log_softmax(jnp.asarray(logits), -1)
        nll = -np.take_along_axis(np.asarray(logp), np.asarray(y)[:, None], axis=-1)
        np.testing.assert_allclose(float(loss_sum), nll.sum(), rtol=1e-4)

    def test_memorized_batch_scores_above_chance(self):
        # Train on one random-label batch until it (mostly) memorizes,
        # then eval on a set containing it: correctness must rise far
        # above the 10% chance level (random labels are a worst case —
        # structured-data accuracy is exercised end-to-end in rust).
        x, y = batch(model.EVAL_BATCH, 9)
        w = jnp.asarray(model.init_params(1))
        xt, yt = x[: model.TRAIN_BATCH], y[: model.TRAIN_BATCH]
        step = jax.jit(model.train_step)
        for _ in range(150):
            w, _ = step(w, xt, yt, jnp.float32(0.2))
        _, correct = model.eval_step(w, x, y)
        assert float(correct) >= model.TRAIN_BATCH * 0.8
