#!/usr/bin/env python3
"""Perf-regression gate for the BENCH_*.json baselines.

Compares freshly regenerated BENCH files (written by the benches in full
mode) against the committed baselines and fails on a >tolerance (default
25%) regression of the warm-path *speedup ratios* ("speedup" rows) —
the only metrics that are self-normalizing across heterogeneous CI
runners (cold and warm are timed on the same machine in the same run).
Raw wall-clock metrics such as instances_per_s are printed for context
but never gate.

Every run (pass or fail) prints a per-metric old -> new delta table so
the perf trajectory is visible in green CI logs, not only in autopsies.

Skips cleanly (exit 0) when a committed baseline is still a schema stub
("generated": false) — the stub era's escape hatch: the first CI run on a
real toolchain produces measured artifacts, and the gate starts biting
once a measured baseline is committed. The skip is LOUD (a !!! WARNING
banner) so stub baselines cannot quietly outlive the toolchain-less
container era. A fresh file that is *itself* a stub is an error: it
means the real bench run did not happen.

Usage:
  python3 python/check_bench.py --baseline-dir .bench_baselines \
      BENCH_resolve.json BENCH_assoc.json BENCH_scenario.json \
      BENCH_hetero.json
  python3 python/check_bench.py --self-test
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys


def norm_name(name: str) -> str:
    """Normalize machine-dependent parts of a row name (the throughput
    rows embed the runner's auto shard count)."""
    return re.sub(r"\b\d+ shards", "auto shards", name)


def metrics_of(doc: dict) -> dict[str, float]:
    """Gated metrics of one BENCH document, keyed by row name.

    Only the warm-path *speedup ratios* are gated: cold and warm are
    measured on the same machine in the same run, so the ratio is
    self-normalizing across heterogeneous CI runners. Raw wall-clock
    metrics (instances_per_s, per-epoch times) vary with the runner's
    hardware and neighbors and would make the gate flaky — they are
    reported informationally instead.
    """
    out: dict[str, float] = {}
    for row in doc.get("rows", []):
        name = norm_name(row.get("name", ""))
        if "speedup" in name and isinstance(row.get("value"), (int, float)):
            out[name] = float(row["value"])
    return out


def info_metrics_of(doc: dict) -> dict[str, float]:
    """Ungated, informational metrics (machine-dependent wall-clock)."""
    out: dict[str, float] = {}
    for row in doc.get("rows", []):
        name = norm_name(row.get("name", ""))
        if isinstance(row.get("instances_per_s"), (int, float)):
            out[name] = float(row["instances_per_s"])
    return out


STUB_BANNER = (
    '!!! WARNING: {name}: committed baseline is a schema stub ("generated" != true).\n'
    "!!!          The perf gate is SKIPPED for this bench. Run the bench in full mode\n"
    "!!!          on a real toolchain and commit the measured BENCH file to arm it."
)


def gh_warning(path: str, bench: str) -> str:
    """GitHub Actions annotation for a stub baseline: surfaces the skipped
    gate on the PR's checks page, not only in the job log. The line is
    plain text outside Actions, so emitting it unconditionally is safe."""
    return (
        f"::warning file={path},title=stub bench baseline::"
        f"{bench}: committed baseline has \"generated\": false; the perf gate is "
        f"skipped until a measured BENCH file is committed"
    )


def delta_pct(base_val: float | None, fresh_val: float) -> str:
    """Signed old -> new percentage change, or n/a without a baseline."""
    if base_val is None or base_val == 0:
        return "n/a"
    return f"{(fresh_val - base_val) / abs(base_val) * 100.0:+.1f}%"


def render_table(rows: list[tuple[str, str, str, str, str]]) -> list[str]:
    widths = [max(len(r[i]) for r in rows) for i in range(5)]
    return [
        "  ".join(cell.ljust(w) for cell, w in zip(row, widths)).rstrip() for row in rows
    ]


def compare(baseline: dict, fresh: dict, tolerance: float) -> tuple[list[str], list[str]]:
    """Returns (regressions, notes) for one baseline/fresh pair.

    The notes always carry a full old -> new delta table — gated speedup
    ratios first, then the informational throughput rows — printed on
    every run, not only on failure.
    """
    notes: list[str] = []
    name = fresh.get("bench") or baseline.get("bench") or "?"
    if baseline.get("generated") is not True:
        notes.append(STUB_BANNER.format(name=name))
        return [], notes
    if fresh.get("generated") is not True:
        return [f"{name}: fresh file is not a measured run (generated != true)"], notes
    base_m = metrics_of(baseline)
    fresh_m = metrics_of(fresh)
    base_info = info_metrics_of(baseline)
    fresh_info = info_metrics_of(fresh)

    rows: list[tuple[str, str, str, str, str]] = [
        ("metric", "baseline", "fresh", "delta", "status")
    ]
    regressions: list[str] = []
    for key, base_val in sorted(base_m.items()):
        if base_val <= 0:
            rows.append((key, f"{base_val:.3f}", "-", "n/a", "skipped (baseline not positive)"))
            continue
        if key not in fresh_m:
            rows.append((key, f"{base_val:.3f}", "MISSING", "n/a", "REGRESSION"))
            regressions.append(f"{name}/{key}: metric missing from fresh run")
            continue
        fresh_val = fresh_m[key]
        floor = base_val * (1.0 - tolerance)
        ok = fresh_val >= floor
        rows.append(
            (
                key,
                f"{base_val:.3f}",
                f"{fresh_val:.3f}",
                delta_pct(base_val, fresh_val),
                "ok (gated)" if ok else "REGRESSION",
            )
        )
        if not ok:
            regressions.append(
                f"{name}/{key}: {fresh_val:.3f} < {floor:.3f} "
                f"(baseline {base_val:.3f}, tolerance {tolerance:.0%})"
            )
    for key, fresh_val in sorted(fresh_info.items()):
        base_val = base_info.get(key)
        base_txt = f"{base_val:.3f}" if base_val is not None else "n/a"
        rows.append(
            (key, base_txt, f"{fresh_val:.3f}", delta_pct(base_val, fresh_val), "info only")
        )
    notes.append(f"{name}: old -> new deltas (gate tolerance {tolerance:.0%}):")
    notes.extend("  " + line for line in render_table(rows))
    return regressions, notes


def self_test() -> int:
    stub = {"bench": "x", "generated": False, "rows": [{"name": "s speedup", "value": None}]}
    good = {"bench": "x", "generated": True, "rows": [{"name": "s speedup", "value": 10.0}]}
    slow = {"bench": "x", "generated": True, "rows": [{"name": "s speedup", "value": 8.0}]}
    bad = {"bench": "x", "generated": True, "rows": [{"name": "s speedup", "value": 2.0}]}
    thr = {
        "bench": "y",
        "generated": True,
        "rows": [{"name": "static", "instances_per_s": 100.0}],
    }
    thr_bad = {
        "bench": "y",
        "generated": True,
        "rows": [{"name": "static", "instances_per_s": 10.0}],
    }
    # BENCH_hetero.json shape: one gated speedup ratio, a throughput info
    # row and plain scalar quality rows (participation) that never gate.
    hetero = {
        "bench": "hetero_scenario",
        "generated": True,
        "rows": [
            {"name": "hetero 50k world", "instances_per_s": 0.5},
            {"name": "hetero participation", "value": 0.93},
            {"name": "hetero assoc warm speedup", "value": 4.0},
        ],
    }
    hetero_slow_world = {
        "bench": "hetero_scenario",
        "generated": True,
        "rows": [
            {"name": "hetero 50k world", "instances_per_s": 0.05},
            {"name": "hetero participation", "value": 0.2},
            {"name": "hetero assoc warm speedup", "value": 4.0},
        ],
    }
    hetero_slow_speedup = {
        "bench": "hetero_scenario",
        "generated": True,
        "rows": [{"name": "hetero assoc warm speedup", "value": 1.0}],
    }
    # BENCH_scale.json shape: per-epoch wall-clock rows (never gated), one
    # gated maintenance-speedup ratio, and plain scalar "ratio" rows
    # (build / frontier refresh) that stay informational by name.
    scale = {
        "bench": "scale_parallel",
        "generated": True,
        "rows": [
            {"name": "scale serial maintenance", "per_epoch_ms": 120.0, "epochs": 4},
            {"name": "scale sharded maintenance", "per_epoch_ms": 30.0, "epochs": 4},
            {"name": "scale parallel maintenance speedup", "value": 4.0, "target": 2.0},
            {"name": "maintenance threads", "value": 4.0},
            {"name": "cold build ratio", "value": 3.5},
            {"name": "frontier refresh ratio", "value": 2.0},
        ],
    }
    scale_slow = {
        "bench": "scale_parallel",
        "generated": True,
        "rows": [
            {"name": "scale parallel maintenance speedup", "value": 1.1},
            {"name": "cold build ratio", "value": 0.1},
        ],
    }
    # BENCH_gap.json shape: certificate-gap and wall-clock rows only — no
    # speedup ratios, so nothing in this bench ever hard-gates.
    gap = {
        "bench": "assoc_gap",
        "generated": True,
        "rows": [
            {"name": "gap proposed", "gap_s": 0.01, "solve_ms": 3.0},
            {"name": "gap flow", "gap_s": 0.0, "solve_ms": 40.0},
            {"name": "flow bound scale", "bound_ms": 150.0, "budget_ms": 2000.0},
        ],
    }
    gap_worse = {
        "bench": "assoc_gap",
        "generated": True,
        "rows": [
            {"name": "gap proposed", "gap_s": 0.5, "solve_ms": 30.0},
            {"name": "flow bound scale", "bound_ms": 1900.0, "budget_ms": 2000.0},
        ],
    }
    assert metrics_of(good) == {"s speedup": 10.0}
    assert metrics_of(gap) == {}  # certificate rows are informational
    assert compare(gap, gap_worse, 0.25)[0] == []  # wider gaps never gate
    assert compare(gap, {"bench": "assoc_gap", "generated": False}, 0.25)[0] != []
    assert metrics_of(thr) == {}  # raw throughput is not gated...
    assert info_metrics_of(thr) == {"static": 100.0}  # ...only reported
    assert metrics_of(hetero) == {"hetero assoc warm speedup": 4.0}
    assert compare(hetero, hetero_slow_world, 0.25)[0] == []  # quality/throughput: info only
    assert compare(hetero, hetero_slow_speedup, 0.25)[0] != []  # 4x -> 1x ratio drop fails
    assert metrics_of(scale) == {"scale parallel maintenance speedup": 4.0}
    assert compare(scale, scale, 0.25)[0] == []  # equal passes
    assert compare(scale, scale_slow, 0.25)[0] != []  # 4x -> 1.1x maintenance drop fails
    regs, notes = compare(stub, good, 0.25)
    assert regs == []  # stub baseline skips...
    assert any("!!! WARNING" in n and "schema stub" in n for n in notes)  # ...loudly
    ann = gh_warning("BENCH_resolve.json", "resolve_warm")
    assert ann.startswith("::warning file=BENCH_resolve.json,title=")  # Actions syntax
    assert "resolve_warm" in ann and "perf gate is skipped" in ann
    assert compare(good, good, 0.25)[0] == []  # equal passes
    regs, notes = compare(good, slow, 0.25)
    assert regs == []  # within tolerance passes
    assert any("-20.0%" in n for n in notes)  # ...but the delta table shows the drift
    assert delta_pct(10.0, 8.0) == "-20.0%" and delta_pct(None, 8.0) == "n/a"
    assert compare(good, bad, 0.25)[0] != []  # 5x drop fails
    assert compare(thr, thr_bad, 0.25)[0] == []  # runner-dependent: info only
    assert compare(good, stub, 0.25)[0] != []  # fresh stub fails
    nrm = norm_name("static 5x100, 64 inst, 4 shards (auto)")
    assert nrm == "static 5x100, 64 inst, auto shards (auto)"
    print("check_bench self-test: OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", nargs="*", help="freshly generated BENCH_*.json paths")
    ap.add_argument("--baseline-dir", default=".bench_baselines")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if not args.fresh:
        print("no fresh BENCH files given; nothing to gate")
        return 0

    all_regressions: list[str] = []
    for fresh_path in args.fresh:
        base_path = os.path.join(args.baseline_dir, os.path.basename(fresh_path))
        if not os.path.exists(fresh_path):
            all_regressions.append(f"{fresh_path}: fresh file missing (bench did not run?)")
            continue
        if not os.path.exists(base_path):
            print(f"{fresh_path}: no committed baseline at {base_path} — skipped")
            continue
        with open(base_path, encoding="utf-8") as f:
            baseline = json.load(f)
        with open(fresh_path, encoding="utf-8") as f:
            fresh = json.load(f)
        regressions, notes = compare(baseline, fresh, args.tolerance)
        for note in notes:
            print(note)
        if baseline.get("generated") is not True:
            # fresh_path is the repo-relative committed file (the baseline
            # copy in --baseline-dir is a CI-local snapshot of it).
            print(gh_warning(fresh_path, baseline.get("bench") or fresh_path))
        all_regressions.extend(regressions)

    if all_regressions:
        print("\nperf gate FAILED:")
        for r in all_regressions:
            print(f"  - {r}")
        return 1
    print("\nperf gate: green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
