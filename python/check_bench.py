#!/usr/bin/env python3
"""Perf-regression gate for the BENCH_*.json baselines.

Compares freshly regenerated BENCH files (written by the benches in full
mode) against the committed baselines and fails on a >tolerance (default
25%) regression of the warm-path *speedup ratios* ("speedup" rows) —
the only metrics that are self-normalizing across heterogeneous CI
runners (cold and warm are timed on the same machine in the same run).
Raw wall-clock metrics such as instances_per_s are printed for context
but never gate.

Skips cleanly (exit 0) when a committed baseline is still a schema stub
("generated": false) — the stub era's escape hatch: the first CI run on a
real toolchain produces measured artifacts, and the gate starts biting
once a measured baseline is committed. A fresh file that is *itself* a
stub is an error: it means the real bench run did not happen.

Usage:
  python3 python/check_bench.py --baseline-dir .bench_baselines \
      BENCH_resolve.json BENCH_assoc.json BENCH_scenario.json \
      BENCH_hetero.json
  python3 python/check_bench.py --self-test
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys


def norm_name(name: str) -> str:
    """Normalize machine-dependent parts of a row name (the throughput
    rows embed the runner's auto shard count)."""
    return re.sub(r"\b\d+ shards", "auto shards", name)


def metrics_of(doc: dict) -> dict[str, float]:
    """Gated metrics of one BENCH document, keyed by row name.

    Only the warm-path *speedup ratios* are gated: cold and warm are
    measured on the same machine in the same run, so the ratio is
    self-normalizing across heterogeneous CI runners. Raw wall-clock
    metrics (instances_per_s, per-epoch times) vary with the runner's
    hardware and neighbors and would make the gate flaky — they are
    reported informationally instead.
    """
    out: dict[str, float] = {}
    for row in doc.get("rows", []):
        name = norm_name(row.get("name", ""))
        if "speedup" in name and isinstance(row.get("value"), (int, float)):
            out[name] = float(row["value"])
    return out


def info_metrics_of(doc: dict) -> dict[str, float]:
    """Ungated, informational metrics (machine-dependent wall-clock)."""
    out: dict[str, float] = {}
    for row in doc.get("rows", []):
        name = norm_name(row.get("name", ""))
        if isinstance(row.get("instances_per_s"), (int, float)):
            out[name] = float(row["instances_per_s"])
    return out


def compare(baseline: dict, fresh: dict, tolerance: float) -> tuple[list[str], list[str]]:
    """Returns (regressions, notes) for one baseline/fresh pair."""
    notes: list[str] = []
    name = fresh.get("bench") or baseline.get("bench") or "?"
    if baseline.get("generated") is not True:
        notes.append(f"{name}: baseline is a schema stub (generated != true) — skipped")
        return [], notes
    if fresh.get("generated") is not True:
        return [f"{name}: fresh file is not a measured run (generated != true)"], notes
    base_m = metrics_of(baseline)
    fresh_m = metrics_of(fresh)
    base_info = info_metrics_of(baseline)
    for key, fresh_val in sorted(info_metrics_of(fresh).items()):
        base_val = base_info.get(key)
        base_txt = f"{base_val:.3f}" if base_val is not None else "n/a"
        notes.append(f"{name}/{key}: baseline {base_txt} fresh {fresh_val:.3f} (info only)")
    regressions: list[str] = []
    for key, base_val in sorted(base_m.items()):
        if base_val <= 0:
            notes.append(f"{name}/{key}: baseline {base_val} not positive — skipped")
            continue
        if key not in fresh_m:
            regressions.append(f"{name}/{key}: metric missing from fresh run")
            continue
        fresh_val = fresh_m[key]
        floor = base_val * (1.0 - tolerance)
        verdict = "ok" if fresh_val >= floor else "REGRESSION"
        notes.append(
            f"{name}/{key}: baseline {base_val:.3f} fresh {fresh_val:.3f} "
            f"floor {floor:.3f} -> {verdict}"
        )
        if fresh_val < floor:
            regressions.append(
                f"{name}/{key}: {fresh_val:.3f} < {floor:.3f} "
                f"(baseline {base_val:.3f}, tolerance {tolerance:.0%})"
            )
    return regressions, notes


def self_test() -> int:
    stub = {"bench": "x", "generated": False, "rows": [{"name": "s speedup", "value": None}]}
    good = {"bench": "x", "generated": True, "rows": [{"name": "s speedup", "value": 10.0}]}
    slow = {"bench": "x", "generated": True, "rows": [{"name": "s speedup", "value": 8.0}]}
    bad = {"bench": "x", "generated": True, "rows": [{"name": "s speedup", "value": 2.0}]}
    thr = {
        "bench": "y",
        "generated": True,
        "rows": [{"name": "static", "instances_per_s": 100.0}],
    }
    thr_bad = {
        "bench": "y",
        "generated": True,
        "rows": [{"name": "static", "instances_per_s": 10.0}],
    }
    # BENCH_hetero.json shape: one gated speedup ratio, a throughput info
    # row and plain scalar quality rows (participation) that never gate.
    hetero = {
        "bench": "hetero_scenario",
        "generated": True,
        "rows": [
            {"name": "hetero 50k world", "instances_per_s": 0.5},
            {"name": "hetero participation", "value": 0.93},
            {"name": "hetero assoc warm speedup", "value": 4.0},
        ],
    }
    hetero_slow_world = {
        "bench": "hetero_scenario",
        "generated": True,
        "rows": [
            {"name": "hetero 50k world", "instances_per_s": 0.05},
            {"name": "hetero participation", "value": 0.2},
            {"name": "hetero assoc warm speedup", "value": 4.0},
        ],
    }
    hetero_slow_speedup = {
        "bench": "hetero_scenario",
        "generated": True,
        "rows": [{"name": "hetero assoc warm speedup", "value": 1.0}],
    }
    assert metrics_of(good) == {"s speedup": 10.0}
    assert metrics_of(thr) == {}  # raw throughput is not gated...
    assert info_metrics_of(thr) == {"static": 100.0}  # ...only reported
    assert metrics_of(hetero) == {"hetero assoc warm speedup": 4.0}
    assert compare(hetero, hetero_slow_world, 0.25)[0] == []  # quality/throughput: info only
    assert compare(hetero, hetero_slow_speedup, 0.25)[0] != []  # 4x -> 1x ratio drop fails
    assert compare(stub, good, 0.25)[0] == []  # stub baseline skips
    assert compare(good, good, 0.25)[0] == []  # equal passes
    assert compare(good, slow, 0.25)[0] == []  # within tolerance passes
    assert compare(good, bad, 0.25)[0] != []  # 5x drop fails
    assert compare(thr, thr_bad, 0.25)[0] == []  # runner-dependent: info only
    assert compare(good, stub, 0.25)[0] != []  # fresh stub fails
    nrm = norm_name("static 5x100, 64 inst, 4 shards (auto)")
    assert nrm == "static 5x100, 64 inst, auto shards (auto)"
    print("check_bench self-test: OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", nargs="*", help="freshly generated BENCH_*.json paths")
    ap.add_argument("--baseline-dir", default=".bench_baselines")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if not args.fresh:
        print("no fresh BENCH files given; nothing to gate")
        return 0

    all_regressions: list[str] = []
    for fresh_path in args.fresh:
        base_path = os.path.join(args.baseline_dir, os.path.basename(fresh_path))
        if not os.path.exists(fresh_path):
            all_regressions.append(f"{fresh_path}: fresh file missing (bench did not run?)")
            continue
        if not os.path.exists(base_path):
            print(f"{fresh_path}: no committed baseline at {base_path} — skipped")
            continue
        with open(base_path, encoding="utf-8") as f:
            baseline = json.load(f)
        with open(fresh_path, encoding="utf-8") as f:
            fresh = json.load(f)
        regressions, notes = compare(baseline, fresh, args.tolerance)
        for note in notes:
            print(note)
        all_regressions.extend(regressions)

    if all_regressions:
        print("\nperf gate FAILED:")
        for r in all_regressions:
            print(f"  - {r}")
        return 1
    print("\nperf gate: green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
