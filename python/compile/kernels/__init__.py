"""L1 Pallas kernels + pure-jnp reference oracles."""

from .matmul import matmul, matmul_pallas_raw, mxu_utilization, vmem_bytes
from .ref import matmul_ref

__all__ = [
    "matmul",
    "matmul_pallas_raw",
    "matmul_ref",
    "mxu_utilization",
    "vmem_bytes",
]
