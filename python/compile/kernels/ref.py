"""Pure-jnp oracle for the Pallas kernel — the CORE correctness signal.

Everything in here is deliberately boring: plain ``jnp`` ops that XLA
lowers natively, no Pallas. pytest asserts the Pallas kernel (and the
full model built on it) matches these references to float tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference matmul with f32 accumulation, matching the kernel."""
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    acc = jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return acc.astype(out_dtype)
