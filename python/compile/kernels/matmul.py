"""L1 — Pallas tiled-matmul kernel.

This is the single compute hot-spot of the LeNet model (all dense layers
*and* both convolutions, which are lowered to im2col + matmul in
``model.py``). The kernel is written in the canonical MXU-oriented style:

* the grid is ``(M/bm, N/bn, K/bk)`` with the K dimension innermost so each
  ``(i, j)`` output tile is revisited ``K/bk`` times and accumulated in
  float32 — the classic systolic-array pipeline shape;
* on a real TPU the block sizes would be pinned at 128x128x128 (one MXU
  pass per step, 3 * 128*128*4 B = 192 KiB of VMEM, leaving ample room for
  double buffering);
* on this image Pallas MUST run ``interpret=True`` (the CPU PJRT plugin
  cannot execute Mosaic custom-calls), so block sizes adapt downward for
  small operands to avoid pathological zero-padding waste. DESIGN.md
  §Hardware-Adaptation records the TPU mapping.

Because ``pallas_call`` has no automatic differentiation rule, the public
``matmul`` is wrapped in ``jax.custom_vjp`` whose backward pass is two more
calls of the same kernel (``dx = g @ w^T``, ``dw = x^T @ g``) — so the
*entire* training step, forward and backward, flows through this kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes a real TPU deployment would use (MXU native tile).
MXU_BLOCK = 128
# Minimum granularity we round small dimensions to in interpret mode.
_MIN_TILE = 8


def _round_up(value: int, multiple: int) -> int:
    return ((value + multiple - 1) // multiple) * multiple


def _pick_block(dim: int, preferred: int = MXU_BLOCK) -> int:
    """Pick a block size: the MXU tile when the dim is big enough,
    otherwise the dim rounded up to the minimum tile granularity."""
    if dim >= preferred:
        return preferred
    return _round_up(max(dim, 1), _MIN_TILE)


def _pick_block_interpret(dim: int) -> int:
    """Interpret-mode (CPU) block policy: one block per operand.

    The grid loop that pipelines 128x128x128 tiles through the MXU on a
    real TPU lowers, under ``interpret=True``, to an XLA while-loop of
    dynamic-slice/dot/dynamic-update-slice steps that the CPU backend
    cannot fuse — a 144-step grid made the exported train_step ~9x slower
    than the pure-jnp reference (EXPERIMENTS.md §Perf, L1 iteration 1).
    Collapsing the grid to a single whole-operand block keeps the kernel
    code identical while letting interpret mode execute one fused dot;
    the TPU deployment config (``bm=bn=bk=MXU_BLOCK``) is exercised by
    the block-shape-invariance tests instead.
    """
    return _round_up(max(dim, 1), _MIN_TILE)


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One grid step: accumulate an (bm, bn) output tile in f32."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas_raw(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """Tiled matmul ``x @ w`` via a Pallas kernel (interpret mode).

    Operands of arbitrary shape are zero-padded up to block multiples; the
    result is sliced back. Accumulation is always float32; the result is
    cast back to the promoted input dtype.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")

    out_dtype = jnp.promote_types(x.dtype, w.dtype)

    bm = bm or _pick_block_interpret(m)
    bn = bn or _pick_block_interpret(n)
    bk = bk or _pick_block_interpret(k)

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))

    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(xp, wp)
    return out[:m, :n].astype(out_dtype)


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable Pallas matmul. Both fwd and bwd use the kernel."""
    return matmul_pallas_raw(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas_raw(x, w), (x, w)


def _matmul_bwd(residual, g):
    x, w = residual
    dx = matmul_pallas_raw(g, w.T)
    dw = matmul_pallas_raw(x.T, g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm: int = MXU_BLOCK, bn: int = MXU_BLOCK, bk: int = MXU_BLOCK) -> int:
    """VMEM footprint of one grid step (x tile + w tile + out tile, f32).

    Used by the perf notes in EXPERIMENTS.md: with the default 128^3
    blocking this is 192 KiB against a 16 MiB VMEM budget, i.e. ~1.2%
    occupancy — double/triple buffering is free.
    """
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, k: int, n: int, bm: int = MXU_BLOCK,
                    bn: int = MXU_BLOCK, bk: int = MXU_BLOCK) -> float:
    """Fraction of MXU MACs doing useful work (padding overhead model)."""
    useful = m * k * n
    padded = _round_up(m, bm) * _round_up(k, bk) * _round_up(n, bn)
    return useful / padded
