"""AOT compile path: lower the L2 model to HLO **text** artifacts.

Run once via ``make artifacts`` (never on the request path):

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Outputs (all consumed by ``rust/src/runtime``):

    artifacts/train_step.hlo.txt   (params, x, y, lr) -> (params', loss)
    artifacts/grad_step.hlo.txt    (params, x, y)     -> (grad, loss)
    artifacts/eval_step.hlo.txt    (params, x, y)     -> (loss_sum, correct)
    artifacts/init_params.bin      f32 LE flat init vector
    artifacts/meta.json            shapes, offsets, batch sizes
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all(out_dir: str, seed: int = 0) -> None:
    os.makedirs(out_dir, exist_ok=True)
    p = _spec((model.PARAM_COUNT,))
    xt = _spec((model.TRAIN_BATCH, model.IMAGE_HW, model.IMAGE_HW, 1))
    yt = _spec((model.TRAIN_BATCH,), jnp.int32)
    xe = _spec((model.EVAL_BATCH, model.IMAGE_HW, model.IMAGE_HW, 1))
    ye = _spec((model.EVAL_BATCH,), jnp.int32)
    lr = _spec((), jnp.float32)

    exports = {
        "train_step": jax.jit(lambda f, x, y, l: model.train_step(f, x, y, l)).lower(p, xt, yt, lr),
        "grad_step": jax.jit(lambda f, x, y: model.grad_step(f, x, y)).lower(p, xt, yt),
        "eval_step": jax.jit(lambda f, x, y: model.eval_step(f, x, y)).lower(p, xe, ye),
    }
    for name, lowered in exports.items():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    init = model.init_params(seed)
    init_path = os.path.join(out_dir, "init_params.bin")
    init.tofile(init_path)
    print(f"wrote {init_path} ({init.nbytes} bytes)")

    meta = {
        "param_count": model.PARAM_COUNT,
        "image_hw": model.IMAGE_HW,
        "num_classes": model.NUM_CLASSES,
        "train_batch": model.TRAIN_BATCH,
        "eval_batch": model.EVAL_BATCH,
        "init_seed": seed,
        "param_layout": [
            {"name": n, "shape": list(s), "offset": model.param_offsets()[n][0]}
            for n, s in model.PARAM_SPEC
        ],
        "executables": {
            "train_step": {
                "inputs": ["params f32[P]", "x f32[B,28,28,1]", "y s32[B]", "lr f32[]"],
                "outputs": ["params f32[P]", "loss f32[]"],
            },
            "grad_step": {
                "inputs": ["params f32[P]", "x f32[B,28,28,1]", "y s32[B]"],
                "outputs": ["grad f32[P]", "loss f32[]"],
            },
            "eval_step": {
                "inputs": ["params f32[P]", "x f32[E,28,28,1]", "y s32[E]"],
                "outputs": ["loss_sum f32[]", "correct f32[]"],
            },
        },
    }
    meta_path = os.path.join(out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    lower_all(args.out_dir, args.seed)


if __name__ == "__main__":
    main()
