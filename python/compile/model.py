"""L2 — the paper's FL model: LeNet-5 forward/backward in JAX.

The paper (§V-A) trains **LeNet on MNIST** at each UE with plain gradient
descent ("we use GD in UE local training"). This module implements that
model over a single **flat f32[P] parameter vector** so the Rust
coordinator can treat model state as one opaque buffer per UE (pack /
unpack offsets are exported in ``meta.json``).

Both convolutions are lowered to **im2col + matmul** so every FLOP of the
network — forward and backward — flows through the L1 Pallas kernel
(``kernels.matmul``). Convolution weights are stored natively in im2col
layout ``(C*kh*kw, OC)``, which keeps the flat-vector layout trivial and
removes any transpose ambiguity between model and reference.

Exported computations (lowered to HLO text by ``aot.py``):

* ``train_step(params, x, y, lr) -> (params', loss)`` — one fused GD step
  (value_and_grad + SGD update in a single executable; no host round trip
  between gradient and update).
* ``grad_step(params, x, y) -> (grad, loss)`` — gradient only, so the Rust
  side can implement alternative local solvers (e.g. DANE-style corrected
  steps) on top of the same compiled artifact.
* ``eval_step(params, x, y) -> (loss_sum, correct)`` — test-set shard
  evaluation; Rust loops shards and reduces.

Architecture (28x28x1 input, VALID convs, 2x2 avg-pool, ReLU):

    conv1 5x5x1->6   -> 24x24x6  -> pool 12x12x6
    conv2 5x5x6->16  ->  8x8x16  -> pool  4x4x16 = 256
    fc1 256->120, fc2 120->84, fc3 84->10
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul

# --------------------------------------------------------------------------
# Shapes / parameter layout
# --------------------------------------------------------------------------

IMAGE_HW = 28
NUM_CLASSES = 10
TRAIN_BATCH = 32
EVAL_BATCH = 128

# (name, shape) in flat-vector order. Conv weights in im2col layout.
PARAM_SPEC: List[Tuple[str, Tuple[int, ...]]] = [
    ("conv1_w", (25, 6)),      # (C*kh*kw, OC) = (1*5*5, 6)
    ("conv1_b", (6,)),
    ("conv2_w", (150, 16)),    # (6*5*5, 16)
    ("conv2_b", (16,)),
    ("fc1_w", (256, 120)),
    ("fc1_b", (120,)),
    ("fc2_w", (120, 84)),
    ("fc2_b", (84,)),
    ("fc3_w", (84, 10)),
    ("fc3_b", (10,)),
]


def param_offsets() -> Dict[str, Tuple[int, int]]:
    """name -> (offset, size) into the flat parameter vector."""
    out, off = {}, 0
    for name, shape in PARAM_SPEC:
        size = int(np.prod(shape))
        out[name] = (off, size)
        off += size
    return out


PARAM_COUNT = sum(int(np.prod(s)) for _, s in PARAM_SPEC)  # 44426


def unpack(flat: jax.Array) -> Dict[str, jax.Array]:
    """Split the flat f32[P] vector into named, shaped parameters."""
    offsets = param_offsets()
    return {
        name: jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(shape)
        for (name, shape), (off, size) in (
            ((n, s), offsets[n]) for n, s in PARAM_SPEC
        )
    }


def pack(params: Dict[str, jax.Array]) -> jax.Array:
    """Inverse of :func:`unpack`."""
    return jnp.concatenate([params[n].reshape(-1) for n, _ in PARAM_SPEC])


def init_params(seed: int = 0) -> np.ndarray:
    """He-style init, computed in numpy at build time (written to
    artifacts/init_params.bin so the Rust side never needs an init HLO)."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in PARAM_SPEC:
        if name.endswith("_b"):
            chunks.append(np.zeros(shape, np.float32))
        else:
            fan_in = shape[0]
            std = np.sqrt(2.0 / fan_in)
            chunks.append(rng.normal(0.0, std, size=shape).astype(np.float32))
    flat = np.concatenate([c.reshape(-1) for c in chunks])
    assert flat.shape == (PARAM_COUNT,)
    return flat


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------


def _im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """(B, H, W, C) -> (B*H'*W', C*kh*kw) VALID patches.

    Feature ordering is whatever ``conv_general_dilated_patches`` produces
    (channel-major); conv weights are stored in the *same* ordering, so
    model and reference agree by construction.
    """
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, H', W', C*kh*kw)
    hp, wp = h - kh + 1, w - kw + 1
    return patches.reshape(b * hp * wp, c * kh * kw), (hp, wp)


def _avg_pool2(x: jax.Array) -> jax.Array:
    """2x2 average pooling, stride 2, NHWC."""
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) / 4.0


def _conv_block(x: jax.Array, w: jax.Array, b: jax.Array, mm) -> jax.Array:
    """im2col conv + bias + ReLU, matmul injected (pallas or ref)."""
    bsz = x.shape[0]
    cols, (hp, wp) = _im2col(x, 5, 5)
    out = mm(cols, w) + b
    out = jax.nn.relu(out)
    return out.reshape(bsz, hp, wp, w.shape[1])


def forward(flat: jax.Array, x: jax.Array, *, mm=matmul) -> jax.Array:
    """LeNet forward: images (B, 28, 28, 1) -> logits (B, 10)."""
    p = unpack(flat)
    h = _conv_block(x, p["conv1_w"], p["conv1_b"], mm)   # (B,24,24,6)
    h = _avg_pool2(h)                                    # (B,12,12,6)
    h = _conv_block(h, p["conv2_w"], p["conv2_b"], mm)   # (B,8,8,16)
    h = _avg_pool2(h)                                    # (B,4,4,16)
    h = h.reshape(h.shape[0], -1)                        # (B,256)
    h = jax.nn.relu(mm(h, p["fc1_w"]) + p["fc1_b"])
    h = jax.nn.relu(mm(h, p["fc2_w"]) + p["fc2_b"])
    return mm(h, p["fc3_w"]) + p["fc3_b"]


def loss_fn(flat: jax.Array, x: jax.Array, y: jax.Array, *, mm=matmul) -> jax.Array:
    """Mean softmax cross-entropy over the batch."""
    logits = forward(flat, x, mm=mm)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# Exported computations
# --------------------------------------------------------------------------


def train_step(flat, x, y, lr):
    """One fused GD step: (params, x, y, lr) -> (params', loss).

    The gradient and the SGD update live in one executable so XLA fuses
    them; the Rust hot loop does a single PJRT execute per local iteration.
    """
    loss, grad = jax.value_and_grad(loss_fn)(flat, x, y)
    return flat - lr * grad, loss


def grad_step(flat, x, y):
    """(params, x, y) -> (grad, loss) — for Rust-side solvers (DANE)."""
    loss, grad = jax.value_and_grad(loss_fn)(flat, x, y)
    return grad, loss


def eval_step(flat, x, y):
    """(params, x, y) -> (loss_sum, correct_count) over one shard."""
    logits = forward(flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32))
    return jnp.sum(nll), correct


# Reference (pure-jnp matmul) variants used only by pytest.


def forward_ref(flat, x):
    from .kernels.ref import matmul_ref

    return forward(flat, x, mm=matmul_ref)


def loss_ref(flat, x, y):
    from .kernels.ref import matmul_ref

    return loss_fn(flat, x, y, mm=matmul_ref)


def train_step_ref(flat, x, y, lr):
    loss, grad = jax.value_and_grad(loss_ref)(flat, x, y)
    return flat - lr * grad, loss
