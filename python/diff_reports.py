#!/usr/bin/env python3
"""Bitwise-determinism diff for scenario BatchReport JSON files.

The serve smoke job submits a job to a resident `hfl serve` over TCP and
runs the *same* spec layers through `hfl scenario` batch mode, then feeds
both report files here. The determinism contract says everything the
simulation computed must match bitwise; only *measured* wall-clock fields
(resolve_time_s, assoc_time_s, the per-phase "phases" objects, wall_s,
phase_*_s) may differ between the two runs. This script strips exactly
those keys — the same set `scenario::report::strip_measured` strips on
the Rust side — and compares the rest with a precise path diff.

Usage:
  python3 python/diff_reports.py wire_report.json batch_report.json
  python3 python/diff_reports.py --self-test
"""

from __future__ import annotations

import argparse
import json
import sys

MEASURED = ("resolve_time_s", "assoc_time_s", "phases", "wall_s")


def is_measured(key: str) -> bool:
    return key in MEASURED or (key.startswith("phase_") and key.endswith("_s"))


def strip_measured(value):
    """Recursively drop measured wall-clock keys from a JSON value."""
    if isinstance(value, dict):
        return {k: strip_measured(v) for k, v in value.items() if not is_measured(k)}
    if isinstance(value, list):
        return [strip_measured(v) for v in value]
    return value


def diff(a, b, path: str, out: list[str]) -> None:
    """Collect human-readable mismatch paths between two stripped values."""
    if type(a) is not type(b):
        out.append(f"{path}: type {type(a).__name__} != {type(b).__name__}")
        return
    if isinstance(a, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                out.append(f"{path}.{k}: only in second file")
            elif k not in b:
                out.append(f"{path}.{k}: only in first file")
            else:
                diff(a[k], b[k], f"{path}.{k}", out)
    elif isinstance(a, list):
        if len(a) != len(b):
            out.append(f"{path}: length {len(a)} != {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            diff(x, y, f"{path}[{i}]", out)
    elif a != b:
        out.append(f"{path}: {a!r} != {b!r}")


def compare(path_a: str, path_b: str) -> list[str]:
    with open(path_a) as f:
        a = strip_measured(json.load(f))
    with open(path_b) as f:
        b = strip_measured(json.load(f))
    out: list[str] = []
    diff(a, b, "$", out)
    return out


def self_test() -> int:
    wire = {
        "makespan_s": {"mean": 1.25},
        "wall_s": 9.0,
        "phases": {"simulate": 0.4},
        "per_instance": [{"seed": "42", "resolve_time_s": 0.3, "rounds": 7}],
    }
    batch = {
        "makespan_s": {"mean": 1.25},
        "wall_s": 2.0,
        "phases": {"simulate": 0.1},
        "per_instance": [{"seed": "42", "resolve_time_s": 0.9, "rounds": 7}],
    }
    mism: list[str] = []
    diff(strip_measured(wire), strip_measured(batch), "$", mism)
    assert not mism, f"measured-only differences must be ignored: {mism}"

    batch["per_instance"][0]["rounds"] = 8
    mism = []
    diff(strip_measured(wire), strip_measured(batch), "$", mism)
    assert mism == ["$.per_instance[0].rounds: 7 != 8"], mism
    print("diff_reports self-test OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("reports", nargs="*", help="two BatchReport JSON files")
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if len(args.reports) != 2:
        ap.error("expected exactly two report files (or --self-test)")
    mismatches = compare(args.reports[0], args.reports[1])
    if mismatches:
        print(f"DETERMINISM VIOLATION: {args.reports[0]} != {args.reports[1]}")
        for m in mismatches:
            print(f"  {m}")
        return 1
    print(f"{args.reports[0]} == {args.reports[1]} (measured fields stripped)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
