//! Offline substitute for the `anyhow` crate.
//!
//! The repo builds with no network access, so the handful of external
//! crates the code depends on by *name* are vendored as path crates (see
//! rust/Cargo.toml). This one covers the `anyhow` API surface the crate
//! actually uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros
//! and the [`Context`] extension trait. Errors are rendered to a flat
//! message eagerly — no backtraces and no source chain, which is all the
//! CLI/report paths here need.

use std::fmt;

/// A rendered error message. Unlike the real `anyhow::Error` there is no
/// source chain: context is prepended textually at attach time.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context line (mirrors `Error::context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (the chain format) and `{}` coincide: the chain was
        // flattened into the message when the error was built.
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

// `?` conversion from any std error. `Error` itself deliberately does not
// implement `std::error::Error`, exactly like the real crate — that is
// what keeps this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
/// Implemented for any displayable error type (a superset of the real
/// crate's `E: StdError` bound, harmless for in-tree use).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Build an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn macro_forms() {
        let plain = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let x = 7;
        let captured = anyhow!("x = {x}");
        assert_eq!(captured.to_string(), "x = 7");
        let formatted = anyhow!("{} and {}", 1, 2);
        assert_eq!(formatted.to_string(), "1 and 2");
        let from_string = anyhow!(String::from("owned"));
        assert_eq!(from_string.to_string(), "owned");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("nope {}", 3);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("disk on fire"));
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: disk on fire");
        let e2 = Error::msg("inner").context("outer");
        assert_eq!(format!("{e2:#}"), "outer: inner");
    }
}
