//! Offline stub of the `xla` PJRT bindings used by `hfl::runtime`.
//!
//! The real crate links libxla_extension and cannot be fetched or built in
//! the offline container, so this stub mirrors exactly the API surface
//! `runtime/engine.rs` touches. Construction of a [`PjRtClient`] fails with
//! a clear message, which makes every runtime-dependent path (the `train`
//! subcommand, the PJRT benches, the runtime integration tests) degrade to
//! a visible "backend unavailable" skip instead of a build break. The
//! latency/optimizer/scenario stack — the paper's actual contribution —
//! never touches PJRT and is unaffected.
//!
//! Swapping this path dependency for the real `xla` crate re-enables
//! training with zero source changes.

use std::fmt;

/// Error type matching the real crate's role in signatures.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable — hfl was built against the offline \
         `xla` stub (rust/vendor/xla); point Cargo.toml at the real xla crate \
         to enable training"
    ))
}

/// Stub PJRT client; [`PjRtClient::cpu`] always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub loaded executable; `execute` always fails before any buffer is
/// produced, so the indexing in callers is never reached at runtime.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub host literal. Constructors succeed (they are pure host-side in the
/// real crate too); every device-facing accessor fails.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_constructors_are_host_side() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        assert!(Literal::scalar(0.5f32).to_tuple2().is_err());
    }
}
