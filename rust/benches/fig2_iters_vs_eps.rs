//! Fig. 2 bench — regenerates the paper's "iterations under different
//! global accuracy" series AND times the solvers that produce it.
//!
//! Paper claim (Fig. 2): as ε decreases (higher accuracy required), the
//! optimal local-iteration count a decreases, the edge-iteration count b
//! increases, and a·b grows. Verified under the integer (⌈R⌉) objective;
//! see EXPERIMENTS.md for the continuous-relaxation caveat.

use hfl::assoc;
use hfl::delay::DelayInstance;
use hfl::metrics::Series;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_continuous, solve_integer, SolveOptions};
use hfl::util::bench::{section, short_mode, Bencher};

fn instance(eps: f64, seed: u64) -> DelayInstance {
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 5, 100, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let a = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();
    DelayInstance::build(&topo, &channel, &a, eps)
}

fn main() {
    section("Fig. 2 — optimal iteration counts vs global accuracy ε (5 edges x 20 UEs)");
    let mut series = Series::new(&["eps", "a_star", "b_star", "a_x_b", "rounds", "total_s"]);
    let opts = SolveOptions::default();
    // `-- --test`: CI smoke shape — a sparser ε sweep, same shape checks.
    let eps_sweep: &[f64] = if short_mode() {
        &[0.5, 0.25, 0.05]
    } else {
        &[0.5, 0.4, 0.3, 0.25, 0.2, 0.15, 0.1, 0.05]
    };
    for &eps in eps_sweep {
        let inst = instance(eps, 42);
        let sol = solve_integer(&inst, &opts);
        series.push(vec![
            eps,
            sol.a as f64,
            sol.b as f64,
            (sol.a * sol.b) as f64,
            sol.rounds as f64,
            sol.objective,
        ]);
    }
    series.print("series (paper Fig. 2)");

    // Shape checks the paper claims (reported, not asserted — the bench
    // prints PASS/DEVIATES so EXPERIMENTS.md can quote it).
    let a_first = series.rows.first().unwrap()[1];
    let a_last = series.rows.last().unwrap()[1];
    let b_first = series.rows.first().unwrap()[2];
    let b_last = series.rows.last().unwrap()[2];
    let ab_first = series.rows.first().unwrap()[3];
    let ab_last = series.rows.last().unwrap()[3];
    println!(
        "shape: a {} as eps shrinks ({} -> {}): {}",
        if a_last <= a_first { "non-increasing" } else { "INCREASING" },
        a_first,
        a_last,
        if a_last <= a_first { "PASS" } else { "DEVIATES" }
    );
    println!(
        "shape: b {} as eps shrinks ({} -> {}): {}",
        if b_last >= b_first { "non-decreasing" } else { "DECREASING" },
        b_first,
        b_last,
        if b_last >= b_first { "PASS" } else { "DEVIATES" }
    );
    println!(
        "shape: a*b grows as eps shrinks ({} -> {}): {}",
        ab_first,
        ab_last,
        if ab_last >= ab_first { "PASS" } else { "DEVIATES" }
    );

    section("solver timing");
    let b = if short_mode() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let inst = instance(0.25, 42);
    b.run("solve_integer (5 edges x 20 UEs)", || {
        solve_integer(&inst, &opts)
    });
    b.run("solve_continuous (5 edges x 20 UEs)", || {
        solve_continuous(&inst, &opts)
    });
}
