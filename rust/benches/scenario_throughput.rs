//! Scenario-engine throughput: instances/second of the parallel fleet
//! runner, for a static batch and a mobility+churn batch, across shard
//! counts. This is the perf trajectory future PRs must beat.
//!
//!   cargo bench --bench scenario_throughput
//!
//! Emits the usual BENCH_JSON lines and rewrites BENCH_scenario.json in
//! the current directory (run from the repo root to refresh the checked-in
//! baseline).

use hfl::scenario::{shard_count, ScenarioRun, ScenarioSpec};
use hfl::util::bench::{section, short_mode};
use hfl::util::json::Json;

struct Row {
    name: String,
    instances: usize,
    shards: usize,
    wall_s: f64,
    instances_per_s: f64,
}

/// Run `run()` `repeats` times, keep the best (wall_s, shards) pair.
fn measure_by<F: FnMut() -> (f64, usize)>(
    name: &str,
    instances: usize,
    repeats: usize,
    mut run: F,
) -> Row {
    let mut best_wall = f64::INFINITY;
    let mut shards = 0;
    for _ in 0..repeats {
        let (wall_s, sh) = run();
        if wall_s < best_wall {
            best_wall = wall_s;
            shards = sh;
        }
    }
    let ips = instances as f64 / best_wall;
    println!(
        "{name:<44} {:>7} inst  {:>2} shards  {:>8.3}s  {:>10.1} inst/s",
        instances, shards, best_wall, ips
    );
    println!(
        "BENCH_JSON {{\"name\":\"{name}\",\"instances\":{instances},\"shards\":{shards},\"wall_s\":{best_wall:.4},\"instances_per_s\":{ips:.2}}}"
    );
    Row {
        name: name.to_string(),
        instances,
        shards,
        wall_s: best_wall,
        instances_per_s: ips,
    }
}

/// Run a batch `repeats` times, keep the best wall-clock.
fn measure(name: &str, spec: &ScenarioSpec, repeats: usize) -> Row {
    measure_by(name, spec.batch.instances, repeats, || {
        let batch = ScenarioRun::new(spec).run_batch().expect("bench batch must run");
        (batch.wall_s, batch.shards)
    })
}

/// Like [`measure`], but with a live per-instance `JsonlSink` (the
/// `--trace` path). Info-only row: quantifies sink overhead against the
/// untraced dynamic row above; the trace-off path itself stays on
/// `NullSink` and is covered by the rows the gate already watches.
fn measure_traced(name: &str, spec: &ScenarioSpec, repeats: usize) -> Row {
    measure_by(name, spec.batch.instances, repeats, || {
        let (batch, sinks) = ScenarioRun::new(spec)
            .run_batch_traced()
            .expect("bench batch must run");
        assert!(
            sinks.iter().all(|s| !s.is_empty()),
            "traced batch must produce per-instance event streams"
        );
        (batch.wall_s, batch.shards)
    })
}

fn main() {
    // `-- --test`: CI smoke shape — smaller batches, single repeat, no
    // baseline rewrite (short numbers are not comparable).
    let short = short_mode();
    let (static_inst, dynamic_inst, repeats) = if short { (8, 4, 1) } else { (64, 32, 3) };
    let mut rows = Vec::new();
    let auto = shard_count(0);

    section("scenario runner: static batches (closed-form regime)");
    let static_spec = ScenarioSpec::new()
        .edges(5)
        .ues(100)
        .eps(0.25)
        .seed(42)
        .instances(static_inst);
    rows.push(measure(
        &format!("static 5x100, {static_inst} inst, 1 shard"),
        &static_spec.clone().shards(1),
        repeats,
    ));
    rows.push(measure(
        &format!("static 5x100, {static_inst} inst, {auto} shards (auto)"),
        &static_spec.clone().shards(0),
        repeats,
    ));

    section("scenario runner: mobility + churn + failures");
    let dynamic_spec = ScenarioSpec::new()
        .edges(5)
        .ues(100)
        .eps(0.25)
        .seed(42)
        .mobility(0.5, 2.0)
        .churn(1.0, 0.02)
        .jitter(0.1)
        .dropout(0.01)
        .epoch_rounds(1)
        .max_epochs(if short { 8 } else { 32 })
        .instances(dynamic_inst);
    rows.push(measure(
        &format!("dynamic 5x100, {dynamic_inst} inst, 1 shard"),
        &dynamic_spec.clone().shards(1),
        repeats,
    ));
    rows.push(measure(
        &format!("dynamic 5x100, {dynamic_inst} inst, {auto} shards (auto)"),
        &dynamic_spec.clone().shards(0),
        repeats,
    ));

    section("trace subsystem: JSONL sink overhead (info only)");
    // Correctness before timing (repo idiom): tracing must not perturb
    // a single outcome bit.
    {
        let spec = dynamic_spec.clone().shards(1);
        let plain = ScenarioRun::new(&spec).run_batch().expect("plain batch must run");
        let (traced, _) = ScenarioRun::new(&spec)
            .run_batch_traced()
            .expect("traced batch must run");
        assert_eq!(plain.outcomes.len(), traced.outcomes.len());
        for (p, t) in plain.outcomes.iter().zip(traced.outcomes.iter()) {
            assert_eq!(p.makespan_s.to_bits(), t.makespan_s.to_bits());
            assert_eq!(p.rounds, t.rounds);
            assert_eq!(p.phase.counters, t.phase.counters);
        }
    }
    rows.push(measure_traced(
        &format!("traced dynamic 5x100, {dynamic_inst} inst, 1 shard"),
        &dynamic_spec.clone().shards(1),
        repeats,
    ));

    // Refresh the checked-in baseline (repo root relative) — full only.
    if short {
        println!("\nshort mode: BENCH_scenario.json left untouched");
        return;
    }
    let json = Json::obj(vec![
        ("bench", Json::str("scenario_throughput")),
        ("generated", Json::Bool(true)),
        (
            "command",
            Json::str("cargo bench --bench scenario_throughput"),
        ),
        (
            "rows",
            Json::arr(rows.iter().map(|r| {
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("instances", Json::num(r.instances as f64)),
                    ("shards", Json::num(r.shards as f64)),
                    ("wall_s", Json::num(r.wall_s)),
                    ("instances_per_s", Json::num(r.instances_per_s)),
                ])
            })),
        ),
    ]);
    let path = "BENCH_scenario.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
