//! Algorithm 2 bench — convergence behaviour of the paper's subgradient
//! solver: iterations to ε₂-accuracy, optimality gap vs the exact convex
//! reference (raw dual recovery AND after the primal polish), and
//! per-solve latency. Complements the paper's O(K ln(1/ε₂)) claim with
//! measured numbers.

use hfl::assoc;
use hfl::delay::DelayInstance;
use hfl::metrics::Series;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_continuous, SolveOptions, SubgradientSolver};
use hfl::util::bench::{section, short_mode, Bencher};

fn instance(eps: f64, seed: u64) -> DelayInstance {
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 5, 100, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let a = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();
    DelayInstance::build(&topo, &channel, &a, eps)
}

fn main() {
    section("Algorithm 2 — optimality gap vs exact solver (10 random instances)");
    let mut series = Series::new(&[
        "seed",
        "exact_J",
        "alg2_raw_J",
        "alg2_polished_J",
        "raw_gap_pct",
        "polished_gap_pct",
        "iters",
    ]);
    let opts = SolveOptions::default();
    let solver = SubgradientSolver::default();
    // `-- --test`: CI smoke shape — fewer instances, same pipeline.
    let seeds = if short_mode() { 3u64 } else { 10u64 };
    for seed in 0..seeds {
        let inst = instance(0.25, 100 + seed);
        let exact = solve_continuous(&inst, &opts);
        let res = solver.solve(&inst);
        series.push(vec![
            seed as f64,
            exact.objective,
            res.raw_objective,
            res.objective,
            (res.raw_objective / exact.objective - 1.0) * 100.0,
            (res.objective / exact.objective - 1.0) * 100.0,
            res.iterations as f64,
        ]);
    }
    series.print("per-instance gaps (percent above exact optimum)");

    section("convergence trace (seed 100, first/last best-objective values)");
    let inst = instance(0.25, 100);
    let res = solver.solve(&inst);
    let trace = &res.trace.best_objective;
    let show: Vec<usize> = [0usize, 1, 2, 5, 10, 20, 50, 100, 200]
        .into_iter()
        .filter(|&i| i < trace.len())
        .collect();
    for i in show {
        println!("  iter {i:>4}: best J = {:.6}", trace[i]);
    }
    println!("  iter {:>4}: best J = {:.6} (final)", trace.len() - 1, trace.last().unwrap());

    section("solver latency");
    let b = if short_mode() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    b.run("Algorithm 2 (polish on)", || solver.solve(&inst));
    let raw = SubgradientSolver {
        polish: false,
        ..SubgradientSolver::default()
    };
    b.run("Algorithm 2 (polish off)", || raw.solve(&inst));
    b.run("exact continuous reference", || {
        solve_continuous(&inst, &opts)
    });
}
