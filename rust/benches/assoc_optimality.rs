//! Ablation bench — how close is Algorithm 3 to the true optimum of the
//! association MILP (39)?  Compares, on instances small enough for the
//! exponential branch-and-bound the paper dismisses:
//!
//!   Algorithm 3  vs  Algorithm 3 + 1-move refinement (our extension)
//!                vs  exact B&B  vs  exact threshold-matching
//!
//! and cross-checks that both exact methods agree.

use hfl::assoc::{self, proposed::refine_swaps, LatencyTable};
use hfl::metrics::Series;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::util::bench::{section, short_mode, Bencher};

fn world(edges: usize, ues: usize, seed: u64) -> (Channel, LatencyTable, usize) {
    let mut params = SystemParams::default();
    // Small capacity so B&B instances stay interesting but bounded.
    params.ue_bandwidth_hz = params.edge_bandwidth_hz / ((ues / edges) as f64 + 2.0);
    let topo = Topology::sample(&params, edges, ues, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let table = LatencyTable::build(&topo, &channel, 20.0);
    let cap = params.edge_capacity();
    (channel, table, cap)
}

fn main() {
    section("Algorithm 3 optimality gap on B&B-tractable instances (3 edges x 12 UEs)");
    let mut series = Series::new(&[
        "seed",
        "alg3_s",
        "alg3_claims_s",
        "alg3_refined_s",
        "bnb_s",
        "matching_s",
        "alg3_gap_pct",
        "refined_gap_pct",
    ]);
    let mut agree = 0;
    // `-- --test`: CI smoke shape — fewer seeds, same pipeline.
    let seeds = if short_mode() { 4u64 } else { 12u64 };
    for seed in 0..seeds {
        let (channel, table, cap) = world(3, 12, seed);
        let alg3 = assoc::time_minimized(&channel, cap).unwrap();
        let claims = assoc::time_minimized_claims(&channel, cap).unwrap();
        let refined = refine_swaps(&alg3, &table, cap, 100);
        let bnb = assoc::solve_exact_bnb(&table, cap, Some(&alg3)).unwrap();
        let matching = assoc::solve_exact_matching(&table, cap).unwrap();
        let (l3, lc, lr, lb, lm) = (
            table.max_latency(&alg3),
            table.max_latency(&claims),
            table.max_latency(&refined),
            table.max_latency(&bnb),
            table.max_latency(&matching),
        );
        if (lb - lm).abs() < 1e-9 {
            agree += 1;
        }
        series.push(vec![
            seed as f64,
            l3,
            lc,
            lr,
            lb,
            lm,
            (l3 / lb - 1.0) * 100.0,
            (lr / lb - 1.0) * 100.0,
        ]);
    }
    series.print("per-seed max latency (s) and gap vs exact optimum");
    println!(
        "exact methods agree on {agree}/{seeds} seeds: {}",
        if agree == seeds { "PASS" } else { "FAIL" }
    );

    section("scaling: exact matching stays sub-millisecond where B&B explodes");
    let bench = Bencher::quick();
    for (edges, ues) in [(3usize, 9usize), (3, 12), (4, 14)] {
        let (_c, table, cap) = world(edges, ues, 3);
        bench.run(&format!("B&B ({edges}x{ues})"), || {
            assoc::solve_exact_bnb(&table, cap, None).unwrap()
        });
    }
    let matching_shapes: &[(usize, usize)] = if short_mode() {
        &[(5, 100)]
    } else {
        &[(5, 100), (10, 200), (10, 500)]
    };
    for &(edges, ues) in matching_shapes {
        let (_c, table, cap) = world(edges, ues, 3);
        bench.run(&format!("matching ({edges}x{ues})"), || {
            assoc::solve_exact_matching(&table, cap).unwrap()
        });
    }
}
