//! Incremental association: cold policy re-runs vs the maintained
//! dirty-set engine, on the `configs/scenario_scale.toml` workload shape
//! (100k UEs x 64 edges, churn-dominated dynamics).
//!
//!   cargo bench --bench assoc_incremental          # full workload
//!   cargo bench --bench assoc_incremental -- --test  # CI smoke shape
//!
//! Three stages:
//!
//! * **engine**: scenario runs of a mobility+churn batch under
//!   `assoc_resolve = "cold"` vs `"warm"` — asserts identical (a*, b*)
//!   trajectories, bitwise-identical makespans and equal handovers
//!   before any timing (the acceptance cross-check).
//! * **maps**: one drifting scale world; every epoch the cold policy map
//!   and the warm engine map are asserted bitwise-identical, then both
//!   paths are timed. Cold re-scores and re-sorts all U·M links; warm
//!   reprocesses only the epoch's dirty set.
//! * Emits BENCH_JSON lines and (full mode only) rewrites
//!   `BENCH_assoc.json` in the current directory — to refresh the
//!   checked-in baseline run from the repo root:
//!   `cargo bench --manifest-path rust/Cargo.toml --bench
//!   assoc_incremental`. Acceptance target: warm >= 5x faster per epoch
//!   on the scale workload.

use std::time::Instant;

use hfl::assoc::{cold_reference_map, MaintainedAssociation, WorldDelta};
use hfl::config::{Args, AssocStrategy};
use hfl::net::{Channel, Position, Topology};
use hfl::scenario::{ResolveMode, ScenarioRun, ScenarioSpec};
use hfl::util::bench::{section, short_mode};
use hfl::util::json::Json;
use hfl::util::Rng;

/// The scenario_mobility.toml workload shrunk to bench size — every
/// delta type fires (moved rows, arrivals, departures, handovers).
fn mobility_spec(assoc_resolve: ResolveMode, short: bool) -> ScenarioSpec {
    ScenarioSpec::new()
        .edges(5)
        .ues(100)
        .eps(0.25)
        .seed(42)
        .mobility(0.5, 2.0)
        .churn(1.0, 0.02)
        .epoch_rounds(1)
        .max_epochs(if short { 8 } else { 32 })
        .instances(if short { 4 } else { 12 })
        .shards(1)
        .assoc_resolve(assoc_resolve)
}

/// Load the checked-in scale spec (repo root or rust/ cwd), falling back
/// to an identical inline shape.
fn scale_spec() -> ScenarioSpec {
    for path in [
        "configs/scenario_scale.toml",
        "../configs/scenario_scale.toml",
    ] {
        if std::path::Path::new(path).exists() {
            match ScenarioSpec::load(Some(path), &Args::default()) {
                Ok(spec) => return spec,
                Err(e) => println!("note: could not load {path}: {e}"),
            }
        }
    }
    let mut spec = ScenarioSpec::new()
        .edges(64)
        .ues(100_000)
        .eps(0.25)
        .seed(42)
        .churn(200.0, 0.002)
        .epoch_rounds(1)
        .max_epochs(6);
    spec.base.system.edge_bandwidth_hz = 2.0e9;
    spec.base.system.ue_bandwidth_hz = 1.0e6;
    spec
}

fn main() {
    let short = short_mode();

    section("engine: assoc_resolve warm vs cold, mobility + churn batch");
    let cold_spec = mobility_spec(ResolveMode::Cold, short);
    let warm_spec = mobility_spec(ResolveMode::Warm, short);
    let cold_batch = ScenarioRun::new(&cold_spec).run_batch().expect("cold batch");
    let warm_batch = ScenarioRun::new(&warm_spec).run_batch().expect("warm batch");
    for (c, w) in cold_batch.outcomes.iter().zip(&warm_batch.outcomes) {
        assert_eq!(c.ab_per_epoch, w.ab_per_epoch, "warm assoc diverged from cold");
        assert_eq!(c.makespan_s.to_bits(), w.makespan_s.to_bits());
        assert_eq!(c.handovers, w.handovers);
    }
    let engine_instances = cold_batch.outcomes.len();
    println!("cross-check: warm == cold on all {engine_instances} instances");
    let (mut cold_reassoc, mut warm_reassoc) = (0u64, 0u64);
    for (c, w) in cold_batch.outcomes.iter().zip(&warm_batch.outcomes) {
        cold_reassoc += c.reassociations;
        warm_reassoc += w.reassociations;
    }
    println!("reprocessed UEs: cold {cold_reassoc}  warm {warm_reassoc}");

    section("maps: cold policy re-run vs MaintainedAssociation sync, scale world");
    let spec = scale_spec();
    let (num_edges, num_ues) = if short {
        (8usize, 2000usize)
    } else {
        // The checked-in scale config has grown past this bench's workload
        // (1M x 256 — that regime belongs to benches/scale_parallel.rs).
        // Cap to the original 100k x 64 slice so BENCH_assoc.json stays
        // comparable across baseline regenerations.
        (spec.base.num_edges.min(64), spec.base.num_ues.min(100_000))
    };
    let cap = spec.base.system.edge_capacity();
    let seed = spec.base.seed;
    let epochs = if short { 3 } else { spec.dynamics.max_epochs.min(6) };
    let churn_per_epoch = if short {
        20
    } else {
        // Capped with the dims above: ~200 is the 100k slice's drift.
        spec.dynamics.arrival_rate.round().min(200.0) as usize
    };
    let moved_per_epoch = churn_per_epoch;
    println!(
        "world: {num_edges} edges x {num_ues} UEs, cap {cap}, {epochs} epochs, \
         ~{churn_per_epoch} arrivals/departures + {moved_per_epoch} moved rows per epoch"
    );

    let mut topo = Topology::sample(&spec.base.system, num_edges, num_ues, seed);
    let mut channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let mut active = vec![true; num_ues];
    let mut inactive_pool: Vec<usize> = Vec::new();
    let area = topo.params.area_m;
    let strategy = AssocStrategy::Proposed;
    let a0 = 20.0;

    let t0 = Instant::now();
    let mut engine = MaintainedAssociation::new(
        strategy,
        &topo,
        &channel,
        &active,
        cap,
        spec.assoc_hysteresis,
        a0,
    )
    .expect("engine build");
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("engine cold build: {build_ms:.1} ms");

    let mut rng = Rng::new(seed ^ 0xA550_C0DE);
    let mut cold_s = 0.0f64;
    let mut warm_s = 0.0f64;
    let rebuilds_before = engine.full_rebuilds;
    for epoch in 0..epochs {
        // Churn + a sprinkle of moved rows — the scale workload's drift.
        let mut delta = WorldDelta::default();
        for _ in 0..churn_per_epoch {
            let ue = rng.below(num_ues as u64) as usize;
            if active[ue] {
                active[ue] = false;
                inactive_pool.push(ue);
                delta.departed.push(ue);
            }
        }
        for _ in 0..churn_per_epoch.min(inactive_pool.len()) {
            let slot = rng.below(inactive_pool.len() as u64) as usize;
            let ue = inactive_pool.swap_remove(slot);
            active[ue] = true;
            topo.ues[ue].pos = Position {
                x: rng.range(0.0, area),
                y: rng.range(0.0, area),
            };
            channel.recompute_ue(&topo.params, &topo.ues[ue], &topo.edges);
            delta.arrived.push(ue);
        }
        for _ in 0..moved_per_epoch {
            let ue = rng.below(num_ues as u64) as usize;
            if active[ue] {
                topo.ues[ue].pos = Position {
                    x: rng.range(0.0, area),
                    y: rng.range(0.0, area),
                };
                channel.recompute_ue(&topo.params, &topo.ues[ue], &topo.edges);
                delta.moved.push(ue);
            }
        }

        let t_cold = Instant::now();
        let cold = cold_reference_map(strategy, &topo, &channel, &active, cap, a0)
            .expect("cold map");
        cold_s += t_cold.elapsed().as_secs_f64();

        let t_warm = Instant::now();
        engine
            .sync(&topo, &channel, &active, &delta, a0)
            .expect("warm sync");
        warm_s += t_warm.elapsed().as_secs_f64();

        // The acceptance invariant, checked on every epoch.
        assert_eq!(
            engine.edge_of_global(),
            cold,
            "warm map diverged from cold at epoch {epoch}"
        );
    }
    let fast_path_epochs = epochs as u64 - (engine.full_rebuilds - rebuilds_before);
    let cold_ms = cold_s / epochs as f64 * 1e3;
    let warm_ms = warm_s / epochs as f64 * 1e3;
    let speedup = cold_ms / warm_ms;
    println!(
        "assoc re-solve: cold {cold_ms:.2} ms/epoch  warm {warm_ms:.3} ms/epoch  \
         speedup {speedup:.1}x  ({fast_path_epochs}/{epochs} fast-path epochs)"
    );
    println!("BENCH_JSON {{\"name\":\"assoc cold\",\"per_epoch_ms\":{cold_ms:.3}}}");
    println!("BENCH_JSON {{\"name\":\"assoc warm\",\"per_epoch_ms\":{warm_ms:.4}}}");
    println!("BENCH_JSON {{\"name\":\"assoc warm speedup\",\"value\":{speedup:.2}}}");

    if short {
        println!("\nshort mode: BENCH_assoc.json left untouched");
        return;
    }
    assert!(
        speedup >= 5.0,
        "acceptance: warm must be >= 5x faster per epoch on the scale workload, got {speedup:.2}x"
    );
    let json = Json::obj(vec![
        ("bench", Json::str("assoc_incremental")),
        ("generated", Json::Bool(true)),
        ("command", Json::str("cargo bench --bench assoc_incremental")),
        (
            "workload",
            Json::str(&format!(
                "configs/scenario_scale.toml shape: {num_edges} edges x {num_ues} UEs, \
                 ~{churn_per_epoch} arrivals/departures + {moved_per_epoch} moved rows per \
                 epoch, cap {cap}"
            )),
        ),
        (
            "rows",
            Json::arr(vec![
                Json::obj(vec![
                    ("name", Json::str("assoc cold")),
                    ("per_epoch_ms", Json::num(cold_ms)),
                    ("epochs", Json::num(epochs as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("assoc warm")),
                    ("per_epoch_ms", Json::num(warm_ms)),
                    ("epochs", Json::num(epochs as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("assoc warm speedup")),
                    ("value", Json::num(speedup)),
                    ("target", Json::num(5.0)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("warm fast-path epochs")),
                    ("value", Json::num(fast_path_epochs as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("engine warm==cold instances")),
                    ("value", Json::num(engine_instances as f64)),
                ]),
            ]),
        ),
    ]);
    let path = "BENCH_assoc.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
