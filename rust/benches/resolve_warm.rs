//! Per-epoch (a, b) re-solve latency: cold vs warm-started, on the
//! `configs/scenario_mobility.toml` workload shape.
//!
//!   cargo bench --bench resolve_warm
//!
//! Two measurements:
//!
//! * **engine**: the scenario engine's own re-solve accounting
//!   (`ScenarioOutcome::resolve_time_s / resolves`) under
//!   `resolve = "cold"` (from-scratch rebuild + unseeded solve, the
//!   pre-incremental baseline) vs `"warm"` (maintained instance + warm
//!   seed). Before timing, the bench asserts the two modes produce
//!   identical (a*, b*) trajectories and bitwise-identical makespans.
//! * **solver**: the same cold-vs-warm pipeline isolated from the engine
//!   — one drifting world, per-step `DelayInstance` rebuild + cold
//!   `solve_integer` vs `MaintainedInstance::sync` + warm
//!   `solve_integer_maintained` — asserting cell-identical optima.
//!
//! Emits BENCH_JSON lines and rewrites `BENCH_resolve.json` in the
//! current directory (run from the repo root to refresh the checked-in
//! baseline; the acceptance target is a ≥3x engine speedup).

use std::time::Instant;

use hfl::assoc::Association;
use hfl::delay::{DelayInstance, MaintainedInstance};
use hfl::net::{Channel, Position, SystemParams, Topology};
use hfl::opt::{solve_integer, solve_integer_maintained, SolveOptions};
use hfl::scenario::{ResolveMode, ScenarioRun, ScenarioSpec};
use hfl::util::bench::{black_box, section, short_mode};
use hfl::util::json::Json;
use hfl::util::Rng;

/// The configs/scenario_mobility.toml workload, shrunk to bench size and
/// pinned to one shard so the timing is not scheduler-dependent. Short
/// mode (`-- --test`) shrinks it further for the CI smoke job.
fn mobility_spec(resolve: ResolveMode) -> ScenarioSpec {
    let short = short_mode();
    ScenarioSpec::new()
        .edges(5)
        .ues(100)
        .eps(0.25)
        .seed(42)
        .mobility(0.5, 2.0)
        .churn(1.0, 0.02)
        .jitter(0.1)
        .dropout(0.01)
        .epoch_rounds(1)
        .max_epochs(if short { 16 } else { 64 })
        .instances(if short { 4 } else { 16 })
        .shards(1)
        .resolve(resolve)
}

/// Mean per-epoch re-solve time (µs) and total re-solves of a batch.
fn engine_us(spec: &ScenarioSpec) -> (f64, u64) {
    let batch = ScenarioRun::new(spec).run_batch().expect("bench batch must run");
    let (mut time_s, mut n) = (0.0f64, 0u64);
    for o in &batch.outcomes {
        time_s += o.resolve_time_s;
        n += o.resolves;
    }
    (time_s / n.max(1) as f64 * 1e6, n)
}

fn main() {
    section("engine: per-epoch (a,b) re-solve, mobility + churn batch");
    let cold_spec = mobility_spec(ResolveMode::Cold);
    let warm_spec = mobility_spec(ResolveMode::Warm);

    // Correctness cross-check before any timing: identical trajectories.
    let cold_batch = ScenarioRun::new(&cold_spec).run_batch().expect("cold batch");
    let warm_batch = ScenarioRun::new(&warm_spec).run_batch().expect("warm batch");
    for (c, w) in cold_batch.outcomes.iter().zip(&warm_batch.outcomes) {
        assert_eq!(c.ab_per_epoch, w.ab_per_epoch, "warm diverged from cold");
        assert_eq!(c.makespan_s.to_bits(), w.makespan_s.to_bits());
    }
    println!(
        "cross-check: warm == cold on all {} instances",
        cold_batch.outcomes.len()
    );

    let (cold_us, cold_n) = engine_us(&cold_spec);
    let (warm_us, warm_n) = engine_us(&warm_spec);
    let engine_speedup = cold_us / warm_us;
    println!(
        "engine re-solve: cold {cold_us:.1} µs/epoch ({cold_n} resolves)  warm {warm_us:.1} µs/epoch ({warm_n} resolves)  speedup {engine_speedup:.2}x"
    );
    println!(
        "BENCH_JSON {{\"name\":\"engine resolve cold\",\"per_epoch_us\":{cold_us:.2},\"resolves\":{cold_n}}}"
    );
    println!(
        "BENCH_JSON {{\"name\":\"engine resolve warm\",\"per_epoch_us\":{warm_us:.2},\"resolves\":{warm_n}}}"
    );
    println!("BENCH_JSON {{\"name\":\"engine resolve speedup\",\"value\":{engine_speedup:.3}}}");

    section("solver: rebuild+cold vs sync+warm over one drifting world");
    let steps = if short_mode() { 50usize } else { 200usize };
    let topo0 = Topology::sample(&SystemParams::default(), 5, 100, 42);
    let edge_of_plain: Vec<usize> = (0..100).map(|i| i % 5).collect();
    let edge_of: Vec<Option<usize>> = edge_of_plain.iter().map(|&e| Some(e)).collect();
    let assoc = Association::new(edge_of_plain, 5);
    let opts = SolveOptions::default();
    let mut rng = Rng::new(0xD21F);
    let area = topo0.params.area_m;

    // Cold lap: per step, move one UE, rebuild the instance, solve.
    let mut topo = topo0.clone();
    let mut channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let mut cold_cells = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for step in 0..steps {
        let n = step % 100;
        topo.ues[n].pos = Position {
            x: rng.range(0.0, area),
            y: rng.range(0.0, area),
        };
        channel.recompute_ue(&topo.params, &topo.ues[n], &topo.edges);
        let inst = DelayInstance::build(&topo, &channel, &assoc, 0.25);
        let sol = black_box(solve_integer(&inst, &opts));
        cold_cells.push((sol.a, sol.b));
    }
    let solver_cold_us = t0.elapsed().as_secs_f64() / steps as f64 * 1e6;

    // Warm lap: identical drift (fresh rng with the same seed), but the
    // maintained instance absorbs each delta and the solver is seeded.
    let mut rng = Rng::new(0xD21F);
    let mut topo = topo0.clone();
    let mut channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let mut maintained = MaintainedInstance::build(&topo, &channel, &edge_of, 0.25);
    let mut warm_cells = Vec::with_capacity(steps);
    let mut prev = None;
    let t0 = Instant::now();
    for step in 0..steps {
        let n = step % 100;
        topo.ues[n].pos = Position {
            x: rng.range(0.0, area),
            y: rng.range(0.0, area),
        };
        channel.recompute_ue(&topo.params, &topo.ues[n], &topo.edges);
        maintained.sync(&topo, &channel, &edge_of);
        let sol = black_box(solve_integer_maintained(&mut maintained, &opts, prev));
        prev = Some((sol.a, sol.b));
        warm_cells.push((sol.a, sol.b));
    }
    let solver_warm_us = t0.elapsed().as_secs_f64() / steps as f64 * 1e6;
    assert_eq!(cold_cells, warm_cells, "solver warm diverged from cold");

    let solver_speedup = solver_cold_us / solver_warm_us;
    println!(
        "solver pipeline: cold {solver_cold_us:.1} µs  warm {solver_warm_us:.1} µs  speedup {solver_speedup:.2}x"
    );
    println!(
        "BENCH_JSON {{\"name\":\"solver resolve cold\",\"per_solve_us\":{solver_cold_us:.2}}}"
    );
    println!(
        "BENCH_JSON {{\"name\":\"solver resolve warm\",\"per_solve_us\":{solver_warm_us:.2}}}"
    );
    println!("BENCH_JSON {{\"name\":\"solver resolve speedup\",\"value\":{solver_speedup:.3}}}");

    // Refresh the checked-in baseline (repo root relative) — full runs
    // only: short-mode numbers are not comparable to the committed rows.
    if short_mode() {
        println!("\nshort mode: BENCH_resolve.json left untouched");
        return;
    }
    let json = Json::obj(vec![
        ("bench", Json::str("resolve_warm")),
        ("generated", Json::Bool(true)),
        ("command", Json::str("cargo bench --bench resolve_warm")),
        (
            "workload",
            Json::str(
                "configs/scenario_mobility.toml shape: 5 edges x 100 UEs, mobility 0.5-2.0 m/s, \
                 churn +1.0/-0.02, 16 instances x <=64 epochs, 1 shard",
            ),
        ),
        (
            "rows",
            Json::arr(vec![
                Json::obj(vec![
                    ("name", Json::str("engine resolve cold")),
                    ("per_epoch_us", Json::num(cold_us)),
                    ("resolves", Json::num(cold_n as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("engine resolve warm")),
                    ("per_epoch_us", Json::num(warm_us)),
                    ("resolves", Json::num(warm_n as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("engine resolve speedup")),
                    ("value", Json::num(engine_speedup)),
                    ("target", Json::num(3.0)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("solver resolve cold")),
                    ("per_solve_us", Json::num(solver_cold_us)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("solver resolve warm")),
                    ("per_solve_us", Json::num(solver_warm_us)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("solver resolve speedup")),
                    ("value", Json::num(solver_speedup)),
                ]),
            ]),
        ),
    ]);
    let path = "BENCH_resolve.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
