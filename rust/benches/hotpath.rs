//! Hot-path bench — the performance-critical operations of every layer:
//!
//!   L3: model aggregation (Eq. (6)/(10)), event-simulator throughput,
//!       solver latency, channel-table construction;
//!   runtime: PJRT train/eval step latency (needs `make artifacts`;
//!       skipped otherwise) and the non-PJRT overhead fraction of a full
//!       coordinated round.
//!
//! Results feed EXPERIMENTS.md §Perf.

use hfl::assoc;
use hfl::data::synthetic::{generate_split, SyntheticConfig};
use hfl::delay::DelayInstance;
use hfl::fl::aggregate::{weighted_average, weighted_average_into};
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};
use hfl::runtime::{find_artifacts, Engine};
use hfl::sim::{simulate, SimConfig};
use hfl::util::bench::{section, short_mode, Bencher};
use hfl::util::Rng;

fn main() {
    // `-- --test`: CI smoke shape (tiny sample windows, same coverage).
    let b = if short_mode() {
        Bencher::quick()
    } else {
        Bencher::default()
    };

    section("L3: aggregation (Eq. (6)/(10)) — 20 UE models x 44426 params");
    let dim = 44426;
    let mut rng = Rng::new(1);
    let models: Vec<Vec<f32>> = (0..20)
        .map(|_| (0..dim).map(|_| rng.f64() as f32).collect())
        .collect();
    let weighted: Vec<(f64, &[f32])> = models.iter().map(|m| (500.0, m.as_slice())).collect();
    b.run("weighted_average (alloc)", || weighted_average(&weighted));
    let mut out = vec![0.0f32; dim];
    b.run("weighted_average_into (no alloc)", || {
        weighted_average_into(&weighted, &mut out)
    });

    section("L3: wireless substrate");
    let params = SystemParams::default();
    b.run("Topology::sample (5 edges, 100 UEs)", || {
        Topology::sample(&params, 5, 100, 42)
    });
    let topo = Topology::sample(&params, 5, 100, 42);
    b.run("Channel::compute (100x5 table)", || {
        Channel::compute(&topo.params, &topo.ues, &topo.edges)
    });

    section("L3: optimizer + simulator");
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let association = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();
    let inst = DelayInstance::build(&topo, &channel, &association, 0.25);
    let opts = SolveOptions::default();
    b.run("solve_integer (100 UEs)", || solve_integer(&inst, &opts));
    let sol = solve_integer(&inst, &opts);
    let cfg = SimConfig::deterministic(sol.a, sol.b);
    let m = b.run("event sim (one full protocol)", || simulate(&inst, &cfg));
    let events = simulate(&inst, &cfg).events;
    println!(
        "  -> {:.1}M events/s",
        events as f64 / (m.mean_ns() / 1e9) / 1e6
    );

    section("runtime: PJRT step latency (skipped without artifacts)");
    match find_artifacts(None).and_then(|d| Engine::load(&d)) {
        Err(e) => println!("  SKIP: {e}"),
        Ok(engine) => {
            let hw = engine.meta.image_hw;
            let tb = engine.meta.train_batch;
            let eb = engine.meta.eval_batch;
            let mut rng = Rng::new(7);
            let params_v = engine.init_params();
            let xt: Vec<f32> = (0..tb * hw * hw).map(|_| rng.f64() as f32).collect();
            let yt: Vec<i32> = (0..tb).map(|_| rng.below(10) as i32).collect();
            let xe: Vec<f32> = (0..eb * hw * hw).map(|_| rng.f64() as f32).collect();
            let ye: Vec<i32> = (0..eb).map(|_| rng.below(10) as i32).collect();
            let slow = Bencher {
                sample_target_s: 0.3,
                samples: 5,
                warmup_s: 1.0,
            };
            let mt = slow.run("train_step (B=32, fused fwd+bwd+update)", || {
                engine.train_step(&params_v, &xt, &yt, 0.05).unwrap()
            });
            slow.run("grad_step (B=32)", || {
                engine.grad_step(&params_v, &xt, &yt).unwrap()
            });
            let me = slow.run("eval_step (B=128)", || {
                engine.eval_step(&params_v, &xe, &ye).unwrap()
            });
            // Per-image costs for the §Perf table.
            println!(
                "  -> train {:.2} ms/image, eval {:.3} ms/image",
                mt.mean_ns() / 1e6 / tb as f64,
                me.mean_ns() / 1e6 / eb as f64
            );

            section("runtime: coordinator overhead (non-PJRT share of a round)");
            let gen = SyntheticConfig::default();
            let shards: Vec<_> = (0..4)
                .map(|i| generate_split(&gen, 64, 42, 9000 + i as u64))
                .collect();
            let test = generate_split(&gen, 128, 42, 12);
            let run = hfl::fl::TrainRun {
                a: 4,
                b: 2,
                cloud_rounds: 1,
                round_time_s: 1.0,
                eval_every: 1,
            };
            let t0 = std::time::Instant::now();
            let before_ns = engine.stats.exec_ns.load(std::sync::atomic::Ordering::Relaxed);
            let _ = hfl::coordinator::run_hfl(
                &engine,
                hfl::fl::LocalSolver::Gd { lr: 0.05 },
                shards,
                vec![vec![0, 1], vec![2, 3]],
                &test,
                &run,
                2,
                42,
            )
            .unwrap();
            let wall = t0.elapsed().as_secs_f64();
            let pjrt =
                (engine.stats.exec_ns.load(std::sync::atomic::Ordering::Relaxed) - before_ns) as f64
                    / 1e9;
            // PJRT time is summed across worker threads; normalize by the
            // parallelism to estimate the wall-clock PJRT share.
            println!(
                "  round wall {:.2}s, summed PJRT exec {:.2}s ({} steps) — overhead {:.1}% of wall (assuming 2-way overlap)",
                wall,
                pjrt,
                engine.stats.train_steps.load(std::sync::atomic::Ordering::Relaxed),
                ((wall - pjrt / 2.0) / wall * 100.0).max(0.0)
            );
        }
    }
}
