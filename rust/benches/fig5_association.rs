//! Fig. 5 bench — maximum system latency of 100 UEs vs the number of
//! edge servers for the proposed / greedy / random association
//! strategies (+ the exact optimum), and the algorithms' own runtime.
//!
//! Paper claims (Fig. 5): proposed < greedy < random at every M, and
//! latency falls as M grows (more choice).

use hfl::assoc::{self, LatencyTable};
use hfl::delay::DelayInstance;
use hfl::metrics::Series;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};
use hfl::util::bench::{section, short_mode, Bencher};
use hfl::util::Rng;

fn main() {
    section("Fig. 5 — max latency of 100 UEs vs #edge servers (ε = 0.25, mean of 5 seeds)");
    let num_ues = 100;
    // `-- --test`: CI smoke shape — fewer sweep points and trials.
    let short = short_mode();
    let trials = if short { 2u64 } else { 5u64 };
    let edge_counts: &[usize] = if short {
        &[6, 10, 16]
    } else {
        &[6, 7, 8, 9, 10, 12, 14, 16]
    };
    let mut series = Series::new(&["edges", "proposed_s", "greedy_s", "random_s", "exact_s"]);
    let mut orderings_ok = 0;
    let mut points = 0;
    for &edges in edge_counts {
        let (mut p, mut g, mut r, mut e) = (0.0, 0.0, 0.0, 0.0);
        for t in 0..trials {
            let params = SystemParams::default();
            let topo = Topology::sample(&params, edges, num_ues, 42 + t * 1000);
            let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
            let cap = params.edge_capacity();
            let prov = assoc::greedy(&channel, cap).unwrap();
            let inst = DelayInstance::build(&topo, &channel, &prov, 0.25);
            let a = solve_integer(&inst, &SolveOptions::default()).a;
            let table = LatencyTable::build(&topo, &channel, a as f64);

            p += table.max_latency(&assoc::time_minimized(&channel, cap).unwrap());
            g += table.max_latency(&assoc::greedy(&channel, cap).unwrap());
            r += table.max_latency(
                &assoc::random(num_ues, edges, cap, &mut Rng::new(42 + t)).unwrap(),
            );
            e += table.max_latency(&assoc::solve_exact_matching(&table, cap).unwrap());
        }
        let k = trials as f64;
        let (p, g, r, e) = (p / k, g / k, r / k, e / k);
        if p <= g && g <= r {
            orderings_ok += 1;
        }
        points += 1;
        series.push(vec![edges as f64, p, g, r, e]);
    }
    series.print("series (paper Fig. 5)");
    println!(
        "shape: proposed <= greedy <= random at {orderings_ok}/{points} points: {}",
        if orderings_ok == points { "PASS" } else { "PARTIAL" }
    );

    section("association algorithm runtime (100 UEs)");
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 10, num_ues, 42);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let cap = params.edge_capacity();
    let table = LatencyTable::build(&topo, &channel, 20.0);
    let bench = if short {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    bench.run("Algorithm 3 (proposed)", || {
        assoc::time_minimized(&channel, cap).unwrap()
    });
    bench.run("greedy", || assoc::greedy(&channel, cap).unwrap());
    let mut rng = Rng::new(1);
    bench.run("random", || {
        assoc::random(num_ues, 10, cap, &mut rng).unwrap()
    });
    bench.run("exact matching (binary search + Dinic)", || {
        assoc::solve_exact_matching(&table, cap).unwrap()
    });
}
