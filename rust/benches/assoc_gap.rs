//! Optimality-certificate study: gap vs solve time across the
//! association policies (proposed / greedy / flow / exact), plus the
//! flow lower bound timed at the `configs/scenario_scale.toml` slice
//! (100k UEs x 64 edges).
//!
//!   cargo bench --bench assoc_gap          # full workload
//!   cargo bench --bench assoc_gap -- --test  # CI smoke shape
//!
//! Two stages:
//!
//! * **gap**: one tractable world; every policy solves it, gets timed,
//!   and is certified against the flow lower bound. Asserted before any
//!   reporting: every certificate holds (bound <= achieved), and the
//!   flow and exact solvers close the gap to exactly 0.0 (bound and
//!   achieved are the *same* latency-table entry, so the equality is
//!   bitwise, not approximate).
//! * **scale**: `flow_lower_bound` on the 100k x 64 slice, timed
//!   against the per-epoch maintenance budget (2000 ms — generous on
//!   purpose: CI runners are shared and wall-clock rows never gate;
//!   the assert only catches complexity regressions, not jitter).
//!
//! Emits BENCH_JSON lines and (full mode only) rewrites
//! `BENCH_gap.json` in the current directory — to refresh the
//! checked-in baseline run from the repo root:
//! `cargo bench --manifest-path rust/Cargo.toml --bench assoc_gap`.
//! Gap and wall-clock rows are informational (no "speedup" rows), so
//! `check_bench.py` reports them without hard-gating.

use std::time::Instant;

use hfl::assoc::{
    certify, flow_lower_bound, greedy, solve_exact_matching, solve_flow, time_minimized,
    Association, LatencyTable,
};
use hfl::config::Args;
use hfl::net::{Channel, Topology};
use hfl::scenario::ScenarioSpec;
use hfl::util::bench::{section, short_mode};
use hfl::util::json::Json;

/// Load the checked-in scale spec (repo root or rust/ cwd), falling back
/// to an identical inline shape (same loader as benches/assoc_incremental.rs).
fn scale_spec() -> ScenarioSpec {
    for path in [
        "configs/scenario_scale.toml",
        "../configs/scenario_scale.toml",
    ] {
        if std::path::Path::new(path).exists() {
            match ScenarioSpec::load(Some(path), &Args::default()) {
                Ok(spec) => return spec,
                Err(e) => println!("note: could not load {path}: {e}"),
            }
        }
    }
    let mut spec = ScenarioSpec::new()
        .edges(64)
        .ues(100_000)
        .eps(0.25)
        .seed(42)
        .churn(200.0, 0.002)
        .epoch_rounds(1)
        .max_epochs(6);
    spec.base.system.edge_bandwidth_hz = 2.0e9;
    spec.base.system.ue_bandwidth_hz = 1.0e6;
    spec
}

fn timed<F: FnOnce() -> Result<Association, String>>(f: F) -> (Result<Association, String>, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64() * 1e3)
}

fn main() {
    let short = short_mode();
    let spec = scale_spec();
    let cap = spec.base.system.edge_capacity();
    let seed = spec.base.seed;
    let a0 = 20.0;

    section("gap vs time: proposed / greedy / flow / exact on one tractable world");
    let (num_edges, num_ues) = if short { (8usize, 500usize) } else { (16usize, 4000usize) };
    // The scale spec's capacity never binds at this slice; tighten it to
    // 125% of a perfectly balanced load so the policies actually have to
    // trade latency against capacity and the gaps are non-degenerate.
    let gap_cap = (num_ues.div_ceil(num_edges) * 5).div_ceil(4);
    println!("world: {num_edges} edges x {num_ues} UEs, cap {gap_cap}, a = {a0}");
    let topo = Topology::sample(&spec.base.system, num_edges, num_ues, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let table = LatencyTable::build(&topo, &channel, a0);

    let results = [
        ("proposed", timed(|| time_minimized(&channel, gap_cap))),
        ("greedy", timed(|| greedy(&channel, gap_cap))),
        ("flow", timed(|| solve_flow(&table, gap_cap))),
        ("exact", timed(|| solve_exact_matching(&table, gap_cap))),
    ];
    let mut policy_rows = Vec::new();
    for (name, (result, solve_ms)) in &results {
        let assoc = match result {
            Ok(a) => a,
            Err(e) => panic!("{name}: {e}"),
        };
        assoc.validate(gap_cap).expect("feasible association");
        let cert =
            certify(&table, gap_cap, assoc).unwrap_or_else(|e| panic!("certify {name}: {e}"));
        assert!(
            cert.holds(),
            "{name}: certificate does not hold (bound {} vs achieved {})",
            cert.lower_bound,
            cert.achieved
        );
        if matches!(*name, "flow" | "exact") {
            // Both sit exactly on the bottleneck optimum: the bound and
            // the achieved max-latency are the same table entry.
            assert_eq!(
                cert.gap.to_bits(),
                0.0f64.to_bits(),
                "{name}: expected a closed gap, got {}",
                cert.gap
            );
        }
        println!(
            "{name:<9} solve {solve_ms:>9.3} ms  achieved {:.6} s  gap {:.6} s",
            cert.achieved, cert.gap
        );
        println!(
            "BENCH_JSON {{\"name\":\"gap {name}\",\"gap_s\":{:.9},\"solve_ms\":{solve_ms:.3}}}",
            cert.gap
        );
        policy_rows.push(Json::obj(vec![
            ("name", Json::str(&format!("gap {name}"))),
            ("gap_s", Json::num(cert.gap)),
            ("achieved_s", Json::num(cert.achieved)),
            ("lower_bound_s", Json::num(cert.lower_bound)),
            ("solve_ms", Json::num(*solve_ms)),
        ]));
    }

    section("scale: flow lower bound on the scenario_scale slice");
    let (big_edges, big_ues) = if short {
        (8usize, 2000usize)
    } else {
        // Cap to the 100k x 64 slice (the checked-in config has grown to
        // 1M x 256) so BENCH_gap.json stays comparable across baselines.
        (spec.base.num_edges.min(64), spec.base.num_ues.min(100_000))
    };
    let topo_big = Topology::sample(&spec.base.system, big_edges, big_ues, seed);
    let channel_big = Channel::compute(&topo_big.params, &topo_big.ues, &topo_big.edges);
    let t = Instant::now();
    let table_big = LatencyTable::build(&topo_big, &channel_big, a0);
    let table_ms = t.elapsed().as_secs_f64() * 1e3;
    let t = Instant::now();
    let bound = flow_lower_bound(&table_big, cap).expect("scale bound");
    let bound_ms = t.elapsed().as_secs_f64() * 1e3;
    assert!(
        bound.is_finite() && bound > 0.0,
        "scale bound must be a finite positive latency, got {bound}"
    );
    println!(
        "{big_edges} edges x {big_ues} UEs: table build {table_ms:.1} ms, \
         flow bound {bound:.6} s in {bound_ms:.1} ms"
    );
    println!("BENCH_JSON {{\"name\":\"flow bound scale\",\"bound_ms\":{bound_ms:.2}}}");
    if !short {
        // Acceptance: certifying an epoch of the 100k x 64 world fits the
        // per-epoch maintenance budget.
        assert!(
            bound_ms <= 2000.0,
            "acceptance: flow bound at {big_ues} UEs x {big_edges} edges took \
             {bound_ms:.0} ms > 2000 ms budget"
        );
    }

    if short {
        println!("\nshort mode: BENCH_gap.json left untouched");
        return;
    }
    let mut rows = policy_rows;
    rows.push(Json::obj(vec![
        ("name", Json::str("flow bound scale")),
        ("bound_ms", Json::num(bound_ms)),
        ("budget_ms", Json::num(2000.0)),
        ("edges", Json::num(big_edges as f64)),
        ("ues", Json::num(big_ues as f64)),
    ]));
    let json = Json::obj(vec![
        ("bench", Json::str("assoc_gap")),
        ("generated", Json::Bool(true)),
        ("command", Json::str("cargo bench --bench assoc_gap")),
        (
            "workload",
            Json::str(&format!(
                "gap slice: {num_edges} edges x {num_ues} UEs cap {gap_cap}; bound \
                 slice: {big_edges} edges x {big_ues} UEs cap {cap} \
                 (configs/scenario_scale.toml shape), a = {a0}"
            )),
        ),
        ("rows", Json::arr(rows)),
    ]);
    let path = "BENCH_gap.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
