//! Serial vs SoA-sharded epoch maintenance at the 1M-UE x 256-edge scale
//! (`configs/scenario_scale.toml`).
//!
//!   cargo bench --bench scale_parallel            # full 1M workload
//!   cargo bench --bench scale_parallel -- --test  # CI smoke shape
//!
//! Two warm association engines — one at `intra_threads = 1`, one at the
//! machine's core count — receive the identical epoch deltas. Every
//! epoch both maps are asserted bitwise-identical (and likewise the cold
//! builds, and the delay engine's frontiers) *before* any timing is
//! reported, so the speedup below can never come from divergent work.
//! Full mode rewrites `BENCH_scale.json`; the gated row is "scale
//! parallel maintenance speedup" (acceptance: >= 2x at 4+ threads —
//! asserted here, gated against the committed baseline by
//! `python/check_bench.py`).

use std::time::Instant;

use hfl::assoc::{MaintainedAssociation, WorldDelta};
use hfl::config::{Args, AssocStrategy};
use hfl::delay::MaintainedInstance;
use hfl::net::{Channel, Position, Topology};
use hfl::scenario::ScenarioSpec;
use hfl::trace::NullSink;
use hfl::util::bench::{section, short_mode};
use hfl::util::json::Json;
use hfl::util::{Rng, ShardPool};

/// Load the checked-in scale spec (repo root or rust/ cwd), falling back
/// to an identical inline shape.
fn scale_spec() -> ScenarioSpec {
    for path in [
        "configs/scenario_scale.toml",
        "../configs/scenario_scale.toml",
    ] {
        if std::path::Path::new(path).exists() {
            match ScenarioSpec::load(Some(path), &Args::default()) {
                Ok(spec) => return spec,
                Err(e) => println!("note: could not load {path}: {e}"),
            }
        }
    }
    let mut spec = ScenarioSpec::new()
        .edges(256)
        .ues(1_000_000)
        .eps(0.25)
        .seed(42)
        .churn(2000.0, 0.002)
        .epoch_rounds(1)
        .max_epochs(6)
        .intra_threads(0);
    spec.base.system.edge_bandwidth_hz = 2.0e9;
    spec.base.system.ue_bandwidth_hz = 4.0e5;
    spec
}

fn main() {
    let short = short_mode();
    let spec = scale_spec();
    let (num_edges, num_ues, epochs, churn_per_epoch) = if short {
        (16usize, 20_000usize, 3usize, 50usize)
    } else {
        (
            spec.base.num_edges,
            spec.base.num_ues,
            4usize,
            spec.dynamics.arrival_rate.round() as usize,
        )
    };
    // Smoke shape pins 2 workers (any machine can run it); full mode uses
    // the config's intra_threads (0 = one per core).
    let par_threads = if short {
        2
    } else {
        ShardPool::new(spec.intra_threads).threads()
    };
    let cap = spec.base.system.edge_capacity();
    let seed = spec.base.seed;
    let moved_per_epoch = churn_per_epoch;
    let strategy = AssocStrategy::Proposed;
    let a0 = 20.0;

    section("scale_parallel: serial vs sharded epoch maintenance");
    println!(
        "world: {num_edges} edges x {num_ues} UEs, cap {cap}, {epochs} epochs, \
         ~{churn_per_epoch} arrivals/departures + {moved_per_epoch} moved rows per epoch, \
         {par_threads} maintenance threads"
    );

    let mut topo = Topology::sample(&spec.base.system, num_edges, num_ues, seed);
    let mut channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let mut active = vec![true; num_ues];
    let mut inactive_pool: Vec<usize> = Vec::new();
    let area = topo.params.area_m;

    // Cold builds: same world, thread counts 1 and N. Bitwise equality of
    // the built maps is the first acceptance assert.
    let t0 = Instant::now();
    let mut serial = MaintainedAssociation::new(
        strategy,
        &topo,
        &channel,
        &active,
        cap,
        spec.assoc_hysteresis,
        a0,
    )
    .expect("serial build");
    let serial_build_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut par = MaintainedAssociation::new_sharded(
        strategy,
        &topo,
        &channel,
        &active,
        cap,
        spec.assoc_hysteresis,
        a0,
        par_threads,
        &mut NullSink,
    )
    .expect("sharded build");
    let par_build_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        serial.edge_of_global(),
        par.edge_of_global(),
        "cold build maps must be bitwise-identical across thread counts"
    );
    let build_ratio = serial_build_s / par_build_s.max(1e-12);
    println!(
        "cold build: serial {:.2} s  sharded {:.2} s  ({build_ratio:.1}x)",
        serial_build_s, par_build_s
    );

    // Delay engine: all-dirty frontier refresh, serial vs edge-parallel,
    // equality asserted per edge before the ratio is reported.
    let edge_of = serial.edge_of_global();
    let mut dserial = MaintainedInstance::build(&topo, &channel, &edge_of, spec.base.eps);
    let mut dpar = dserial.clone();
    dpar.set_intra_threads(par_threads);
    let t0 = Instant::now();
    dserial.refresh();
    let refresh_serial_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    dpar.refresh();
    let refresh_par_s = t0.elapsed().as_secs_f64();
    for e in 0..num_edges {
        assert_eq!(
            dserial.frontier_of(e),
            dpar.frontier_of(e),
            "frontier of edge {e} diverged across thread counts"
        );
    }
    let refresh_ratio = refresh_serial_s / refresh_par_s.max(1e-12);
    println!(
        "frontier refresh (all edges dirty): serial {:.1} ms  sharded {:.1} ms  \
         ({refresh_ratio:.1}x)",
        refresh_serial_s * 1e3,
        refresh_par_s * 1e3
    );

    // Epoch loop: identical churn + mobility deltas into both engines;
    // the map equality assert runs every epoch, before any timing is
    // reported.
    let mut rng = Rng::new(seed ^ 0x5CA1_E0DE);
    let mut serial_s = 0.0f64;
    let mut par_s = 0.0f64;
    for epoch in 0..epochs {
        let mut delta = WorldDelta::default();
        for _ in 0..churn_per_epoch {
            let ue = rng.below(num_ues as u64) as usize;
            if active[ue] {
                active[ue] = false;
                inactive_pool.push(ue);
                delta.departed.push(ue);
            }
        }
        for _ in 0..churn_per_epoch.min(inactive_pool.len()) {
            let slot = rng.below(inactive_pool.len() as u64) as usize;
            let ue = inactive_pool.swap_remove(slot);
            active[ue] = true;
            topo.ues[ue].pos = Position {
                x: rng.range(0.0, area),
                y: rng.range(0.0, area),
            };
            channel.recompute_ue(&topo.params, &topo.ues[ue], &topo.edges);
            delta.arrived.push(ue);
        }
        for _ in 0..moved_per_epoch {
            let ue = rng.below(num_ues as u64) as usize;
            if active[ue] {
                topo.ues[ue].pos = Position {
                    x: rng.range(0.0, area),
                    y: rng.range(0.0, area),
                };
                channel.recompute_ue(&topo.params, &topo.ues[ue], &topo.edges);
                delta.moved.push(ue);
            }
        }

        let t_serial = Instant::now();
        serial
            .sync(&topo, &channel, &active, &delta, a0)
            .expect("serial sync");
        serial_s += t_serial.elapsed().as_secs_f64();

        let t_par = Instant::now();
        par.sync(&topo, &channel, &active, &delta, a0)
            .expect("sharded sync");
        par_s += t_par.elapsed().as_secs_f64();

        assert_eq!(
            serial.edge_of_global(),
            par.edge_of_global(),
            "maps diverged across thread counts at epoch {epoch}"
        );
    }
    let serial_ms = serial_s / epochs as f64 * 1e3;
    let par_ms = par_s / epochs as f64 * 1e3;
    let speedup = serial_ms / par_ms.max(1e-9);
    println!(
        "epoch maintenance: serial {serial_ms:.2} ms/epoch  sharded {par_ms:.2} ms/epoch  \
         speedup {speedup:.2}x on {par_threads} threads"
    );
    println!(
        "BENCH_JSON {{\"name\":\"scale serial maintenance\",\"per_epoch_ms\":{serial_ms:.3}}}"
    );
    println!("BENCH_JSON {{\"name\":\"scale sharded maintenance\",\"per_epoch_ms\":{par_ms:.3}}}");
    println!(
        "BENCH_JSON {{\"name\":\"scale parallel maintenance speedup\",\"value\":{speedup:.2}}}"
    );

    if short {
        println!("\nshort mode: BENCH_scale.json left untouched");
        return;
    }
    // Acceptance: >= 2x at 4+ threads. On narrower runners the ratio is
    // still reported (and gated against the committed baseline), but the
    // hard floor only makes sense with real parallelism available.
    if par_threads >= 4 {
        assert!(
            speedup >= 2.0,
            "acceptance: sharded maintenance must be >= 2x serial at \
             {par_threads} threads, got {speedup:.2}x"
        );
    }
    let json = Json::obj(vec![
        ("bench", Json::str("scale_parallel")),
        ("generated", Json::Bool(true)),
        ("command", Json::str("cargo bench --bench scale_parallel")),
        (
            "workload",
            Json::str(&format!(
                "configs/scenario_scale.toml shape: {num_edges} edges x {num_ues} UEs, \
                 ~{churn_per_epoch} arrivals/departures + {moved_per_epoch} moved rows per \
                 epoch, cap {cap}, {par_threads} maintenance threads"
            )),
        ),
        (
            "rows",
            Json::arr(vec![
                Json::obj(vec![
                    ("name", Json::str("scale serial maintenance")),
                    ("per_epoch_ms", Json::num(serial_ms)),
                    ("epochs", Json::num(epochs as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("scale sharded maintenance")),
                    ("per_epoch_ms", Json::num(par_ms)),
                    ("epochs", Json::num(epochs as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("scale parallel maintenance speedup")),
                    ("value", Json::num(speedup)),
                    ("target", Json::num(2.0)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("maintenance threads")),
                    ("value", Json::num(par_threads as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("cold build ratio")),
                    ("value", Json::num(build_ratio)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("frontier refresh ratio")),
                    ("value", Json::num(refresh_ratio)),
                ]),
            ]),
        ),
    ]);
    let path = "BENCH_scale.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
