//! Heterogeneous device classes + edge outages + deadline aggregation,
//! end to end on the `configs/scenario_hetero.toml` workload (50k UEs x
//! 32 edges, three device classes, ~15% per-epoch edge failures, finite
//! aggregation deadline).
//!
//!   cargo bench --bench hetero_scenario           # full workload
//!   cargo bench --bench hetero_scenario -- --test # CI smoke (same 50k
//!                                                 # world, 1 instance,
//!                                                 # 2 epochs; baselines
//!                                                 # untouched)
//!
//! Stages:
//!
//! * **generalization**: identity-class spec == plain spec, bitwise, on
//!   a small dynamic world (the strict-generalization guard, asserted
//!   before any timing);
//! * **cross-check**: warm vs cold assoc/resolve trajectories on a
//!   shrunken hetero+outage world — identical (a*, b*) sequences and
//!   bitwise-equal makespans;
//! * **world**: the 50k-UE heterogeneous outage world end to end,
//!   timed; asserts outages fired and participation is partial but
//!   nonzero. Full mode rewrites `BENCH_hetero.json` (from the repo
//!   root: `cargo bench --manifest-path rust/Cargo.toml --bench
//!   hetero_scenario`).

use std::time::Instant;

use hfl::config::Args;
use hfl::net::DeviceClassSpec;
use hfl::scenario::{BatchReport, ResolveMode, ScenarioRun, ScenarioSpec};
use hfl::util::bench::{section, short_mode};
use hfl::util::json::Json;

/// Load the checked-in hetero spec (repo root or rust/ cwd). A present-
/// but-broken TOML is fatal — silently falling back to the inline shape
/// would let the two drift apart and gate BENCH_hetero.json against a
/// different world than the one documented. The inline fallback only
/// covers cwds where the config genuinely is not checked out.
fn hetero_spec() -> ScenarioSpec {
    for path in [
        "configs/scenario_hetero.toml",
        "../configs/scenario_hetero.toml",
    ] {
        if std::path::Path::new(path).exists() {
            return ScenarioSpec::load(Some(path), &Args::default())
                .unwrap_or_else(|e| panic!("load {path}: {e}"));
        }
    }
    let mut spec = ScenarioSpec::new()
        .edges(32)
        .ues(50_000)
        .eps(0.25)
        .seed(42)
        .devices(
            DeviceClassSpec::parse(
                "flagship:0.3:1.0:1.0:1.0, mid:0.5:0.5:0.8:1.0, iot:0.2:0.08:0.4:1.5",
            )
            .expect("inline device classes"),
        )
        .deadline(8.0)
        .outage(0.15, 0.5)
        .churn(100.0, 0.002)
        .epoch_rounds(1)
        .max_epochs(6)
        .instances(2);
    spec.base.system.edge_bandwidth_hz = 2.0e9;
    spec.base.system.ue_bandwidth_hz = 1.0e6;
    spec
}

fn main() {
    let short = short_mode();

    section("generalization: identity class + no outage + no deadline == plain, bitwise");
    let plain = ScenarioSpec::new()
        .edges(3)
        .ues(36)
        .eps(0.1)
        .seed(13)
        .mobility(1.0, 4.0)
        .churn(1.0, 0.05)
        .epoch_rounds(1)
        .max_epochs(24);
    let identity = plain
        .clone()
        .device_class("only", 1.0, 1.0, 1.0, 1.0)
        .outage(0.0, 0.0)
        .deadline(f64::INFINITY);
    let a = ScenarioRun::new(&plain).seed(9).run().expect("plain instance");
    let b = ScenarioRun::new(&identity).seed(9).run().expect("identity instance");
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "strict generalization broke");
    assert_eq!(a.ab_per_epoch, b.ab_per_epoch);
    assert_eq!(a.events, b.events);
    println!("identity spec reproduces the homogeneous trajectory bitwise");

    section("cross-check: warm vs cold on a shrunken hetero+outage world");
    let mut small = hetero_spec()
        .ues(4_000)
        .edges(8)
        .max_epochs(if short { 3 } else { 5 })
        .instances(if short { 1 } else { 2 })
        .shards(1);
    small.base.system.edge_bandwidth_hz = 1.0e9; // cap 1000/edge, 8k total
    let warm_small = small
        .clone()
        .resolve(ResolveMode::Warm)
        .assoc_resolve(ResolveMode::Warm);
    let cold_small = small
        .clone()
        .resolve(ResolveMode::Cold)
        .assoc_resolve(ResolveMode::Cold);
    let warm_batch = ScenarioRun::new(&warm_small).run_batch().expect("warm batch");
    let cold_batch = ScenarioRun::new(&cold_small).run_batch().expect("cold batch");
    for (w, c) in warm_batch.outcomes.iter().zip(&cold_batch.outcomes) {
        assert_eq!(w.ab_per_epoch, c.ab_per_epoch, "hetero warm diverged from cold");
        assert_eq!(w.makespan_s.to_bits(), c.makespan_s.to_bits());
        assert_eq!(w.outages, c.outages);
        assert_eq!(w.late_uploads, c.late_uploads);
    }
    println!(
        "warm == cold on {} hetero instances (outages: {:?})",
        warm_batch.outcomes.len(),
        warm_batch.outcomes.iter().map(|o| o.outages).collect::<Vec<_>>()
    );
    section("world: 50k-UE heterogeneous outage world, end to end");
    let spec = hetero_spec()
        .max_epochs(if short { 2 } else { 6 })
        .instances(if short { 1 } else { 2 });
    println!("spec: [{}]", spec.summary());
    let t0 = Instant::now();
    let batch = ScenarioRun::new(&spec).run_batch().expect("hetero batch");
    let wall = t0.elapsed().as_secs_f64();
    let report = BatchReport::from_outcomes(&batch.outcomes);
    let ips = batch.outcomes.len() as f64 / wall;
    println!(
        "{} instances in {wall:.2}s on {} shards ({ips:.2} instances/s)",
        batch.outcomes.len(),
        batch.shards
    );
    println!(
        "participation mean {:.4}  outages mean {:.1}  late mean {:.0}  epochs mean {:.1}",
        report.participation_rate.mean,
        report.outages.mean,
        report.late_uploads.mean,
        report.epochs.mean
    );
    for o in &batch.outcomes {
        assert!(o.outages > 0, "an outage-heavy world must fail edges");
        assert!(
            o.participation_rate > 0.0 && o.participation_rate <= 1.0,
            "participation out of range: {}",
            o.participation_rate
        );
        assert!(o.makespan_s.is_finite() && o.makespan_s > 0.0);
    }
    println!("BENCH_JSON {{\"name\":\"hetero 50k world\",\"instances_per_s\":{ips:.4}}}");
    println!(
        "BENCH_JSON {{\"name\":\"hetero participation\",\"value\":{:.4}}}",
        report.participation_rate.mean
    );

    if short {
        println!("\nshort mode: BENCH_hetero.json left untouched");
        return;
    }

    section("baseline: cold association on the same 50k world (full mode only)");
    let cold50_spec = spec.clone().assoc_resolve(ResolveMode::Cold);
    let cold50 = ScenarioRun::new(&cold50_spec).run_batch().expect("cold 50k");
    for (w, c) in batch.outcomes.iter().zip(&cold50.outcomes) {
        assert_eq!(w.ab_per_epoch, c.ab_per_epoch, "50k warm diverged from cold");
        assert_eq!(w.makespan_s.to_bits(), c.makespan_s.to_bits());
        assert_eq!(w.outages, c.outages);
    }
    let warm_assoc_s: f64 = batch.outcomes.iter().map(|o| o.assoc_time_s).sum();
    let cold_assoc_s: f64 = cold50.outcomes.iter().map(|o| o.assoc_time_s).sum();
    let assoc_speedup = cold_assoc_s / warm_assoc_s.max(1e-9);
    println!(
        "assoc wall at 50k: cold {cold_assoc_s:.3}s  warm {warm_assoc_s:.3}s  \
         speedup {assoc_speedup:.1}x"
    );
    assert!(
        assoc_speedup >= 1.0,
        "acceptance: warm association must not lose to cold on the 50k outage world, \
         got {assoc_speedup:.2}x"
    );
    println!("BENCH_JSON {{\"name\":\"hetero assoc warm speedup\",\"value\":{assoc_speedup:.2}}}");
    let json = Json::obj(vec![
        ("bench", Json::str("hetero_scenario")),
        ("generated", Json::Bool(true)),
        ("command", Json::str("cargo bench --bench hetero_scenario")),
        (
            "workload",
            Json::str(
                "configs/scenario_hetero.toml: 32 edges x 50k UEs, 3 device classes, \
                 outage 0.15/0.5, deadline 8s, churn 100/0.002",
            ),
        ),
        (
            "rows",
            Json::arr(vec![
                Json::obj(vec![
                    ("name", Json::str("hetero 50k world")),
                    ("instances_per_s", Json::num(ips)),
                    ("instances", Json::num(batch.outcomes.len() as f64)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("hetero participation")),
                    ("value", Json::num(report.participation_rate.mean)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("hetero outages per instance")),
                    ("value", Json::num(report.outages.mean)),
                ]),
                Json::obj(vec![
                    ("name", Json::str("hetero assoc warm speedup")),
                    ("value", Json::num(assoc_speedup)),
                    ("target", Json::num(1.0)),
                ]),
            ]),
        ),
    ]);
    let path = "BENCH_hetero.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
