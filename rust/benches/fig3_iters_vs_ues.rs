//! Fig. 3 bench — optimal iteration counts vs the number of UEs each
//! edge server hosts (paper: "no visible trend", because the weighted
//! aggregation balances per-UE variance; each sweep point redraws the
//! UE population).

use hfl::assoc;
use hfl::delay::DelayInstance;
use hfl::metrics::Series;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};
use hfl::util::bench::{section, short_mode, Bencher};
use hfl::util::stats;

fn instance(ues_per_edge: usize, seed: u64) -> DelayInstance {
    let mut params = SystemParams::default();
    params.ue_bandwidth_hz = params.edge_bandwidth_hz / ues_per_edge.max(20) as f64;
    let topo = Topology::sample(&params, 5, 5 * ues_per_edge, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let a = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();
    DelayInstance::build(&topo, &channel, &a, 0.25)
}

fn main() {
    section("Fig. 3 — optimal iteration counts vs UEs per edge (ε = 0.25)");
    let mut series = Series::new(&["ues_per_edge", "a_star", "b_star", "rounds", "total_s"]);
    let opts = SolveOptions::default();
    let mut a_vals = Vec::new();
    let mut b_vals = Vec::new();
    // `-- --test`: CI smoke shape — a sparser sweep, same reporting.
    let sweep: &[usize] = if short_mode() {
        &[10, 50, 100]
    } else {
        &[10, 20, 30, 40, 50, 60, 70, 80, 90, 100]
    };
    for &upe in sweep {
        let inst = instance(upe, 42 + upe as u64);
        let sol = solve_integer(&inst, &opts);
        a_vals.push(sol.a as f64);
        b_vals.push(sol.b as f64);
        series.push(vec![
            upe as f64,
            sol.a as f64,
            sol.b as f64,
            sol.rounds as f64,
            sol.objective,
        ]);
    }
    series.print("series (paper Fig. 3)");

    // Paper claim: no correlation with the UE count. Report the relative
    // spread — small vs the ε-sweep's monotone swings.
    println!(
        "shape: a in [{:.0}, {:.0}] (cv {:.2}), b in [{:.0}, {:.0}] (cv {:.2}) — \
         no monotone trend expected",
        a_vals.iter().cloned().fold(f64::INFINITY, f64::min),
        a_vals.iter().cloned().fold(0.0, f64::max),
        stats::std(&a_vals) / stats::mean(&a_vals),
        b_vals.iter().cloned().fold(f64::INFINITY, f64::min),
        b_vals.iter().cloned().fold(0.0, f64::max),
        stats::std(&b_vals) / stats::mean(&b_vals),
    );

    section("scaling: solver cost vs instance size");
    let b = Bencher::quick();
    let scaling: &[usize] = if short_mode() { &[10, 100] } else { &[10, 50, 100] };
    for &upe in scaling {
        let inst = instance(upe, 7);
        b.run(&format!("solve_integer ({upe} UEs/edge)"), || {
            solve_integer(&inst, &opts)
        });
    }
}
