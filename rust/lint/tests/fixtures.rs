//! Fixture-based rule tests + the self-check over the real `rust/src`.
//!
//! Each rule R1–R6 has a `*_fail.rs` fixture proving it fires and a
//! `*_pass.rs` fixture proving the sanctioned replacement (plus a
//! reasoned allow-marker) stays quiet. The marker fixtures pin the
//! hygiene half: reason-less, unknown-rule and unused markers are
//! findings. Finally, `real_tree_is_clean` runs the full pass over the
//! actual hfl sources — the same invocation CI gates on.

use std::path::Path;

use hfl_lint::{check_source, check_tree, Finding, Rule, Stats};

fn check_fixture(name: &str) -> Vec<Finding> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(name);
    let source = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {name}: {e}"));
    // A neutral relative path: no per-rule path allowlist matches it.
    check_source(&format!("fixtures/{name}"), &source, &mut Stats::default())
}

fn rules_of(findings: &[Finding]) -> Vec<Rule> {
    findings.iter().map(|f| f.rule).collect()
}

#[test]
fn r1_fires_on_hash_collections_and_passes_on_btree() {
    let fail = check_fixture("r1_fail.rs");
    assert!(!fail.is_empty(), "r1_fail must trip R1");
    assert!(rules_of(&fail).iter().all(|&r| r == Rule::R1), "{fail:?}");
    assert!(check_fixture("r1_pass.rs").is_empty());
}

#[test]
fn r2_fires_on_partial_cmp_and_passes_on_total_cmp() {
    let fail = check_fixture("r2_fail.rs");
    assert_eq!(rules_of(&fail), vec![Rule::R2, Rule::R2], "{fail:?}");
    // The pass fixture contains a `fn partial_cmp` trait impl — the
    // sanctioned delegate-to-Ord shape must not count as a call.
    assert!(check_fixture("r2_pass.rs").is_empty());
}

#[test]
fn r3_fires_on_wall_clock_and_passes_on_simulated_time() {
    let fail = check_fixture("r3_fail.rs");
    assert!(fail.len() >= 2, "both clock types must trip R3: {fail:?}");
    assert!(rules_of(&fail).iter().all(|&r| r == Rule::R3));
    // The pass fixture holds a *reasoned* wall-span marker.
    assert!(check_fixture("r3_pass.rs").is_empty());
}

#[test]
fn r4_fires_on_raw_rng_and_passes_on_forks() {
    let fail = check_fixture("r4_fail.rs");
    assert_eq!(rules_of(&fail), vec![Rule::R4, Rule::R4], "{fail:?}");
    assert!(check_fixture("r4_pass.rs").is_empty());
}

#[test]
fn r5_fires_on_prints_and_reasonless_stdout_ok() {
    let fail = check_fixture("r5_fail.rs");
    let rules = rules_of(&fail);
    assert_eq!(rules.iter().filter(|&&r| r == Rule::R5).count(), 3, "{fail:?}");
    // The bare `// stdout-ok` is additionally a marker-hygiene finding.
    assert_eq!(rules.iter().filter(|&&r| r == Rule::Marker).count(), 1, "{fail:?}");
    assert!(check_fixture("r5_pass.rs").is_empty());
}

#[test]
fn r6_fires_on_arrival_order_folds_and_passes_on_slotting() {
    let fail = check_fixture("r6_fail.rs");
    assert!(fail.len() >= 2, "recv call + receiver fold: {fail:?}");
    assert!(rules_of(&fail).iter().all(|&r| r == Rule::R6));
    assert!(check_fixture("r6_pass.rs").is_empty());
}

#[test]
fn marker_without_reason_fails_and_does_not_silence() {
    let fail = check_fixture("marker_no_reason_fail.rs");
    let rules = rules_of(&fail);
    assert!(rules.contains(&Rule::R2), "the violation survives: {fail:?}");
    assert!(rules.contains(&Rule::Marker), "the bad marker is flagged: {fail:?}");
}

#[test]
fn unused_and_unknown_markers_fail() {
    let fail = check_fixture("marker_unused_fail.rs");
    assert_eq!(rules_of(&fail), vec![Rule::Marker, Rule::Marker], "{fail:?}");
}

#[test]
fn path_allowlists_scope_the_rules() {
    let mut stats = Stats::default();
    // Wall clock is the metrics module's purpose.
    let clock = "pub fn t() -> std::time::Instant { std::time::Instant::now() }\n";
    assert!(check_source("metrics/mod.rs", clock, &mut stats).is_empty());
    assert!(check_source("util/bench.rs", clock, &mut stats).is_empty());
    assert!(!check_source("sim/events.rs", clock, &mut stats).is_empty());
    // RNG construction belongs to util/rng.rs.
    let rng = "pub fn mk(seed: u64) -> Rng { Rng::new(seed) }\n";
    assert!(check_source("util/rng.rs", rng, &mut stats).is_empty());
    assert!(!check_source("assoc/mod.rs", rng, &mut stats).is_empty());
    // The CLI surface may print; library modules may not.
    let print = "pub fn p() { println!(\"x\"); }\n";
    assert!(check_source("main.rs", print, &mut stats).is_empty());
    assert!(!check_source("fl/mod.rs", print, &mut stats).is_empty());
    // The fork/join executor owns worker coordination.
    let recv = "pub fn r(rx: &Rx) { rx.recv().unwrap(); }\n";
    assert!(check_source("util/par.rs", recv, &mut stats).is_empty());
    assert!(!check_source("scenario/runner.rs", recv, &mut stats).is_empty());
}

#[test]
fn cfg_test_modules_are_exempt() {
    let mut stats = Stats::default();
    let src = "\
pub fn lib_code(x: f64) -> f64 {
    x + 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_a_throwaway_rng() {
        let mut rng = Rng::new(42);
        let xs = vec![(1u64, rng.f64())];
        let _ = xs
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        println!(\"debug {xs:?}\");
    }
}
";
    assert!(
        check_source("delay/mod.rs", src, &mut stats).is_empty(),
        "rules must not fire inside #[cfg(test)] items"
    );
    // The same constructs outside the gated module do fire.
    let bare = "pub fn f() { let mut rng = Rng::new(42); }\n";
    assert!(!check_source("delay/mod.rs", bare, &mut stats).is_empty());
}

#[test]
fn marker_reason_survives_parens_and_attaches_above() {
    let mut stats = Stats::default();
    let src = "\
// hfl-lint: allow(R4, stream root (forked per instance) of the batch)
pub fn mk(seed: u64) -> Rng {
    Rng::new(seed)
}
";
    // The marker sits one line above a 2-line-down violation: attach is
    // the *next code line* (the fn header), not the Rng::new line — so
    // this marker is unused and the violation survives. Both findings.
    let findings = check_source("scenario/mod.rs", src, &mut stats);
    let rules = rules_of(&findings);
    assert!(rules.contains(&Rule::R4) && rules.contains(&Rule::Marker), "{findings:?}");

    // Directly above (or on) the violating line, it silences it.
    let good = "\
pub fn mk(seed: u64) -> Rng {
    // hfl-lint: allow(R4, stream root (forked per instance) of the batch)
    Rng::new(seed)
}
";
    assert!(check_source("scenario/mod.rs", good, &mut stats).is_empty());
    assert!(stats.allows_used >= 1);
}

/// The invocation CI gates on: the real `rust/src` tree is clean. This
/// is the tentpole acceptance check — every finding in the tree has
/// either been fixed or carries a reasoned allow-marker.
#[test]
fn real_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
    let (findings, stats) = check_tree(&src).expect("scan rust/src");
    assert!(
        findings.is_empty(),
        "hfl-lint findings in rust/src:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(stats.files > 40, "scanned {} files — wrong root?", stats.files);
    assert!(stats.allows_used > 20, "expected the sweep's markers to be live");
}
