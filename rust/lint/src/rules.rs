//! The determinism contract as named, individually-testable rules.
//!
//! Every rule is a source-level check over scrubbed code lines (see
//! [`crate::lexer`]): the engines' warm==cold / shard-count-independent /
//! bitwise-reproducible guarantees are only as strong as the absence of
//! these constructs from semantic paths, so the contract is enforced
//! before review, not after a property test happens to catch the drift.
//!
//! | rule | forbids | required instead |
//! |------|---------|------------------|
//! | R1   | `HashMap`/`HashSet` (iteration order is seed-random) | `BTreeMap`/`BTreeSet`, flat `Vec` state |
//! | R2   | `partial_cmp` on floats (not total under NaN) | `total_cmp`, the crate's `OrdF64` |
//! | R3   | `Instant::now`/`SystemTime` (wall clock in semantics) | allowlisted wall-span sites only |
//! | R4   | ad-hoc `Rng::new`/reseeding (stream drift) | forks of a documented seed stream |
//! | R5   | `println!`-family in library code | `main.rs`, reasoned `stdout-ok` markers |
//! | R6   | channel drains folding in arrival order | index-slotted results (`util/par`) |
//!
//! A violation is silenced by an inline marker that **must carry a
//! reason**: `// hfl-lint: allow(R3, trace wall spans measure real time)`,
//! placed on the offending line or as a standalone comment directly above
//! it. Reason-less markers, markers naming unknown rules, and markers that
//! silence nothing are themselves violations — the allowlist stays
//! self-auditing. Code under `#[cfg(test)]` is exempt from every rule
//! (tests legitimately seed throwaway RNGs and assert on comparator
//! behavior); `rust/tests/` integration tests are outside the scanned
//! tree for the same reason.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::lexer::{is_ident, scrub, Line};

/// The named rules of the determinism contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    /// Meta-rule: a malformed, reason-less, or unused allow-marker.
    Marker,
}

impl Rule {
    pub const CHECKED: [Rule; 6] = [Rule::R1, Rule::R2, Rule::R3, Rule::R4, Rule::R5, Rule::R6];

    pub fn id(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::R6 => "R6",
            Rule::Marker => "marker",
        }
    }

    pub fn title(self) -> &'static str {
        match self {
            Rule::R1 => "no hash-ordered collections",
            Rule::R2 => "no partial_cmp on floats",
            Rule::R3 => "no wall clock outside allowlisted spans",
            Rule::R4 => "no raw RNG construction outside fork points",
            Rule::R5 => "no stdout/stderr prints in library code",
            Rule::R6 => "no arrival-order channel folds",
            Rule::Marker => "allow-marker hygiene",
        }
    }

    fn from_id(id: &str) -> Option<Rule> {
        match id {
            "R1" => Some(Rule::R1),
            "R2" => Some(Rule::R2),
            "R3" => Some(Rule::R3),
            "R4" => Some(Rule::R4),
            "R5" => Some(Rule::R5),
            "R6" => Some(Rule::R6),
            _ => None,
        }
    }

    /// Paths (relative to the scan root) where the rule does not apply at
    /// all — the handful of modules whose *purpose* is the forbidden
    /// construct. Everything else must use an inline marker, so the
    /// exemption is visible at the use site.
    fn path_allowlisted(self, rel: &str) -> bool {
        match self {
            // metrics::Timer and the bench harness exist to measure wall
            // time; their output feeds reports, never semantics.
            Rule::R3 => rel.starts_with("metrics/") || rel == "util/bench.rs",
            // The generator's own module: constructors + fork live here.
            Rule::R4 => rel == "util/rng.rs",
            // The CLI display surface.
            Rule::R5 => rel == "main.rs",
            // The deterministic fork/join executor is the one place
            // allowed to coordinate workers (it slots results by index).
            Rule::R6 => rel == "util/par.rs",
            _ => false,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {} ({})",
            self.file.display(),
            self.line,
            self.rule.id(),
            self.message,
            self.rule.title()
        )
    }
}

/// Scan statistics for the summary line.
#[derive(Debug, Default, Clone, Copy)]
pub struct Stats {
    pub files: usize,
    pub lines: usize,
    pub allows_used: usize,
}

#[derive(Debug)]
struct Marker {
    rule: Option<Rule>,
    reason_ok: bool,
    /// Line the marker silences (1-based).
    attach: usize,
    /// Line the marker text lives on (1-based).
    at: usize,
    used: bool,
    legacy_stdout_ok: bool,
}

/// Check one file's source text. `rel` is the path relative to the scan
/// root (`rust/src`), used for the per-rule path allowlists and reported
/// in findings.
pub fn check_source(rel: &str, source: &str, stats: &mut Stats) -> Vec<Finding> {
    let lines = scrub(source);
    let skip = test_regions(&lines);
    let mut markers = collect_markers(&lines, &skip);
    let receivers = channel_receivers(&lines);
    let mut findings = Vec::new();

    for (idx, line) in lines.iter().enumerate() {
        if skip[idx] {
            continue;
        }
        let lineno = idx + 1;
        for rule in Rule::CHECKED {
            if rule.path_allowlisted(rel) {
                continue;
            }
            let Some(message) = rule_hit(rule, &line.code, &receivers) else {
                continue;
            };
            if consume_marker(&mut markers, rule, lineno) {
                stats.allows_used += 1;
                continue;
            }
            findings.push(Finding {
                file: PathBuf::from(rel),
                line: lineno,
                rule,
                message,
            });
        }
    }

    // Marker hygiene: malformed or unused markers are violations too.
    for m in &markers {
        let message = match (m.rule, m.reason_ok, m.used) {
            (None, _, _) if m.legacy_stdout_ok => {
                "legacy `stdout-ok` marker requires a reason (`// stdout-ok: <why>`)".to_string()
            }
            (None, _, _) => "allow-marker names an unknown rule (expected R1..R6)".to_string(),
            (Some(r), false, _) => format!(
                "allow({}) marker requires a reason: `// hfl-lint: allow({}, <why>)`",
                r.id(),
                r.id()
            ),
            (Some(r), true, false) => format!(
                "unused allow({}) marker: the line it covers does not trip {}",
                r.id(),
                r.id()
            ),
            (Some(_), true, true) => continue,
        };
        findings.push(Finding {
            file: PathBuf::from(rel),
            line: m.at,
            rule: Rule::Marker,
            message,
        });
    }

    stats.files += 1;
    stats.lines += lines.len();
    findings.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(&b.rule)));
    findings
}

/// Walk `src_root` and check every `.rs` file. File order is sorted so
/// output is deterministic (the lint practices what it preaches).
pub fn check_tree(src_root: &Path) -> io::Result<(Vec<Finding>, Stats)> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    files.sort();
    let mut stats = Stats::default();
    let mut findings = Vec::new();
    for file in &files {
        let source = fs::read_to_string(file)?;
        let rel = file
            .strip_prefix(src_root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(check_source(&rel, &source, &mut stats));
    }
    Ok((findings, stats))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Does `rule` fire on this scrubbed code line?
fn rule_hit(rule: Rule, code: &str, receivers: &[String]) -> Option<String> {
    match rule {
        Rule::R1 => {
            for tok in ["HashMap", "HashSet"] {
                if has_token(code, tok) {
                    return Some(format!(
                        "`{tok}` has seed-randomized iteration order; use BTreeMap/BTreeSet \
                         or flat Vec state"
                    ));
                }
            }
            None
        }
        Rule::R2 => {
            // Implementing `PartialOrd` by delegating to a total `cmp` is
            // the sanctioned pattern — only *calls* are suspect.
            if has_token(code, "partial_cmp") && !code.contains("fn partial_cmp") {
                Some(
                    "`partial_cmp` is not a total order under NaN; use `total_cmp` \
                     or the crate's OrdF64"
                        .to_string(),
                )
            } else {
                None
            }
        }
        Rule::R3 => {
            if code.contains("Instant::now") {
                Some("`Instant::now` reads the wall clock".to_string())
            } else if has_token(code, "SystemTime") {
                Some("`SystemTime` reads the wall clock".to_string())
            } else {
                None
            }
        }
        Rule::R4 => {
            if code.contains("Rng::new") {
                return Some(
                    "raw `Rng::new` outside util/rng.rs: derive streams by forking a \
                     documented seed stream"
                        .to_string(),
                );
            }
            for tok in ["thread_rng", "from_entropy", "seed_from_u64", "StdRng", "SmallRng"] {
                if has_token(code, tok) {
                    return Some(format!("`{tok}`: nondeterministic or ad-hoc RNG source"));
                }
            }
            None
        }
        Rule::R5 => {
            for tok in ["println!", "eprintln!", "print!", "eprint!", "dbg!"] {
                if has_macro(code, tok) {
                    return Some(format!("`{tok}` in library code"));
                }
            }
            None
        }
        Rule::R6 => {
            for tok in [".recv(", ".try_recv(", ".recv_timeout("] {
                if code.contains(tok) {
                    return Some(format!(
                        "`{}` consumes results in arrival order",
                        &tok[1..tok.len() - 1]
                    ));
                }
            }
            for rx in receivers {
                let for_loop = code.contains("for ")
                    && (has_phrase(code, &format!("in {rx}"))
                        || has_phrase(code, &format!("in &{rx}")));
                let iter_call = code.contains(&format!("{rx}.iter()"))
                    || code.contains(&format!("{rx}.try_iter()"))
                    || code.contains(&format!("{rx}.into_iter()"));
                if for_loop || iter_call {
                    return Some(format!(
                        "iterating channel receiver `{rx}` folds in arrival order"
                    ));
                }
            }
            None
        }
        Rule::Marker => None,
    }
}

/// Word-boundary token search (boundaries are non-identifier chars).
fn has_token(code: &str, tok: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident(code[..at].chars().next_back().unwrap());
        let after_ok = code[at + tok.len()..]
            .chars()
            .next()
            .map(|c| !is_ident(c))
            .unwrap_or(true);
        if before_ok && after_ok {
            return true;
        }
        start = at + tok.len();
    }
    false
}

/// Like `has_token` for `name!` macros (the `!` is part of the token, so
/// only the leading boundary needs checking).
fn has_macro(code: &str, tok: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let at = start + pos;
        if at == 0 || !is_ident(code[..at].chars().next_back().unwrap()) {
            return true;
        }
        start = at + tok.len();
    }
    false
}

/// Phrase search where the char *after* the phrase must not extend an
/// identifier (`in rx` must not match `in rxs`).
fn has_phrase(code: &str, phrase: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(phrase) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident(code[..at].chars().next_back().unwrap());
        let after_ok = code[at + phrase.len()..]
            .chars()
            .next()
            .map(|c| !is_ident(c))
            .unwrap_or(true);
        if before_ok && after_ok {
            return true;
        }
        start = at + phrase.len();
    }
    false
}

/// Identifiers bound as the receiver half of `let (tx, rx) = …channel…`.
fn channel_receivers(lines: &[Line]) -> Vec<String> {
    let mut out = Vec::new();
    for line in lines {
        let code = &line.code;
        let makes_channel = code.contains("mpsc::channel")
            || code.contains("channel::<")
            || code.contains("sync_channel");
        if !makes_channel {
            continue;
        }
        let Some(let_at) = code.find("let (") else {
            continue;
        };
        let inner = &code[let_at + 5..];
        let Some(close) = inner.find(')') else {
            continue;
        };
        if let Some(last) = inner[..close].split(',').next_back() {
            let name = last.trim().trim_start_matches("mut ").trim();
            if !name.is_empty() && name.chars().all(is_ident) {
                out.push(name.to_string());
            }
        }
    }
    out
}

/// Mark every line inside a `#[cfg(test)]`-gated item as skipped.
fn test_regions(lines: &[Line]) -> Vec<bool> {
    let mut skip = vec![false; lines.len()];
    let mut depth: i64 = 0;
    // Some(depth at which the gated item's braces opened).
    let mut in_skip: Option<i64> = None;
    let mut pending = false;
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if in_skip.is_none() && code.contains("#[cfg(test)]") {
            pending = true;
        }
        if pending && in_skip.is_none() {
            skip[idx] = true; // the attribute + following item header
            if code.contains('{') {
                in_skip = Some(depth);
                pending = false;
            } else if code.trim_end().ends_with(';') {
                // `#[cfg(test)] use …;` — a single-line gated item.
                pending = false;
            }
        }
        let entry_depth = depth;
        for c in code.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        if let Some(open_depth) = in_skip {
            skip[idx] = true;
            // Closed back to (or past) the depth the item opened at —
            // but only after the braces actually opened on this or an
            // earlier line.
            let opened = entry_depth > open_depth || code.contains('{');
            if depth <= open_depth && opened {
                in_skip = None;
            }
        }
    }
    skip
}

/// Parse `hfl-lint: allow(RULE, reason)` and legacy `stdout-ok[: reason]`
/// markers from comment text. A marker on a comment-only line attaches to
/// the next line that carries code.
fn collect_markers(lines: &[Line], skip: &[bool]) -> Vec<Marker> {
    let mut markers = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if skip[idx] {
            continue;
        }
        let lineno = idx + 1;
        let has_code = !line.code.trim().is_empty();
        let attach = if has_code {
            lineno
        } else {
            // Next line with code (markers above an item attach to it).
            (idx + 1..lines.len())
                .find(|&j| !lines[j].code.trim().is_empty())
                .map(|j| j + 1)
                .unwrap_or(lineno)
        };
        let comment = &line.comment;
        let mut start = 0;
        while let Some(pos) = comment[start..].find("hfl-lint:") {
            let rest = &comment[start + pos + "hfl-lint:".len()..];
            let rest = rest.trim_start();
            if let Some(args) = rest.strip_prefix("allow(") {
                let body = match args.rfind(')') {
                    Some(end) => &args[..end],
                    None => args,
                };
                let (id, reason) = match body.split_once(',') {
                    Some((id, reason)) => (id.trim(), reason.trim()),
                    None => (body.trim(), ""),
                };
                markers.push(Marker {
                    rule: Rule::from_id(id),
                    reason_ok: !reason.is_empty(),
                    attach,
                    at: lineno,
                    used: false,
                    legacy_stdout_ok: false,
                });
            } else {
                // `hfl-lint:` with anything but allow(...) — treat as an
                // unknown-rule marker so typos fail loudly.
                markers.push(Marker {
                    rule: None,
                    reason_ok: false,
                    attach,
                    at: lineno,
                    used: false,
                    legacy_stdout_ok: false,
                });
            }
            start += pos + "hfl-lint:".len();
        }
        // Legacy stdout hygiene marker (absorbed from the old CI grep
        // gate): `stdout-ok: reason` == allow(R5, reason); a bare
        // `stdout-ok` is a reason-less marker and fails. The marker is
        // same-line by definition, so it only counts on lines whose code
        // actually prints — prose that merely *mentions* stdout-ok (docs,
        // rule descriptions) is not a marker.
        let prints = ["println!", "eprintln!", "print!", "eprint!", "dbg!"]
            .iter()
            .any(|t| has_macro(&line.code, t));
        if !prints {
            continue;
        }
        if let Some(pos) = comment.find("stdout-ok") {
            let rest = &comment[pos + "stdout-ok".len()..];
            let reason_ok = rest
                .strip_prefix(':')
                .map(|r| !r.trim().is_empty())
                .unwrap_or(false);
            if reason_ok {
                markers.push(Marker {
                    rule: Some(Rule::R5),
                    reason_ok: true,
                    attach,
                    at: lineno,
                    used: false,
                    legacy_stdout_ok: true,
                });
            } else {
                markers.push(Marker {
                    rule: None,
                    reason_ok: false,
                    attach,
                    at: lineno,
                    used: false,
                    legacy_stdout_ok: true,
                });
            }
        }
    }
    markers
}

/// Consume (mark used) a marker for `rule` attached to `lineno`.
fn consume_marker(markers: &mut [Marker], rule: Rule, lineno: usize) -> bool {
    for m in markers.iter_mut() {
        if m.rule == Some(rule) && m.reason_ok && m.attach == lineno {
            m.used = true;
            return true;
        }
    }
    false
}
