//! CLI: `cargo run -p hfl-lint -- --check [ROOT]`.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use hfl_lint::{check_tree, Rule};

const USAGE: &str = "\
hfl-lint — determinism static-analysis pass for the hfl engines

USAGE:
    hfl-lint --check [ROOT]    scan ROOT (default: the hfl crate's src/)
    hfl-lint --list-rules      print the rules of the contract
    hfl-lint --help

Silence a finding with an inline marker that names the rule AND a reason:
    // hfl-lint: allow(R3, trace wall spans measure real time by design)
placed on the offending line or as a standalone comment directly above it.
Reason-less, unknown-rule, and unused markers are findings themselves.
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut check = false;
    let mut root: Option<PathBuf> = None;
    for arg in &args {
        match arg.as_str() {
            "--check" => check = true,
            "--list-rules" => {
                for rule in Rule::CHECKED {
                    println!("{}: {}", rule.id(), rule.title());
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other if !other.starts_with('-') && root.is_none() => {
                root = Some(PathBuf::from(other));
            }
            other => {
                eprintln!("hfl-lint: unknown argument {other:?}\n\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    if !check {
        eprintln!("hfl-lint: nothing to do (pass --check)\n\n{USAGE}");
        return ExitCode::from(2);
    }

    // Default scan root: the hfl crate's src/, located relative to this
    // crate's manifest so the tool works from any working directory.
    let root = root
        .unwrap_or_else(|| PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../src")));
    let (findings, stats) = match check_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("hfl-lint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for f in &findings {
        println!("{f}");
    }
    println!(
        "hfl-lint: {} finding(s) in {} file(s) / {} line(s), {} reasoned allow(s)",
        findings.len(),
        stats.files,
        stats.lines,
        stats.allows_used
    );
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
