//! A minimal Rust surface lexer for the determinism lint.
//!
//! `hfl-lint` does not need a real AST (and the container policy forbids
//! pulling `syn`): every rule in the determinism contract is expressible
//! over *code tokens with strings and comments removed*, plus the comment
//! text itself (for allow-markers). This module produces exactly that
//! split: for each source line, the code content with every string/char
//! literal blanked to spaces (quotes kept, so token boundaries survive)
//! and every comment blanked, next to the comment text captured
//! separately.
//!
//! The lexer understands the constructs that would otherwise cause false
//! positives: line comments (`//`, `///`, `//!`), nested block comments,
//! string literals with escapes, raw strings (`r"…"`, `r#"…"#`, any hash
//! depth), byte strings (`b"…"`, `br#"…"#`), char literals (`'x'`,
//! `'\n'`) and lifetimes (`'a`, `'static` — which are *not* char
//! literals). It does not need to understand anything else: macro bodies,
//! generics and attributes all pass through as plain code text.

/// One source line, split into scrubbed code and captured comment text.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code with string/char contents and comments blanked to spaces.
    /// Column positions match the original line.
    pub code: String,
    /// Concatenated comment text on this line (without `//` / `/*`).
    pub comment: String,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    Code,
    /// Nested block comment at the given depth.
    Block(u32),
    /// Inside `"…"`; `true` while the next char is escaped.
    Str(bool),
    /// Inside a raw string closed by `"` + this many `#`s.
    RawStr(u32),
    /// Inside `'…'`; `true` while the next char is escaped.
    Char(bool),
}

/// Split a source file into per-line scrubbed code + comment text.
pub fn scrub(source: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    for raw in source.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut line = Line::default();
        let mut i = 0usize;
        // A line comment never crosses lines; block/string states do.
        while i < chars.len() {
            let c = chars[i];
            match state {
                State::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        // Line comment: capture the rest, blank the code.
                        let text: String = chars[i + 2..].iter().collect();
                        line.comment.push_str(text.trim());
                        line.comment.push(' ');
                        for _ in i..chars.len() {
                            line.code.push(' ');
                        }
                        i = chars.len();
                        continue;
                    }
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(1);
                        line.code.push_str("  ");
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        // Plain or raw/byte string: look back over the
                        // contiguous prefix for `r`/`b`/`#`.
                        let hashes = raw_hashes_before(&chars, i);
                        state = match hashes {
                            Some(h) => State::RawStr(h),
                            None => State::Str(false),
                        };
                        line.code.push('"');
                        i += 1;
                        continue;
                    }
                    if c == '\'' {
                        if is_char_literal(&chars, i) {
                            state = State::Char(false);
                            line.code.push('\'');
                            i += 1;
                            continue;
                        }
                        // A lifetime: keep it as code text.
                        line.code.push('\'');
                        i += 1;
                        continue;
                    }
                    line.code.push(c);
                    i += 1;
                }
                State::Block(depth) => {
                    if c == '*' && chars.get(i + 1) == Some(&'/') {
                        state = if depth == 1 {
                            State::Code
                        } else {
                            State::Block(depth - 1)
                        };
                        line.code.push_str("  ");
                        line.comment.push(' ');
                        i += 2;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(depth + 1);
                        line.code.push_str("  ");
                        i += 2;
                    } else {
                        line.code.push(' ');
                        line.comment.push(c);
                        i += 1;
                    }
                }
                State::Str(escaped) => {
                    if escaped {
                        state = State::Str(false);
                    } else if c == '\\' {
                        state = State::Str(true);
                    } else if c == '"' {
                        state = State::Code;
                        line.code.push('"');
                        i += 1;
                        continue;
                    }
                    line.code.push(' ');
                    i += 1;
                }
                State::RawStr(h) => {
                    if c == '"' && closes_raw(&chars, i, h) {
                        state = State::Code;
                        line.code.push('"');
                        i += 1;
                        // Blank the trailing hashes too.
                        for _ in 0..h {
                            line.code.push(' ');
                        }
                        i += h as usize;
                        continue;
                    }
                    line.code.push(' ');
                    i += 1;
                }
                State::Char(escaped) => {
                    if escaped {
                        state = State::Char(false);
                    } else if c == '\\' {
                        state = State::Char(true);
                    } else if c == '\'' {
                        state = State::Code;
                        line.code.push('\'');
                        i += 1;
                        continue;
                    }
                    line.code.push(' ');
                    i += 1;
                }
            }
        }
        // An unterminated escape or string state simply continues on the
        // next line; reset a dangling escape flag at the newline.
        if let State::Str(true) = state {
            state = State::Str(false);
        }
        if let State::Char(true) = state {
            state = State::Char(false);
        }
        out.push(line);
    }
    out
}

/// Is `chars[i] == '"'` the opening quote of a raw/byte string? Returns
/// the hash count (0 for `r"…"`), or `None` for a plain string.
fn raw_hashes_before(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i;
    let mut hashes = 0u32;
    while j > 0 && chars[j - 1] == '#' {
        hashes += 1;
        j -= 1;
    }
    let r_at = j.checked_sub(1)?;
    if chars[r_at] != 'r' {
        return None;
    }
    // `r` must start the prefix: either line start, a `b` (byte raw
    // string), or a non-identifier char before it.
    let before_ok = match r_at.checked_sub(1) {
        None => true,
        Some(k) => chars[k] == 'b' && !prev_is_ident(chars, k) || !is_ident(chars[k]),
    };
    if before_ok {
        Some(hashes)
    } else {
        None
    }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i.checked_sub(1).map(|k| is_ident(chars[k])).unwrap_or(false)
}

/// Does the raw string with `h` hashes close at this `"`?
fn closes_raw(chars: &[char], i: usize, h: u32) -> bool {
    (1..=h as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Distinguish `'x'` / `'\n'` (char literal) from `'a` / `'static`
/// (lifetime) at an apostrophe in code position.
fn is_char_literal(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(&c2) => {
            if chars.get(i + 2) == Some(&'\'') {
                // 'x' — but '' is not a char literal and 'a'b is nonsense.
                c2 != '\''
            } else {
                false
            }
        }
        None => false,
    }
}

pub fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        scrub(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comment_split() {
        let lines = scrub("let x = 1; // HashMap here\n");
        assert!(!lines[0].code.contains("HashMap"));
        assert!(lines[0].code.contains("let x = 1;"));
        assert!(lines[0].comment.contains("HashMap here"));
    }

    #[test]
    fn string_contents_blanked_quotes_kept() {
        let lines = code_of("let s = \"Instant::now() // not code\";\n");
        assert!(!lines[0].contains("Instant::now"));
        assert!(!lines[0].contains("//"));
        assert_eq!(lines[0].matches('"').count(), 2);
    }

    #[test]
    fn raw_strings_any_hash_depth() {
        let src = "let s = r#\"partial_cmp \"quoted\" inside\"#;\nlet t = 1;\n";
        let lines = code_of(src);
        assert!(!lines[0].contains("partial_cmp"));
        assert!(lines[1].contains("let t = 1;"));
    }

    #[test]
    fn byte_and_plain_raw_strings() {
        let lines = code_of("let b = br\"recv(\"; let r = r\"recv(\"; let done = 1;\n");
        assert!(!lines[0].contains("recv("));
        assert!(lines[0].contains("let done = 1;"));
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let src = "a /* one /* two */ still */ b\n/* open\nInstant::now\n*/ c\n";
        let lines = scrub(src);
        assert!(lines[0].code.contains('a') && lines[0].code.contains('b'));
        assert!(!lines[2].code.contains("Instant"));
        assert!(lines[2].comment.contains("Instant::now"));
        assert!(lines[3].code.contains('c'));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let lines = code_of("fn f<'a>(x: &'a str) { let c = '\"'; let d = 'x'; }\n");
        // The quoted chars are blanked; the lifetime text stays.
        assert!(lines[0].contains("<'a>"));
        assert!(lines[0].contains("&'a str"));
        assert_eq!(lines[0].matches('"').count(), 0);
    }

    #[test]
    fn escaped_quote_in_string() {
        let lines = code_of("let s = \"a\\\"b\"; let after = 1;\n");
        assert!(lines[0].contains("let after = 1;"));
    }

    #[test]
    fn multiline_string_blanks_every_line() {
        let lines = code_of("let s = \"line one\nHashMap::new()\nend\"; let z = 2;\n");
        assert!(!lines[1].contains("HashMap"));
        assert!(lines[2].contains("let z = 2;"));
    }

    #[test]
    fn columns_preserved() {
        let lines = scrub("abc \"xy\" def // tail\n");
        // Blanking is space-for-char: positions of `def` are unchanged.
        assert_eq!(lines[0].code.find("def"), "abc \"xy\" def".find("def"));
    }
}
