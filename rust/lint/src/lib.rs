//! `hfl-lint` — the determinism contract of the `hfl` engines as a
//! machine-checked static-analysis pass.
//!
//! The simulator's headline guarantees (warm == cold resolves,
//! shard-count-independent batches, bitwise-reproducible epochs) were
//! enforced only by property tests *after* a regression landed. This
//! crate encodes the source-level discipline those guarantees rest on as
//! named rules R1–R6 (see [`rules::Rule`]) and runs them over
//! `rust/src/**` in CI (`cargo run -p hfl-lint -- --check`), next to the
//! dynamic half of the same contract: Miri on the `util::rng` /
//! `util::stats` unit tests and ThreadSanitizer on `tests/parallel.rs`.
//!
//! Zero dependencies by design: the repo builds fully offline, so the
//! pass is a purpose-built lexer + token scan (`lexer`), not a `syn`
//! AST — every rule here is expressible over comment/string-scrubbed
//! code lines, and the fixtures in `fixtures/` pin each rule's firing
//! and non-firing shapes.

pub mod lexer;
pub mod rules;

pub use rules::{check_source, check_tree, Finding, Rule, Stats};
