// Marker hygiene must fire twice: an allow-marker on a clean line is
// dead weight (left behind after a refactor), and a marker naming an
// unknown rule is a typo that would otherwise silence nothing forever.
pub fn fine(v: &mut Vec<f64>) {
    // hfl-lint: allow(R2, this sort was rewritten to total_cmp long ago)
    v.sort_by(|a, b| b.total_cmp(a));
}

pub fn typoed(v: &mut Vec<f64>) {
    // hfl-lint: allow(R9, no such rule)
    v.sort_by(|a, b| a.total_cmp(b));
}
