// R5 must stay quiet: a reasoned legacy `stdout-ok` marker (absorbed
// from the old CI grep gate) and a reasoned hfl-lint marker both work.
pub fn show(x: f64) {
    println!("value = {x}"); // stdout-ok: this is the display surface
}

pub fn show_more(x: f64) {
    // hfl-lint: allow(R5, bench harness table output)
    println!("row = {x}");
    let _not_a_macro = "println! inside a string";
}
