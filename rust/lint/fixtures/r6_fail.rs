// R6 must fire: folding channel results in arrival order — the classic
// way a parallel reduction stops being bitwise-reproducible.
use std::sync::mpsc;

pub fn sum_of_workers(parts: Vec<Vec<f64>>) -> f64 {
    let (tx, rx) = mpsc::channel::<f64>();
    std::thread::scope(|scope| {
        for part in parts {
            let tx = tx.clone();
            scope.spawn(move || tx.send(part.iter().sum::<f64>()).unwrap());
        }
    });
    drop(tx);
    let mut total = 0.0;
    for partial in rx {
        total += partial; // float addition is not associative
    }
    total
}

pub fn first_done(rx: &mpsc::Receiver<u64>) -> u64 {
    rx.recv().unwrap()
}
