// R4 must fire: ad-hoc RNG construction mid-engine. Every such site is a
// stream the seed-stability tests cannot see until it drifts.
pub fn noisy_scores(n: usize, magic: u64) -> Vec<f64> {
    let mut rng = crate::util::Rng::new(magic ^ 0xABCD);
    (0..n).map(|_| rng.f64()).collect()
}

pub fn entropy_seeded() -> u64 {
    // Idiomatic `rand` constructions are equally banned.
    let rng = thread_rng();
    rng.gen()
}
