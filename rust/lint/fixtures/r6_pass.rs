// R6 must stay quiet: results slotted by input index (the util/par
// pattern) involve no channel at all, and a genuinely order-insensitive
// drain carries a reasoned marker.
use std::sync::mpsc;

pub fn sum_in_index_order(parts: Vec<Vec<f64>>) -> f64 {
    let partials: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = parts
            .into_iter()
            .map(|p| scope.spawn(move || p.iter().sum::<f64>()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    partials.iter().sum()
}

pub fn drain_slotted(n: usize, rx: mpsc::Receiver<(usize, f64)>) -> Vec<f64> {
    let mut slots = vec![0.0; n];
    loop {
        // hfl-lint: allow(R6, results are slotted by index; arrival order never reaches the fold)
        let Ok((i, v)) = rx.recv() else { break };
        slots[i] = v;
    }
    slots
}
