// R3 must fire: wall-clock reads in unmarked library code.
use std::time::{Instant, SystemTime};

pub fn jitter_seed() -> u64 {
    // A classic determinism bug: seeding anything from the clock.
    SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}
