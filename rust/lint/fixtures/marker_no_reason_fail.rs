// Marker hygiene must fire: an allow-marker without a reason silences
// nothing and is itself a finding (the allowlist stays self-auditing).
pub fn sort_desc(v: &mut Vec<f64>) {
    // hfl-lint: allow(R2)
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
}
