// R3 must stay quiet: simulated time is data, not a clock read, and a
// genuine wall-span site carries a reasoned marker.
pub fn advance(now_s: f64, dt_s: f64) -> f64 {
    now_s + dt_s
}

pub fn traced<T>(f: impl FnOnce() -> T) -> (T, f64) {
    // hfl-lint: allow(R3, wall span feeds the trace sink, never semantics)
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}
