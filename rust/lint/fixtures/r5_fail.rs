// R5 must fire: unmarked prints in library code, including the legacy
// `stdout-ok` marker *without* a reason (reason-less markers fail too).
pub fn report(x: f64) {
    println!("value = {x}");
    eprintln!("warning: {x}");
}

pub fn legacy_marked(x: f64) {
    println!("value = {x}"); // stdout-ok
}
