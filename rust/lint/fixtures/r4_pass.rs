// R4 must stay quiet: streams derive from a caller-provided generator by
// forking (the documented pattern), and a genuine stream-root site
// carries a reasoned marker.
use crate::util::Rng;

pub fn noisy_scores(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut local = rng.fork(0xA550);
    (0..n).map(|_| local.f64()).collect()
}

pub fn instance_streams(seed: u64) -> (Rng, Rng) {
    let mut master = Rng::new(seed ^ 0x5EED); // hfl-lint: allow(R4, documented stream root: forks the instance seed)
    (master.fork(1), master.fork(2))
}
