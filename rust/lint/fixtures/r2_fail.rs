// R2 must fire: partial_cmp on floats is not a total order under NaN —
// both the panicky unwrap form and the silently-wrong unwrap_or form.
pub fn sort_desc(v: &mut Vec<(u64, f64)>) {
    v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
}

pub fn max_latency(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .max_by(|a, b| a.partial_cmp(b).unwrap())
        .unwrap_or(0.0)
}
