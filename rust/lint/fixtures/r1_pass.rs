// R1 must stay quiet: ordered collections, and "HashMap" only inside
// strings and comments (the lexer strips both).
use std::collections::BTreeMap;

pub fn tally(xs: &[(u64, f64)]) -> Vec<(u64, f64)> {
    let mut acc: BTreeMap<u64, f64> = BTreeMap::new();
    for &(k, v) in xs {
        *acc.entry(k).or_insert(0.0) += v;
    }
    let _doc = "a HashMap would be wrong here";
    acc.into_iter().collect()
}
