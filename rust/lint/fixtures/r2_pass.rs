// R2 must stay quiet: total_cmp calls, and a PartialOrd impl that
// delegates to a total Ord (the sanctioned `fn partial_cmp` shape).
use std::cmp::Ordering;

pub struct OrdF64(pub f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}
impl Eq for OrdF64 {}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

pub fn sort_desc(v: &mut Vec<(u64, f64)>) {
    v.sort_by(|a, b| b.1.total_cmp(&a.1));
}
