// R1 must fire: hash-ordered collections anywhere in a semantic path.
use std::collections::HashMap;

pub fn tally(xs: &[(u64, f64)]) -> Vec<(u64, f64)> {
    let mut acc: HashMap<u64, f64> = HashMap::new();
    for &(k, v) in xs {
        *acc.entry(k).or_insert(0.0) += v;
    }
    // Iteration order here is seed-random: the fold output depends on it.
    acc.into_iter().collect()
}
