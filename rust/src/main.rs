//! `hfl` — CLI launcher for the hierarchical-FL time-minimization stack.
//!
//! Subcommands:
//!   optimize   solve sub-problem I (a*, b*) for a scenario
//!   associate  compare UE-to-edge association strategies (sub-problem II)
//!   simulate   event-driven protocol latency simulation
//!   scenario   declarative scenario batches (mobility/churn/failures)
//!              over the parallel fleet runner, with a JSON report
//!   serve      resident scenario service: accept jobs over TCP (NDJSON),
//!              stream per-epoch results, graceful drain, checkpoint/resume
//!   submit     client for `serve`: ship a spec + overrides, stream results
//!   trace      aggregate a `--trace` JSONL event stream into a per-phase
//!              profile (time share, engine counters, slowest epochs)
//!   train      run hierarchical FL training via the PJRT runtime
//!   info       print scenario + artifact information
//!
//! Common options: --edges N --ues N --eps E --seed S --assoc NAME
//!                 --config FILE (TOML; CLI overrides file)
//! Layering: CLI > `HFL_*` environment > TOML > defaults.
//! Run `hfl help` for the full list.

use anyhow::{anyhow, bail, Result};

use hfl::assoc::{self, LatencyTable};
use hfl::config::{Args, AssocStrategy, Scenario};
use hfl::coordinator::run_hfl;
use hfl::data::{partition_dirichlet, partition_iid, synthetic};
use hfl::delay::DelayInstance;
use hfl::fl::{LocalSolver, TrainRun};
use hfl::metrics::Recorder;
use hfl::net::{Channel, Topology};
use hfl::opt::{solve_continuous, solve_integer, SolveOptions, SubgradientSolver};
use hfl::runtime::{find_artifacts, Engine};
use hfl::scenario::{record_batch, BatchReport, ScenarioRun, ScenarioSpec};
use hfl::serve::{protocol, resolve_request, JobRequest, ServeConfig, Server};
use hfl::sim::{simulate, SimConfig};
use hfl::util::json::Json;
use hfl::util::toml::TomlDoc;
use hfl::util::Rng;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow!("{e}"))?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "optimize" => cmd_optimize(&args),
        "associate" => cmd_associate(&args),
        "simulate" => cmd_simulate(&args),
        "scenario" => cmd_scenario(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "trace" => cmd_trace(&args),
        "train" => cmd_train(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `hfl help`)"),
    }
}

const HELP: &str = "\
hfl — Time Minimization in Hierarchical Federated Learning (reproduction)

USAGE: hfl <subcommand> [options]

SUBCOMMANDS
  optimize   solve sub-problem I: optimal local iterations a* and edge
             iterations b* (exact + Algorithm 2), print both
  associate  solve sub-problem II: compare proposed/greedy/random/exact
             UE-to-edge association latencies
  simulate   event-driven latency simulation (supports --jitter, --dropout)
  scenario   run a declarative scenario batch (TOML spec; mobility, churn,
             failures) on the parallel fleet runner; emits a JSON report
  serve      resident scenario service: accept jobs as NDJSON over TCP,
             stream per-epoch results, drain gracefully on shutdown,
             checkpoint/resume accepted jobs (--checkpoint)
  submit     submit a job to a running `hfl serve` (reads the spec file
             locally, ships its text + env/CLI overrides over the wire)
  trace      profile a scenario trace: `hfl trace run.jsonl` prints phase
             time shares, engine counters, and the slowest epochs
  train      hierarchical FL training (LeNet via PJRT artifacts)
  info       scenario + artifact summary

COMMON OPTIONS
  --config FILE        TOML scenario file (CLI overrides it)
                       precedence everywhere: CLI > HFL_* env > TOML >
                       defaults (HFL_MAX_EPOCHS=8 == --max-epochs 8)
  --edges N            number of edge servers        (default 5)
  --ues N              number of UEs                 (default 100)
  --eps E              global accuracy ε             (default 0.25)
  --seed S             RNG seed                      (default 42)
  --assoc NAME         proposed|greedy|random|exact  (default proposed)
  --gamma G, --zeta Z  loss-geometry constants

TRAIN OPTIONS
  --a N --b N          iteration counts (default: from optimizer)
  --cloud-rounds N     cloud rounds                  (default 10)
  --lr LR              local GD learning rate        (default 0.05)
  --samples-per-ue N   training samples per UE       (default 256)
  --test-samples N     held-out test set size        (default 2048)
  --dirichlet-alpha A  non-IID partition (0 = IID)
  --workers N          UE worker threads per edge (0 = auto)
  --solver NAME        gd|dane                       (default gd)
  --artifacts-dir DIR  AOT artifacts (default: ./artifacts)
  --results-dir DIR    CSV/JSON output (default: ./results)

SIMULATE OPTIONS
  --a N --b N          iteration counts (default: from optimizer)
  --jitter SIGMA       lognormal jitter on every delay (default 0)
  --dropout P          per-round UE dropout probability (default 0)
  --deadline S         per-edge-round aggregation deadline τ_dl in seconds:
                       later uploads are dropped at the barrier (default off)
  --rounds N           override the ⌈R⌉ cloud-round count

SCENARIO OPTIONS
  --spec FILE          scenario TOML (adds [failure]/[dynamics]/[optimizer]/
                       [batch] sections; see configs/scenario_mobility.toml)
  --instances N        scenario instances in the batch     (default 1)
  --shards N           worker threads (0 = one per core)   (default 0)
  --jitter SIGMA       lognormal delay jitter              (default 0)
  --dropout P          per-round UE dropout probability    (default 0)
  --deadline S         per-edge-round aggregation deadline τ_dl (s): late
                       uploads are dropped as partial participation
  --device-classes S   heterogeneous device classes, compact format
                       name:weight:f_cpu:power:cycles[,...] (default uniform)
  --outage-fail P      per-epoch edge up→down probability  (default 0)
  --outage-recover P   per-epoch edge down→up probability  (default 0)
  --speed-min M        random-waypoint min speed (m/s)     (default 0)
  --speed-max M        random-waypoint max speed (m/s)     (default 0)
  --arrival-rate L     Poisson UE arrivals per epoch       (default 0)
  --departure-prob P   per-UE departure prob per epoch     (default 0)
  --epoch-rounds N     cloud rounds per epoch (default: auto)
  --max-epochs N       epoch cap                           (default 256)
  --mode NAME          integer|continuous|subgradient      (default integer)
  --resolve NAME       per-epoch (a,b) re-solve: warm|cold (default warm)
  --assoc-resolve NAME per-epoch re-association: warm (incremental
                       MaintainedAssociation engine) | cold (default warm;
                       identical maps either way)
  --assoc-hysteresis H load-drift fraction of capacity that re-scores an
                       edge's members in warm mode (default 0.25)
  --intra-threads N    maintenance threads / engine shards inside one
                       instance (0 = one per core; results are bitwise-
                       identical for any value)          (default 1)
  --certify            attach a min-cost-flow optimality certificate to
                       each outcome (assoc_lower_bound / assoc_gap);
                       reporting only — trajectories are bitwise-identical
                       with it on or off                 (default off)
  --report FILE        JSON report path (default results/scenario_report.json)
  --trace FILE         write a JSONL trace event stream (per-epoch phase
                       spans + engine counters; content is seed-deterministic)
  --validate-only      resolve + validate all layers, print the effective
                       spec, and exit without running anything

SERVE OPTIONS
  --addr HOST:PORT     listen address              (default 127.0.0.1:4710)
  --workers N          concurrent jobs             (default 2)
  --queue-depth N      queued jobs before `busy`   (default 8)
  --checkpoint FILE    append-only job journal; pending jobs resume on
                       restart (reports land next to the journal)
  --validate-only      print the effective server config and exit
  (TOML: a [server] table with addr/workers/queue_depth/checkpoint;
   env: HFL_ADDR, HFL_WORKERS, HFL_QUEUE_DEPTH, HFL_CHECKPOINT)

SUBMIT OPTIONS
  --addr HOST:PORT     server address              (default 127.0.0.1:4710)
  --spec FILE          scenario TOML, read locally and shipped as text
  --report FILE        write the returned report JSON here
  --no-stream          skip per-epoch streaming (outcomes + report only)
  --validate-only      resolve the submission locally (same code path the
                       server uses) and exit without connecting
  --ping | --shutdown  health-check / drain-and-stop a running server
  Every other --option is forwarded as the job's CLI layer, and the
  client's HFL_* environment rides along as the job's env layer; a wire
  job is bitwise-identical to `hfl scenario` on the same layers.

TRACE OPTIONS
  hfl trace FILE       the JSONL file written by `hfl scenario --trace`
  --top N              slowest epochs to list            (default 10)
";

/// Build topology + channel + association for a scenario.
fn build_world(sc: &Scenario) -> Result<(Topology, Channel, assoc::Association)> {
    let topo = Topology::sample(&sc.system, sc.num_edges, sc.num_ues, sc.seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let cap = sc.system.edge_capacity();
    let a0 = 20.0; // provisional a for exact latency tables
    let association = match sc.assoc {
        AssocStrategy::Proposed => assoc::time_minimized(&channel, cap),
        AssocStrategy::Greedy => assoc::greedy(&channel, cap),
        AssocStrategy::Random => {
            // hfl-lint: allow(R4, throwaway baseline RNG rooted at the scenario seed)
            assoc::random(sc.num_ues, sc.num_edges, cap, &mut Rng::new(sc.seed))
        }
        AssocStrategy::Exact => {
            let table = LatencyTable::build(&topo, &channel, a0);
            assoc::solve_exact_matching(&table, cap)
        }
    }
    .map_err(|e| anyhow!("association: {e}"))?;
    Ok((topo, channel, association))
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let sc = load_scenario(args)?;
    let (topo, channel, association) = build_world(&sc)?;
    let inst = DelayInstance::build(&topo, &channel, &association, sc.eps);
    let opts = SolveOptions::default();

    let cont = solve_continuous(&inst, &opts);
    let int = solve_integer(&inst, &opts);
    let alg2 = SubgradientSolver::default().solve(&inst);

    println!(
        "scenario: {} edges, {} UEs, eps={}, gamma={}, zeta={}, assoc={}",
        sc.num_edges,
        sc.num_ues,
        sc.eps,
        sc.system.gamma,
        sc.system.zeta,
        sc.assoc.name()
    );
    println!(
        "continuous relaxation: a*={:.3} b*={:.3} J={:.4}s (R={:.2}, T={:.4}s)",
        cont.a, cont.b, cont.objective, cont.rounds, cont.round_time
    );
    println!(
        "integer (⌈R⌉, exact):  a*={} b*={} J={:.4}s (R={}, T={:.4}s)",
        int.a, int.b, int.objective, int.rounds, int.round_time
    );
    println!(
        "Algorithm 2 (paper):   a*={:.3} b*={:.3} J={:.4}s in {} iters",
        alg2.a, alg2.b, alg2.objective, alg2.iterations
    );
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    Ok(())
}

fn cmd_associate(args: &Args) -> Result<()> {
    let sc = load_scenario(args)?;
    let topo = Topology::sample(&sc.system, sc.num_edges, sc.num_ues, sc.seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let cap = sc.system.edge_capacity();

    // The paper fixes a, b from sub-problem I before association; use the
    // integer-optimal a under a provisional (greedy) association.
    let tmp_assoc = assoc::greedy(&channel, cap).map_err(|e| anyhow!(e))?;
    let inst = DelayInstance::build(&topo, &channel, &tmp_assoc, sc.eps);
    let int = solve_integer(&inst, &SolveOptions::default());
    let table = LatencyTable::build(&topo, &channel, int.a as f64);

    println!(
        "scenario: {} edges, {} UEs, eps={}, a={}, capacity={}",
        sc.num_edges, sc.num_ues, sc.eps, int.a, cap
    );
    let proposed = assoc::time_minimized(&channel, cap).map_err(|e| anyhow!(e))?;
    let greedy = assoc::greedy(&channel, cap).map_err(|e| anyhow!(e))?;
    // hfl-lint: allow(R4, throwaway baseline RNG rooted at the scenario seed)
    let random = assoc::random(sc.num_ues, sc.num_edges, cap, &mut Rng::new(sc.seed))
        .map_err(|e| anyhow!(e))?;
    let exact = assoc::solve_exact_matching(&table, cap).map_err(|e| anyhow!(e))?;
    for (name, a) in [
        ("proposed (Alg 3)", &proposed),
        ("greedy", &greedy),
        ("random", &random),
        ("exact (matching)", &exact),
    ] {
        println!("  {name:<20} max latency {:.4}s", table.max_latency(a));
    }
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let sc = load_scenario(args)?;
    let (topo, channel, association) = build_world(&sc)?;
    let inst = DelayInstance::build(&topo, &channel, &association, sc.eps);
    let int = solve_integer(&inst, &SolveOptions::default());
    let a = args.get_or("a", int.a).map_err(|e| anyhow!("{e}"))?;
    let b = args.get_or("b", int.b).map_err(|e| anyhow!("{e}"))?;
    let deadline_s = args
        .get_or("deadline", f64::INFINITY)
        .map_err(|e| anyhow!("{e}"))?;
    if deadline_s.is_nan() || deadline_s <= 0.0 {
        bail!("--deadline must be > 0 seconds (omit it to disable), got {deadline_s}");
    }
    let cfg = SimConfig {
        a,
        b,
        rounds: args.get("rounds").map_err(|e| anyhow!("{e}"))?,
        jitter_sigma: args.get_or("jitter", 0.0).map_err(|e| anyhow!("{e}"))?,
        dropout_prob: args.get_or("dropout", 0.0).map_err(|e| anyhow!("{e}"))?,
        seed: sc.seed,
        start_s: 0.0,
        deadline_s,
    };
    let res = simulate(&inst, &cfg);
    println!(
        "simulated protocol: a={a} b={b} rounds={} (assoc={})",
        res.rounds,
        sc.assoc.name()
    );
    println!("  makespan            {:.4}s", res.total_time_s);
    println!(
        "  closed-form R·T     {:.4}s",
        inst.total_time_int(a as f64, b as f64)
    );
    println!("  events              {}", res.events);
    println!("  dropped uploads     {}", res.dropped_uploads);
    println!("  UE barrier wait     {:.4}s", res.ue_barrier_wait_s);
    println!("  edge barrier wait   {:.4}s", res.edge_barrier_wait_s);
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    // Layering: CLI > HFL_* env > TOML > defaults. The paths themselves
    // layer too (--spec / HFL_SPEC, --report / HFL_REPORT); env keys must
    // be claimed before load_layered strict-checks the env layer.
    let env = Args::from_prefixed_vars(ScenarioSpec::ENV_PREFIX, std::env::vars());
    let spec_path = args.str("spec").or_else(|| env.str("spec"));
    let report_path_arg = args.str("report").or_else(|| env.str("report"));
    let validate_only = args.flag("validate-only");
    let spec = ScenarioSpec::load_layered(spec_path.as_deref().map(|p| (p, None)), &env, args)
        .map_err(|e| anyhow!("{e}"))?;
    // Long-running command: surface typo'd flags *before* the batch runs,
    // not after minutes of compute land wrong results on disk.
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    let instances = spec.batch.instances;
    println!("scenario batch: {instances} instances of [{}]", spec.summary());
    if validate_only {
        print!("{}", spec.describe());
        println!("spec OK (validate-only; nothing ran)");
        return Ok(());
    }

    let progress_every = (instances / 10).max(1);
    let mut completed = 0usize;
    fn progress(completed: &mut usize, instances: usize, every: usize) {
        *completed += 1;
        if *completed % every == 0 || *completed == instances {
            println!("  {completed}/{instances} instances done");
        }
    }
    // Traced batches collect one JSONL stream per instance (slotted by
    // index, so the concatenation is shard-count independent).
    let (batch, trace_out) = match spec.trace.file.clone() {
        Some(path) => {
            let (batch, sinks) = ScenarioRun::new(&spec)
                .on_outcome(|_, _| progress(&mut completed, instances, progress_every))
                .run_batch_traced()
                .map_err(|e| anyhow!("{e}"))?;
            (batch, Some((path, sinks)))
        }
        None => {
            let batch = ScenarioRun::new(&spec)
                .on_outcome(|_, _| progress(&mut completed, instances, progress_every))
                .run_batch()
                .map_err(|e| anyhow!("{e}"))?;
            (batch, None)
        }
    };

    let report = BatchReport::from_outcomes(&batch.outcomes);
    report.print();
    println!(
        "  {} instances in {:.2}s on {} shards ({:.1} instances/s)",
        instances,
        batch.wall_s,
        batch.shards,
        batch.instances_per_s()
    );

    // Per-instance rows (CSV + combined JSON) through the Recorder...
    let results_dir = std::path::PathBuf::from(&spec.base.results_dir);
    let mut rec = Recorder::new();
    record_batch(&batch.outcomes, &mut rec);
    rec.write_dir(&results_dir)?;
    // ...and the aggregate JSON report.
    let report_path = report_path_arg
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| results_dir.join("scenario_report.json"));
    report.write(&report_path, Some(&spec))?;
    println!(
        "wrote {}/scenario_instances.csv and {}",
        results_dir.display(),
        report_path.display()
    );

    if let Some((path, sinks)) = trace_out {
        let mut stream = String::new();
        for sink in &sinks {
            stream.push_str(sink.as_str());
        }
        let path = std::path::PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, &stream)?;
        println!(
            "wrote trace event stream to {} ({} lines; inspect with `hfl trace {}`)",
            path.display(),
            stream.lines().count(),
            path.display()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let env = Args::from_prefixed_vars(ScenarioSpec::ENV_PREFIX, std::env::vars());
    let cfg_path = args.str("config").or_else(|| env.str("config"));
    let doc = match cfg_path.as_deref() {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| anyhow!("read {p}: {e}"))?;
            Some(TomlDoc::parse(&text).map_err(|e| anyhow!("{e}"))?)
        }
        None => None,
    };
    let validate_only = args.flag("validate-only");
    let cfg = ServeConfig::load_layered(doc.as_ref(), &env, args).map_err(|e| anyhow!("{e}"))?;
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    if validate_only {
        print!("{}", cfg.describe());
        println!("server config OK (validate-only; nothing bound)");
        return Ok(());
    }
    let server = Server::bind(cfg).map_err(|e| anyhow!("{e}"))?;
    if server.resumed_jobs() > 0 {
        println!("resuming {} checkpointed job(s)", server.resumed_jobs());
    }
    println!("hfl serve listening on {}", server.addr());
    server.run().map_err(|e| anyhow!("{e}"))?;
    println!("server drained cleanly");
    Ok(())
}

fn cmd_submit(args: &Args) -> Result<()> {
    use std::io::{BufRead, Write};

    let env = Args::from_prefixed_vars(ScenarioSpec::ENV_PREFIX, std::env::vars());
    let addr = args
        .str("addr")
        .or_else(|| env.str("addr"))
        .unwrap_or_else(|| "127.0.0.1:4710".to_string());
    let spec_path = args.str("spec").or_else(|| env.str("spec"));
    let report_path = args.str("report").or_else(|| env.str("report"));
    let stream = !args.flag("no-stream");
    let ping = args.flag("ping");
    let shutdown = args.flag("shutdown");
    let validate_only = args.flag("validate-only");
    let spec_toml = match spec_path.as_deref() {
        Some(p) => Some(std::fs::read_to_string(p).map_err(|e| anyhow!("read {p}: {e}"))?),
        None => None,
    };
    // Everything not claimed above is forwarded: leftover CLI options
    // become the job's CLI layer, leftover HFL_* vars its env layer —
    // the server re-applies them through the exact batch-mode path.
    let req = JobRequest {
        spec_toml,
        env: env.to_argv_unconsumed(),
        args: args.to_argv_unconsumed(),
        stream,
    };
    if validate_only {
        // The same function the server runs on the real submission.
        let spec = resolve_request(&req).map_err(|e| anyhow!("{e}"))?;
        println!("submission resolves to [{}]", spec.summary());
        print!("{}", spec.describe());
        println!("spec OK (validate-only; nothing submitted)");
        return Ok(());
    }

    let sock = std::net::TcpStream::connect(&addr).map_err(|e| anyhow!("connect {addr}: {e}"))?;
    let mut writer = sock.try_clone().map_err(|e| anyhow!("{e}"))?;
    let line = if ping {
        protocol::ping_line()
    } else if shutdown {
        protocol::shutdown_cmd_line()
    } else {
        protocol::submit_line(&req)
    };
    writeln!(writer, "{line}")?;
    writer.flush()?;

    let reader = std::io::BufReader::new(sock);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).map_err(|e| anyhow!("bad server frame: {e}"))?;
        let num = |key: &str| v.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        let txt = |key: &str| v.get(key).and_then(Json::as_str).unwrap_or("?").to_string();
        match v.get("ev").and_then(Json::as_str).unwrap_or("?") {
            "pong" => {
                println!("pong from {addr}");
                return Ok(());
            }
            "shutdown" => {
                println!("server at {addr} is draining");
                return Ok(());
            }
            "accepted" => println!("job {} accepted by {addr}", num("job")),
            "busy" => bail!("server busy (queue depth {}); retry later", num("queue_depth")),
            "invalid" => bail!("submission rejected: {}", txt("error")),
            "rejected" => bail!("job {} dropped: {}", num("job"), txt("reason")),
            "error" => bail!("job {} failed: {}", num("job"), txt("error")),
            "epoch" => println!(
                "  instance {} epoch {}: a={} b={} clock={:.3}s participation={:.3}",
                num("instance"),
                num("epoch"),
                num("a"),
                num("b"),
                num("clock_s"),
                num("participation")
            ),
            "outcome" => {
                let makespan = v
                    .get("outcome")
                    .and_then(|o| o.get("makespan_s"))
                    .and_then(Json::as_f64)
                    .unwrap_or(f64::NAN);
                println!("  instance {} done: makespan {makespan:.4}s", num("instance"));
            }
            "done" => {
                println!(
                    "job {} done in {:.2}s on {} shards",
                    num("job"),
                    num("wall_s"),
                    num("shards")
                );
                if let (Some(path), Some(report)) = (&report_path, v.get("report")) {
                    // Byte-identical to what `hfl scenario --report` writes
                    // for the same layers: Json emission is canonical.
                    let path = std::path::PathBuf::from(path);
                    if let Some(parent) = path.parent() {
                        if !parent.as_os_str().is_empty() {
                            std::fs::create_dir_all(parent)?;
                        }
                    }
                    std::fs::write(&path, report.to_string())?;
                    println!("wrote report to {}", path.display());
                }
                return Ok(());
            }
            other => println!("  (unrecognized event '{other}')"),
        }
    }
    bail!("connection to {addr} closed before the job finished")
}

fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .pos(0)
        .or_else(|| args.str("file"))
        .ok_or_else(|| anyhow!("usage: hfl trace <FILE.jsonl> [--top N]"))?;
    let topk = args.get_or("top", 10usize).map_err(|e| anyhow!("{e}"))?;
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("cannot read trace file '{path}': {e}"))?;
    let profile = hfl::trace::TraceProfile::parse_jsonl(&text).map_err(|e| anyhow!("{e}"))?;
    println!("trace file: {path}");
    profile.print(topk);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let sc = load_scenario(args)?;
    let (topo, channel, association) = build_world(&sc)?;
    let inst = DelayInstance::build(&topo, &channel, &association, sc.eps);
    let int = solve_integer(&inst, &SolveOptions::default());
    let a = sc.train.a.unwrap_or(int.a);
    let b = sc.train.b.unwrap_or(int.b);
    let _ = &topo;

    let artifacts = find_artifacts(Some(sc.artifacts_dir.as_str()).filter(|s| !s.is_empty()))?;
    let engine = Engine::load(&artifacts)?;
    println!(
        "loaded artifacts from {} (P={} params)",
        artifacts.display(),
        engine.meta.param_count
    );

    // Data: synthetic MNIST-like corpus partitioned across UEs. Train and
    // test share the prototype seed (same task), not the sample seed.
    let gen_cfg = synthetic::SyntheticConfig::default();
    let total = sc.num_ues * sc.train.samples_per_ue;
    let corpus = synthetic::generate_split(&gen_cfg, total, sc.seed, sc.seed ^ 0xDA7A);
    let test = synthetic::generate_split(&gen_cfg, sc.train.test_samples, sc.seed, sc.seed ^ 0x7E57);
    // hfl-lint: allow(R4, partitioning stream rooted at the scenario seed)
    let mut rng = Rng::new(sc.seed ^ 0x5EED);
    let shards = if sc.train.dirichlet_alpha > 0.0 {
        partition_dirichlet(
            &corpus,
            sc.num_ues,
            sc.train.samples_per_ue,
            sc.train.dirichlet_alpha,
            &mut rng,
        )
    } else {
        partition_iid(&corpus, sc.num_ues, sc.train.samples_per_ue, &mut rng)
    }
    .map_err(|e| anyhow!(e))?;

    let solver = LocalSolver::parse(&sc.train.solver, sc.train.lr).map_err(|e| anyhow!(e))?;
    let run = TrainRun {
        a,
        b,
        cloud_rounds: sc.train.cloud_rounds,
        round_time_s: inst.round_time(a as f64, b as f64),
        eval_every: 1,
    };
    println!(
        "training: a={a} b={b} rounds={} lr={} solver={} ({} UEs x {} samples)",
        run.cloud_rounds, sc.train.lr, sc.train.solver, sc.num_ues, sc.train.samples_per_ue
    );

    let outcome = run_hfl(
        &engine,
        solver,
        shards,
        association.members(),
        &test,
        &run,
        sc.train.workers,
        sc.seed,
    )?;

    let series = outcome.curve.to_series();
    series.print("training curve (accuracy vs simulated completion time)");
    let mut rec = Recorder::new();
    rec.series.insert("train_curve".into(), series);
    rec.write_dir(std::path::Path::new(&sc.results_dir))?;
    if let Some(stem) = args.str("save-checkpoint") {
        let meta = hfl::fl::CheckpointMeta {
            param_count: outcome.final_model.len(),
            cloud_round: sc.train.cloud_rounds,
            a,
            b,
            test_acc: outcome.curve.final_acc() as f64,
        };
        let bin = hfl::fl::save_checkpoint(std::path::Path::new(&stem), &outcome.final_model, &meta)?;
        println!("checkpoint saved to {}", bin.display());
    }
    println!(
        "\nfinal accuracy {:.4} | wall {:.1}s | mean PJRT step {:.2}ms | results in {}/",
        outcome.curve.final_acc(),
        outcome.wall_s,
        engine.mean_exec_ns() / 1e6,
        sc.results_dir
    );
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let sc = load_scenario(args)?;
    println!("hfl v{}", hfl::VERSION);
    println!(
        "scenario: {} edges, {} UEs, eps={}, seed={}",
        sc.num_edges, sc.num_ues, sc.eps, sc.seed
    );
    println!(
        "system: area {}m, carrier {:.1} GHz, B={} MHz, B_n={} MHz, capacity {}",
        sc.system.area_m,
        sc.system.carrier_hz / 1e9,
        sc.system.edge_bandwidth_hz / 1e6,
        sc.system.ue_bandwidth_hz / 1e6,
        sc.system.edge_capacity()
    );
    println!(
        "learning: gamma={} zeta={} C={}",
        sc.system.gamma, sc.system.zeta, sc.system.c_const
    );
    match find_artifacts(Some(sc.artifacts_dir.as_str()).filter(|s| !s.is_empty())) {
        Ok(dir) => {
            let meta = hfl::runtime::ArtifactMeta::load(&dir)?;
            println!(
                "artifacts: {} (P={}, train_batch={}, eval_batch={})",
                dir.display(),
                meta.param_count,
                meta.train_batch,
                meta.eval_batch
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    args.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    Ok(())
}

fn load_scenario(args: &Args) -> Result<Scenario> {
    let cfg_path = args.str("config");
    Scenario::load(cfg_path.as_deref(), args).map_err(|e| anyhow!(e))
}
