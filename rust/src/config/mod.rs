//! Scenario configuration: defaults (paper §V-A) + TOML file + CLI
//! overrides, in that precedence order.

pub mod cli;

use crate::net::{BandwidthPolicy, SystemParams};
use crate::util::toml::TomlDoc;

pub use cli::Args;

/// Which association strategy a scenario uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssocStrategy {
    /// Algorithm 3 (the paper's proposal).
    Proposed,
    /// Greedy max-SNR baseline.
    Greedy,
    /// Random baseline.
    Random,
    /// Exact (threshold + matching) solver.
    Exact,
}

impl AssocStrategy {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "proposed" | "alg3" => Ok(AssocStrategy::Proposed),
            "greedy" => Ok(AssocStrategy::Greedy),
            "random" => Ok(AssocStrategy::Random),
            "exact" | "matching" => Ok(AssocStrategy::Exact),
            other => Err(format!(
                "unknown association strategy '{other}' (proposed|greedy|random|exact)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AssocStrategy::Proposed => "proposed",
            AssocStrategy::Greedy => "greedy",
            AssocStrategy::Random => "random",
            AssocStrategy::Exact => "exact",
        }
    }
}

/// Training-loop knobs for the `train` subcommand / FL engine.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Learning rate of the local GD steps.
    pub lr: f32,
    /// Cloud rounds to run (training curves use a fixed horizon).
    pub cloud_rounds: u64,
    /// Local iterations per edge round (a). `None` = take from optimizer.
    pub a: Option<u64>,
    /// Edge rounds per cloud round (b). `None` = take from optimizer.
    pub b: Option<u64>,
    /// Samples per UE for the training set.
    pub samples_per_ue: usize,
    /// Held-out test samples.
    pub test_samples: usize,
    /// Dirichlet concentration for non-IID partitioning (0 = IID).
    pub dirichlet_alpha: f64,
    /// Worker threads for parallel UE steps (0 = num_cpus).
    pub workers: usize,
    /// Local solver: "gd" (paper) or "dane" (gradient-corrected).
    pub solver: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 0.05,
            cloud_rounds: 10,
            a: None,
            b: None,
            samples_per_ue: 256,
            test_samples: 2048,
            dirichlet_alpha: 0.0,
            workers: 0,
            solver: "gd".to_string(),
        }
    }
}

/// A complete scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub system: SystemParams,
    pub num_edges: usize,
    pub num_ues: usize,
    /// Target global accuracy ε.
    pub eps: f64,
    pub seed: u64,
    pub assoc: AssocStrategy,
    pub bandwidth_policy: BandwidthPolicy,
    pub train: TrainConfig,
    /// Directory for artifacts (HLO + init params + meta).
    pub artifacts_dir: String,
    /// Directory for result CSV/JSON.
    pub results_dir: String,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            system: SystemParams::default(),
            num_edges: 5,
            num_ues: 100,
            eps: 0.25,
            seed: 42,
            assoc: AssocStrategy::Proposed,
            bandwidth_policy: BandwidthPolicy::FixedPerUe,
            train: TrainConfig::default(),
            artifacts_dir: "artifacts".to_string(),
            results_dir: "results".to_string(),
        }
    }
}

impl Scenario {
    /// Load from a TOML file then apply CLI overrides.
    pub fn load(path: Option<&str>, args: &Args) -> Result<Scenario, String> {
        let mut sc = Scenario::default();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"))?;
            let doc = TomlDoc::parse(&text).map_err(|e| e.to_string())?;
            sc.apply_toml(&doc)?;
        }
        sc.apply_args(args).map_err(|e| e.to_string())?;
        sc.validate()?;
        Ok(sc)
    }

    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        // [scenario]
        if let Some(v) = doc.i64("scenario", "num_edges") {
            self.num_edges = v as usize;
        }
        if let Some(v) = doc.i64("scenario", "num_ues") {
            self.num_ues = v as usize;
        }
        if let Some(v) = doc.f64("scenario", "eps") {
            self.eps = v;
        }
        if let Some(v) = doc.i64("scenario", "seed") {
            self.seed = v as u64;
        }
        if let Some(s) = doc.str("scenario", "assoc") {
            self.assoc = AssocStrategy::parse(s)?;
        }
        if let Some(s) = doc.str("scenario", "bandwidth_policy") {
            self.bandwidth_policy = match s {
                "equal_share" => BandwidthPolicy::EqualShare,
                "fixed" => BandwidthPolicy::FixedPerUe,
                other => return Err(format!("unknown bandwidth policy '{other}'")),
            };
        }
        // [system]
        let sys = &mut self.system;
        let set = |key: &str, field: &mut f64| {
            if let Some(v) = doc.f64("system", key) {
                *field = v;
            }
        };
        set("area_m", &mut sys.area_m);
        set("carrier_hz", &mut sys.carrier_hz);
        set("noise_dbm_per_hz", &mut sys.noise_dbm_per_hz);
        set("edge_bandwidth_hz", &mut sys.edge_bandwidth_hz);
        set("ue_bandwidth_hz", &mut sys.ue_bandwidth_hz);
        set("f_max_hz", &mut sys.f_max_hz);
        set("p_max_dbm", &mut sys.p_max_dbm);
        set("model_bits", &mut sys.model_bits);
        set("edge_model_bits", &mut sys.edge_model_bits);
        set("edge_cloud_rate_bps", &mut sys.edge_cloud_rate_bps);
        set("gamma", &mut sys.gamma);
        set("zeta", &mut sys.zeta);
        set("c_const", &mut sys.c_const);
        if let Some(model) = doc.str("system", "path_loss") {
            sys.path_loss = match model {
                "free_space" => crate::net::topology::PathLossModel::FreeSpace,
                "log_distance" => crate::net::topology::PathLossModel::LogDistance {
                    exponent: doc.f64("system", "path_loss_exponent").unwrap_or(3.0),
                    ref_dist_m: doc.f64("system", "path_loss_ref_dist_m").unwrap_or(10.0),
                },
                other => return Err(format!("unknown path_loss '{other}'")),
            };
        }
        if let Some(fad) = doc.str("system", "fading") {
            sys.fading = match fad {
                "none" => crate::net::topology::FadingModel::None,
                "rayleigh" => crate::net::topology::FadingModel::Rayleigh {
                    seed: doc.i64("system", "fading_seed").unwrap_or(0) as u64,
                },
                other => return Err(format!("unknown fading '{other}'")),
            };
        }
        // [train]
        let tr = &mut self.train;
        if let Some(v) = doc.f64("train", "lr") {
            tr.lr = v as f32;
        }
        if let Some(v) = doc.i64("train", "cloud_rounds") {
            tr.cloud_rounds = v as u64;
        }
        if let Some(v) = doc.i64("train", "a") {
            tr.a = Some(v as u64);
        }
        if let Some(v) = doc.i64("train", "b") {
            tr.b = Some(v as u64);
        }
        if let Some(v) = doc.i64("train", "samples_per_ue") {
            tr.samples_per_ue = v as usize;
        }
        if let Some(v) = doc.i64("train", "test_samples") {
            tr.test_samples = v as usize;
        }
        if let Some(v) = doc.f64("train", "dirichlet_alpha") {
            tr.dirichlet_alpha = v;
        }
        if let Some(v) = doc.i64("train", "workers") {
            tr.workers = v as usize;
        }
        if let Some(s) = doc.str("train", "solver") {
            tr.solver = s.to_string();
        }
        // [paths]
        if let Some(s) = doc.str("paths", "artifacts_dir") {
            self.artifacts_dir = s.to_string();
        }
        if let Some(s) = doc.str("paths", "results_dir") {
            self.results_dir = s.to_string();
        }
        Ok(())
    }

    pub fn apply_args(&mut self, args: &Args) -> Result<(), cli::CliError> {
        if let Some(v) = args.get::<usize>("edges")? {
            self.num_edges = v;
        }
        if let Some(v) = args.get::<usize>("ues")? {
            self.num_ues = v;
        }
        if let Some(v) = args.get::<f64>("eps")? {
            self.eps = v;
        }
        if let Some(v) = args.get::<u64>("seed")? {
            self.seed = v;
        }
        if let Some(s) = args.str("assoc") {
            self.assoc = AssocStrategy::parse(&s).map_err(cli::CliError)?;
        }
        if let Some(v) = args.get::<f32>("lr")? {
            self.train.lr = v;
        }
        if let Some(v) = args.get::<u64>("cloud-rounds")? {
            self.train.cloud_rounds = v;
        }
        if let Some(v) = args.get::<u64>("a")? {
            self.train.a = Some(v);
        }
        if let Some(v) = args.get::<u64>("b")? {
            self.train.b = Some(v);
        }
        if let Some(v) = args.get::<usize>("samples-per-ue")? {
            self.train.samples_per_ue = v;
        }
        if let Some(v) = args.get::<usize>("test-samples")? {
            self.train.test_samples = v;
        }
        if let Some(v) = args.get::<f64>("dirichlet-alpha")? {
            self.train.dirichlet_alpha = v;
        }
        if let Some(v) = args.get::<usize>("workers")? {
            self.train.workers = v;
        }
        if let Some(s) = args.str("solver") {
            self.train.solver = s;
        }
        if let Some(s) = args.str("artifacts-dir") {
            self.artifacts_dir = s;
        }
        if let Some(s) = args.str("results-dir") {
            self.results_dir = s;
        }
        if let Some(v) = args.get::<f64>("gamma")? {
            self.system.gamma = v;
        }
        if let Some(v) = args.get::<f64>("zeta")? {
            self.system.zeta = v;
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.num_edges == 0 || self.num_ues == 0 {
            return Err("need at least one edge and one UE".into());
        }
        if !(0.0 < self.eps && self.eps < 1.0) {
            return Err(format!("eps must be in (0,1), got {}", self.eps));
        }
        if self.system.gamma <= 0.0 || self.system.zeta <= 0.0 {
            return Err("gamma/zeta must be positive".into());
        }
        if self.bandwidth_policy == BandwidthPolicy::FixedPerUe
            && self.num_ues > self.num_edges * self.system.edge_capacity()
        {
            return Err(format!(
                "infeasible: {} UEs exceed {} edges x {} capacity",
                self.num_ues,
                self.num_edges,
                self.system.edge_capacity()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn defaults_are_papers() {
        let sc = Scenario::default();
        assert_eq!(sc.num_edges, 5);
        assert_eq!(sc.num_ues, 100);
        assert_eq!(sc.eps, 0.25);
        assert_eq!(sc.system.area_m, 500.0);
        sc.validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let doc = TomlDoc::parse(
            "[scenario]\nnum_edges = 7\neps = 0.1\nassoc = \"greedy\"\n[system]\ngamma = 3\n[train]\nlr = 0.1\na = 35",
        )
        .unwrap();
        let mut sc = Scenario::default();
        sc.apply_toml(&doc).unwrap();
        assert_eq!(sc.num_edges, 7);
        assert_eq!(sc.eps, 0.1);
        assert_eq!(sc.assoc, AssocStrategy::Greedy);
        assert_eq!(sc.system.gamma, 3.0);
        assert_eq!(sc.train.lr, 0.1);
        assert_eq!(sc.train.a, Some(35));
    }

    #[test]
    fn cli_overrides_beat_defaults() {
        let mut sc = Scenario::default();
        sc.apply_args(&args("--edges 9 --eps 0.05 --assoc random")).unwrap();
        assert_eq!(sc.num_edges, 9);
        assert_eq!(sc.eps, 0.05);
        assert_eq!(sc.assoc, AssocStrategy::Random);
    }

    #[test]
    fn validation_catches_infeasible() {
        let mut sc = Scenario::default();
        sc.num_ues = 10_000; // over 5 edges x 20 capacity
        assert!(sc.validate().is_err());
        sc = Scenario::default();
        sc.eps = 1.5;
        assert!(sc.validate().is_err());
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(
            AssocStrategy::parse("alg3").unwrap(),
            AssocStrategy::Proposed
        );
        assert!(AssocStrategy::parse("bogus").is_err());
    }
}
