//! Tiny CLI argument parser (offline substitute for `clap`).
//!
//! Grammar: `hfl <subcommand> [POSITIONAL]... [--key value]... [--flag]...`.
//! Values are parsed on demand (`f64`, `u64`, `usize`, `String`), unknown
//! keys and unconsumed positionals are rejected up front so typos fail
//! fast. A bare token that does not follow a `--key` is a positional
//! (e.g. the trace file in `hfl trace run.jsonl`).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
    consumed_pos: std::cell::RefCell<Vec<usize>>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let key = match tok.strip_prefix("--") {
                Some(k) => k.to_string(),
                None => {
                    args.positional.push(tok);
                    continue;
                }
            };
            if key.is_empty() {
                return Err(CliError("empty option name".into()));
            }
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    let val = it.next().unwrap();
                    args.kv.insert(key, val);
                }
                _ => args.flags.push(key),
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        let found = self.flags.iter().any(|f| f == name);
        if found {
            self.consumed.borrow_mut().push(name.to_string());
        }
        found
    }

    pub fn str(&self, name: &str) -> Option<String> {
        let v = self.kv.get(name).cloned();
        if v.is_some() {
            self.consumed.borrow_mut().push(name.to_string());
        }
        v
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("cannot parse --{name} value '{s}'"))),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        Ok(self.get(name)?.unwrap_or(default))
    }

    /// The `i`-th positional argument (0-based, after the subcommand).
    pub fn pos(&self, i: usize) -> Option<String> {
        let v = self.positional.get(i).cloned();
        if v.is_some() {
            self.consumed_pos.borrow_mut().push(i);
        }
        v
    }

    /// After all lookups, reject options nobody consumed (typo guard).
    pub fn reject_unknown(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        if !unknown.is_empty() {
            return Err(CliError(format!("unknown options: {unknown:?}")));
        }
        let consumed_pos = self.consumed_pos.borrow();
        let stray: Vec<&String> = self
            .positional
            .iter()
            .enumerate()
            .filter(|(i, _)| !consumed_pos.contains(i))
            .map(|(_, p)| p)
            .collect();
        if stray.is_empty() {
            Ok(())
        } else {
            Err(CliError(format!("unexpected arguments: {stray:?}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse("train --eps 0.25 --edges 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get::<f64>("eps").unwrap(), Some(0.25));
        assert_eq!(a.get_or::<usize>("edges", 1).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults_applied() {
        let a = parse("simulate");
        assert_eq!(a.get_or::<u64>("seed", 42).unwrap(), 42);
    }

    #[test]
    fn bad_value_rejected() {
        let a = parse("x --eps banana");
        assert!(a.get::<f64>("eps").is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("x --epss 0.1");
        let _ = a.get::<f64>("eps");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn positionals_are_collected_and_guarded() {
        let a = parse("trace run.jsonl --top 5");
        assert_eq!(a.subcommand.as_deref(), Some("trace"));
        // Unconsumed positional trips the typo guard...
        let _ = a.get::<usize>("top");
        assert!(a.reject_unknown().is_err());
        // ...consuming it clears the guard.
        assert_eq!(a.pos(0).as_deref(), Some("run.jsonl"));
        assert_eq!(a.pos(1), None);
        a.reject_unknown().unwrap();
    }

    #[test]
    fn positional_after_kv_is_a_value_not_positional() {
        let a = parse("scenario --spec s.toml out.jsonl");
        assert_eq!(a.str("spec").as_deref(), Some("s.toml"));
        assert_eq!(a.pos(0).as_deref(), Some("out.jsonl"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--eps 0.1");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get::<f64>("eps").unwrap(), Some(0.1));
    }
}
