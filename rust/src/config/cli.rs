//! Tiny CLI argument parser (offline substitute for `clap`).
//!
//! Grammar: `hfl <subcommand> [POSITIONAL]... [--key value]... [--flag]...`.
//! Values are parsed on demand (`f64`, `u64`, `usize`, `String`), unknown
//! keys and unconsumed positionals are rejected up front so typos fail
//! fast. A bare token that does not follow a `--key` is a positional
//! (e.g. the trace file in `hfl trace run.jsonl`).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
    consumed_pos: std::cell::RefCell<Vec<usize>>,
    /// Options looked up as values (`str`/`get`) that were parsed as bare
    /// flags because the next token was another `--option`. Surfaced by
    /// [`Args::reject_unknown`] so `--trace --top 3` fails fast instead of
    /// silently dropping the missing value.
    missing_value: std::cell::RefCell<Vec<String>>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let key = match tok.strip_prefix("--") {
                Some(k) => k.to_string(),
                None => {
                    args.positional.push(tok);
                    continue;
                }
            };
            if key.is_empty() {
                return Err(CliError("empty option name".into()));
            }
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    let val = it.next().unwrap();
                    args.kv.insert(key, val);
                }
                _ => args.flags.push(key),
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    /// Build an option set from prefixed environment variables:
    /// `HFL_SPEED_MAX=12` becomes `--speed-max 12`. Only key/value pairs
    /// are representable (an env var always carries a value); ordering is
    /// canonical (`BTreeMap`), not process-dependent.
    pub fn from_prefixed_vars<I>(prefix: &str, vars: I) -> Args
    where
        I: IntoIterator<Item = (String, String)>,
    {
        let mut args = Args::default();
        for (name, value) in vars {
            if let Some(rest) = name.strip_prefix(prefix) {
                if rest.is_empty() {
                    continue;
                }
                let key = rest.to_ascii_lowercase().replace('_', "-");
                args.kv.insert(key, value);
            }
        }
        args
    }

    /// Reconstruct every not-yet-consumed option as an argv fragment
    /// (`--key value` pairs first, in canonical key order, then bare
    /// flags) and mark them consumed. Used by `hfl submit` to forward
    /// spec-level overrides to the server verbatim.
    pub fn to_argv_unconsumed(&self) -> Vec<String> {
        let mut out = Vec::new();
        let mut consumed = self.consumed.borrow_mut();
        for (k, v) in &self.kv {
            if !consumed.contains(k) {
                out.push(format!("--{k}"));
                out.push(v.clone());
                consumed.push(k.clone());
            }
        }
        for f in &self.flags {
            if !consumed.contains(f) {
                out.push(format!("--{f}"));
                consumed.push(f.clone());
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        let found = self.flags.iter().any(|f| f == name);
        if found {
            self.consumed.borrow_mut().push(name.to_string());
        }
        found
    }

    pub fn str(&self, name: &str) -> Option<String> {
        let v = self.kv.get(name).cloned();
        if v.is_some() {
            self.consumed.borrow_mut().push(name.to_string());
        } else if self.flags.iter().any(|f| f == name) {
            // The caller expects a value but the parser saw `--name`
            // followed by another option: record it for reject_unknown so
            // the mistake fails fast with a precise message (returning
            // None here would silently apply the default).
            self.consumed.borrow_mut().push(name.to_string());
            self.missing_value.borrow_mut().push(name.to_string());
        }
        v
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.str(name) {
            None => {
                if self.missing_value.borrow().iter().any(|f| f == name) {
                    return Err(missing_value_err(name));
                }
                Ok(None)
            }
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("cannot parse --{name} value '{s}'"))),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        Ok(self.get(name)?.unwrap_or(default))
    }

    /// The `i`-th positional argument (0-based, after the subcommand).
    pub fn pos(&self, i: usize) -> Option<String> {
        let v = self.positional.get(i).cloned();
        if v.is_some() {
            self.consumed_pos.borrow_mut().push(i);
        }
        v
    }

    /// After all lookups, reject options nobody consumed (typo guard) and
    /// surface any value-taking option that was used as a bare flag.
    pub fn reject_unknown(&self) -> Result<(), CliError> {
        if let Some(name) = self.missing_value.borrow().first() {
            return Err(missing_value_err(name));
        }
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        if !unknown.is_empty() {
            return Err(CliError(format!("unknown options: {unknown:?}")));
        }
        let consumed_pos = self.consumed_pos.borrow();
        let stray: Vec<&String> = self
            .positional
            .iter()
            .enumerate()
            .filter(|(i, _)| !consumed_pos.contains(i))
            .map(|(_, p)| p)
            .collect();
        if stray.is_empty() {
            Ok(())
        } else {
            Err(CliError(format!("unexpected arguments: {stray:?}")))
        }
    }
}

fn missing_value_err(name: &str) -> CliError {
    CliError(format!(
        "option --{name} expects a value but was followed by another option \
         (write `--{name} VALUE`)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse("train --eps 0.25 --edges 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get::<f64>("eps").unwrap(), Some(0.25));
        assert_eq!(a.get_or::<usize>("edges", 1).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults_applied() {
        let a = parse("simulate");
        assert_eq!(a.get_or::<u64>("seed", 42).unwrap(), 42);
    }

    #[test]
    fn bad_value_rejected() {
        let a = parse("x --eps banana");
        assert!(a.get::<f64>("eps").is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("x --epss 0.1");
        let _ = a.get::<f64>("eps");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn positionals_are_collected_and_guarded() {
        let a = parse("trace run.jsonl --top 5");
        assert_eq!(a.subcommand.as_deref(), Some("trace"));
        // Unconsumed positional trips the typo guard...
        let _ = a.get::<usize>("top");
        assert!(a.reject_unknown().is_err());
        // ...consuming it clears the guard.
        assert_eq!(a.pos(0).as_deref(), Some("run.jsonl"));
        assert_eq!(a.pos(1), None);
        a.reject_unknown().unwrap();
    }

    #[test]
    fn positional_after_kv_is_a_value_not_positional() {
        let a = parse("scenario --spec s.toml out.jsonl");
        assert_eq!(a.str("spec").as_deref(), Some("s.toml"));
        assert_eq!(a.pos(0).as_deref(), Some("out.jsonl"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--eps 0.1");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get::<f64>("eps").unwrap(), Some(0.1));
    }

    #[test]
    fn value_option_followed_by_option_fails_fast() {
        // `--trace --top 3` used to silently treat --trace as a bare flag;
        // a value lookup must now produce a precise error, both eagerly
        // (typed get) and via the reject_unknown sweep (str).
        let a = parse("scenario --trace --top 3");
        assert!(a.str("trace").is_none());
        let _ = a.get::<usize>("top");
        let err = a.reject_unknown().unwrap_err();
        assert!(
            err.0.contains("--trace expects a value"),
            "want missing-value message, got '{}'",
            err.0
        );

        let b = parse("trace run.jsonl --top --verbose");
        let err = b.get::<usize>("top").unwrap_err();
        assert!(err.0.contains("--top expects a value"), "got '{}'", err.0);
    }

    #[test]
    fn flag_lookup_is_still_a_flag() {
        // flag() consumption must not trip the missing-value guard.
        let a = parse("scenario --validate-only --instances 2");
        assert!(a.flag("validate-only"));
        let _ = a.get::<usize>("instances");
        a.reject_unknown().unwrap();
    }

    #[test]
    fn negative_numbers_are_values_not_options() {
        let a = parse("x --shift -3.5 --delta -2");
        assert_eq!(a.get::<f64>("shift").unwrap(), Some(-3.5));
        assert_eq!(a.get::<i64>("delta").unwrap(), Some(-2));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn prefixed_vars_map_to_kv() {
        let vars = [
            ("HFL_SPEED_MAX".to_string(), "12.5".to_string()),
            ("HFL_MAX_EPOCHS".to_string(), "64".to_string()),
            ("HOME".to_string(), "/root".to_string()),
            ("HFL_".to_string(), "ignored".to_string()),
        ];
        let a = Args::from_prefixed_vars("HFL_", vars);
        assert_eq!(a.get::<f64>("speed-max").unwrap(), Some(12.5));
        assert_eq!(a.get::<u64>("max-epochs").unwrap(), Some(64));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn unconsumed_args_forward_and_then_count_as_consumed() {
        let a = parse("submit --addr 1.2.3.4:9 --ues 50 --max-epochs 4 --verbose");
        assert_eq!(a.str("addr").as_deref(), Some("1.2.3.4:9"));
        let fwd = a.to_argv_unconsumed();
        assert_eq!(
            fwd,
            vec!["--max-epochs", "4", "--ues", "50", "--verbose"],
            "kv pairs in canonical key order, then flags"
        );
        a.reject_unknown().unwrap();
        assert!(a.to_argv_unconsumed().is_empty());
    }
}
