//! The PJRT bridge: load `artifacts/*.hlo.txt`, compile once on the CPU
//! PJRT client, and expose typed `execute` wrappers for the FL hot loop.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` for why the
//! serialized-proto path is rejected by xla_extension 0.5.1). Each
//! executable is compiled exactly once at engine construction; per-step
//! cost is literal upload + execute + literal download.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed `artifacts/meta.json` (written by the python AOT pass).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub param_count: usize,
    pub image_hw: usize,
    pub num_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub init_seed: u64,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parse meta.json: {e}"))?;
        let field = |name: &str| -> Result<usize> {
            json.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("meta.json missing numeric field '{name}'"))
        };
        Ok(ArtifactMeta {
            param_count: field("param_count")?,
            image_hw: field("image_hw")?,
            num_classes: field("num_classes")?,
            train_batch: field("train_batch")?,
            eval_batch: field("eval_batch")?,
            init_seed: field("init_seed")? as u64,
        })
    }
}

/// Execution statistics (hot-path observability).
#[derive(Debug, Default)]
pub struct EngineStats {
    pub train_steps: AtomicU64,
    pub grad_steps: AtomicU64,
    pub eval_steps: AtomicU64,
    pub exec_ns: AtomicU64,
}

/// Compiled-model runtime. One instance per process; shareable across the
/// coordinator's worker threads (see [`Engine`] safety note).
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    train_step: xla::PjRtLoadedExecutable,
    grad_step: xla::PjRtLoadedExecutable,
    eval_step: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    init_params: Vec<f32>,
    pub stats: EngineStats,
    pub artifacts_dir: PathBuf,
}

// SAFETY: the xla crate's wrappers are `!Send`/`!Sync` only because they
// hold raw pointers. The underlying objects — PJRT CPU client and loaded
// executables — are documented thread-safe in XLA (the PJRT C API allows
// concurrent `Execute` calls on one loaded executable; the TFRT CPU
// client serializes/parallelizes internally). We never mutate the
// wrappers after construction; all &self calls go straight to
// thread-safe C++ entry points.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let meta = ArtifactMeta::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("load {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))
        };

        let train_step = compile("train_step")?;
        let grad_step = compile("grad_step")?;
        let eval_step = compile("eval_step")?;

        let init_path = dir.join("init_params.bin");
        let bytes = std::fs::read(&init_path)
            .with_context(|| format!("read {}", init_path.display()))?;
        if bytes.len() != meta.param_count * 4 {
            bail!(
                "init_params.bin is {} bytes, expected {} (param_count {})",
                bytes.len(),
                meta.param_count * 4,
                meta.param_count
            );
        }
        let init_params: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        Ok(Engine {
            client,
            train_step,
            grad_step,
            eval_step,
            meta,
            init_params,
            stats: EngineStats::default(),
            artifacts_dir: dir.to_path_buf(),
        })
    }

    /// The build-time initial parameter vector (identical for every UE, as
    /// Algorithm 1 line 1 requires).
    pub fn init_params(&self) -> Vec<f32> {
        self.init_params.clone()
    }

    fn params_literal(&self, params: &[f32]) -> Result<xla::Literal> {
        if params.len() != self.meta.param_count {
            bail!(
                "params length {} != param_count {}",
                params.len(),
                self.meta.param_count
            );
        }
        Ok(xla::Literal::vec1(params))
    }

    fn batch_literals(&self, x: &[f32], y: &[i32], batch: usize) -> Result<(xla::Literal, xla::Literal)> {
        let hw = self.meta.image_hw;
        if x.len() != batch * hw * hw {
            bail!("x length {} != {}x{}x{}", x.len(), batch, hw, hw);
        }
        if y.len() != batch {
            bail!("y length {} != batch {}", y.len(), batch);
        }
        let xl = xla::Literal::vec1(x).reshape(&[batch as i64, hw as i64, hw as i64, 1])?;
        let yl = xla::Literal::vec1(y);
        Ok((xl, yl))
    }

    /// One fused GD step: `(params, batch, lr) -> (params', loss)`.
    pub fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        // hfl-lint: allow(R3, exec_ns is an executor wall-time stat; never fed back into results)
        let t0 = std::time::Instant::now();
        let p = self.params_literal(params)?;
        let (xl, yl) = self.batch_literals(x, y, self.meta.train_batch)?;
        let lrl = xla::Literal::scalar(lr);
        let result = self.train_step.execute::<xla::Literal>(&[p, xl, yl, lrl])?[0][0]
            .to_literal_sync()?;
        let (new_params, loss) = result.to_tuple2()?;
        let out = (new_params.to_vec::<f32>()?, loss.get_first_element::<f32>()?);
        self.stats.train_steps.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Gradient only: `(params, batch) -> (grad, loss)` — used by the
    /// DANE-style local solver which forms its own update on the rust side.
    pub fn grad_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)> {
        // hfl-lint: allow(R3, exec_ns is an executor wall-time stat; never fed back into results)
        let t0 = std::time::Instant::now();
        let p = self.params_literal(params)?;
        let (xl, yl) = self.batch_literals(x, y, self.meta.train_batch)?;
        let result =
            self.grad_step.execute::<xla::Literal>(&[p, xl, yl])?[0][0].to_literal_sync()?;
        let (grad, loss) = result.to_tuple2()?;
        let out = (grad.to_vec::<f32>()?, loss.get_first_element::<f32>()?);
        self.stats.grad_steps.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// One evaluation shard: `(params, batch) -> (loss_sum, correct)`.
    pub fn eval_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        // hfl-lint: allow(R3, exec_ns is an executor wall-time stat; never fed back into results)
        let t0 = std::time::Instant::now();
        let p = self.params_literal(params)?;
        let (xl, yl) = self.batch_literals(x, y, self.meta.eval_batch)?;
        let result =
            self.eval_step.execute::<xla::Literal>(&[p, xl, yl])?[0][0].to_literal_sync()?;
        let (loss_sum, correct) = result.to_tuple2()?;
        let out = (
            loss_sum.get_first_element::<f32>()?,
            correct.get_first_element::<f32>()?,
        );
        self.stats.eval_steps.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Evaluate over a full test set, padding the last shard. Returns
    /// (mean loss, accuracy).
    pub fn evaluate(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<(f32, f32)> {
        let e = self.meta.eval_batch;
        let hw = self.meta.image_hw;
        let n = ys.len();
        if n == 0 {
            bail!("empty eval set");
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        let mut shard_x = vec![0.0f32; e * hw * hw];
        let mut shard_y = vec![0i32; e];
        while seen < n {
            let take = (n - seen).min(e);
            shard_x[..take * hw * hw]
                .copy_from_slice(&xs[seen * hw * hw..(seen + take) * hw * hw]);
            shard_y[..take].copy_from_slice(&ys[seen..seen + take]);
            if take < e {
                // Pad by repeating the first example; corrections applied below.
                for i in take..e {
                    shard_x.copy_within(0..hw * hw, i * hw * hw);
                    shard_y[i] = shard_y[0];
                }
            }
            let (ls, cc) = self.eval_step(params, &shard_x, &shard_y)?;
            if take < e {
                // Subtract the padded duplicates' contribution: evaluate the
                // first example alone via proportionality is not exact, so
                // recompute: padded examples are copies of shard[0]; their
                // per-example loss/correctness equals (ls0, cc0) measured on
                // a full shard of copies.
                let x0: Vec<f32> = shard_x[..hw * hw].repeat(e);
                let y0 = vec![shard_y[0]; e];
                let (ls0, cc0) = self.eval_step(params, &x0, &y0)?;
                let pad = (e - take) as f32;
                loss_sum += (ls - ls0 / e as f32 * pad) as f64;
                correct += (cc - cc0 / e as f32 * pad) as f64;
            } else {
                loss_sum += ls as f64;
                correct += cc as f64;
            }
            seen += take;
        }
        Ok((
            (loss_sum / n as f64) as f32,
            (correct / n as f64) as f32,
        ))
    }

    /// Mean PJRT execute latency in nanoseconds (all step kinds).
    pub fn mean_exec_ns(&self) -> f64 {
        let steps = self.stats.train_steps.load(Ordering::Relaxed)
            + self.stats.grad_steps.load(Ordering::Relaxed)
            + self.stats.eval_steps.load(Ordering::Relaxed);
        if steps == 0 {
            0.0
        } else {
            self.stats.exec_ns.load(Ordering::Relaxed) as f64 / steps as f64
        }
    }
}

/// Locate the artifacts directory: explicit argument, `HFL_ARTIFACTS`
/// env var, or walk up from the current directory.
pub fn find_artifacts(explicit: Option<&str>) -> Result<PathBuf> {
    if let Some(p) = explicit {
        let path = PathBuf::from(p);
        if path.join("meta.json").exists() {
            return Ok(path);
        }
        bail!("artifacts dir {p} has no meta.json (run `make artifacts`)");
    }
    if let Ok(p) = std::env::var("HFL_ARTIFACTS") {
        let path = PathBuf::from(p);
        if path.join("meta.json").exists() {
            return Ok(path);
        }
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("meta.json").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            bail!("no artifacts/ directory found (run `make artifacts`)");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let dir = std::env::temp_dir().join(format!("hfl_meta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"param_count": 44426, "image_hw": 28, "num_classes": 10,
                "train_batch": 32, "eval_batch": 128, "init_seed": 0}"#,
        )
        .unwrap();
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(meta.param_count, 44426);
        assert_eq!(meta.image_hw, 28);
        assert_eq!(meta.eval_batch, 128);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_missing_field_rejected() {
        let dir = std::env::temp_dir().join(format!("hfl_meta_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), r#"{"param_count": 5}"#).unwrap();
        assert!(ArtifactMeta::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn find_artifacts_rejects_bogus() {
        assert!(find_artifacts(Some("/nonexistent/nowhere")).is_err());
    }
}
