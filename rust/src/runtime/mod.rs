//! PJRT runtime: load AOT artifacts (HLO text), compile once on the CPU
//! PJRT client, execute train/grad/eval steps from the L3 hot path.

pub mod engine;

pub use engine::{find_artifacts, ArtifactMeta, Engine, EngineStats};
