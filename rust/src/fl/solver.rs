//! Local UE update rules for the `a` iterations of Algorithm 1 lines 6–8.
//!
//! The paper trains with plain gradient descent at the UEs ("we use GD in
//! UE local training", §III-B) while referencing DANE [22] as the
//! framework. Both are provided:
//!
//! * [`LocalSolver::Gd`] — `a` fused PJRT `train_step` executions
//!   (gradient + SGD update inside one executable).
//! * [`LocalSolver::Dane`] — DANE-style gradient correction: at round
//!   start each UE evaluates its local gradient at the shared model; the
//!   caller (edge) averages them into a global-gradient estimate; each
//!   UE then takes `a` corrected steps
//!   `w ← w − lr·(∇F_n(w) − ∇F_n(w₀) + ∇F(w₀))` via `grad_step` +
//!   rust-side axpy. This matches DANE's inexact Newton step with the
//!   regularizer μ = 0 and a GD inner solver.

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::Engine;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LocalSolver {
    Gd { lr: f32 },
    Dane { lr: f32 },
}

impl LocalSolver {
    pub fn parse(name: &str, lr: f32) -> Result<LocalSolver, String> {
        match name {
            "gd" => Ok(LocalSolver::Gd { lr }),
            "dane" => Ok(LocalSolver::Dane { lr }),
            other => Err(format!("unknown solver '{other}' (gd|dane)")),
        }
    }

    pub fn lr(&self) -> f32 {
        match self {
            LocalSolver::Gd { lr } | LocalSolver::Dane { lr } => *lr,
        }
    }
}

/// Mini-batch cursor over a UE's shard (reshuffled every wrap).
#[derive(Debug, Clone)]
pub struct BatchCursor {
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl BatchCursor {
    pub fn new(len: usize, seed: u64) -> BatchCursor {
        // hfl-lint: allow(R4, cursor RNG is rooted at the caller-derived per-UE seed)
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..len).collect();
        rng.shuffle(&mut order);
        BatchCursor {
            order,
            cursor: 0,
            rng,
        }
    }

    pub fn next_batch(&mut self, ds: &Dataset, x: &mut [f32], y: &mut [i32]) {
        let new_cursor = ds.fill_batch(&self.order, self.cursor, x, y);
        if new_cursor <= self.cursor {
            // Wrapped: reshuffle for the next epoch.
            self.rng.shuffle(&mut self.order);
        }
        self.cursor = new_cursor;
    }
}

/// Run `a` local iterations of the chosen solver. `correction` is the
/// DANE term `∇F(w₀) − ∇F_n(w₀)` (empty slice for GD). Returns the new
/// local model and the mean training loss across the `a` steps.
pub fn local_round(
    engine: &Engine,
    solver: &LocalSolver,
    params: &[f32],
    shard: &Dataset,
    cursor: &mut BatchCursor,
    a: u64,
    correction: &[f32],
) -> Result<(Vec<f32>, f32)> {
    let batch = engine.meta.train_batch;
    let hw = engine.meta.image_hw;
    let mut x = vec![0.0f32; batch * hw * hw];
    let mut y = vec![0i32; batch];
    let mut w = params.to_vec();
    let mut loss_acc = 0.0f64;
    for _ in 0..a {
        cursor.next_batch(shard, &mut x, &mut y);
        match solver {
            LocalSolver::Gd { lr } => {
                let (nw, loss) = engine.train_step(&w, &x, &y, *lr)?;
                w = nw;
                loss_acc += loss as f64;
            }
            LocalSolver::Dane { lr } => {
                let (grad, loss) = engine.grad_step(&w, &x, &y)?;
                debug_assert_eq!(correction.len(), w.len());
                for ((wi, gi), ci) in w.iter_mut().zip(&grad).zip(correction) {
                    *wi -= lr * (gi + ci);
                }
                loss_acc += loss as f64;
            }
        }
    }
    Ok((w, (loss_acc / a.max(1) as f64) as f32))
}

/// Evaluate the DANE correction inputs: the UE's local gradient at the
/// shared round-start model (averaged over one pass of up to
/// `max_batches` batches for stability).
pub fn local_gradient_at(
    engine: &Engine,
    params: &[f32],
    shard: &Dataset,
    cursor: &mut BatchCursor,
    max_batches: usize,
) -> Result<Vec<f32>> {
    let batch = engine.meta.train_batch;
    let hw = engine.meta.image_hw;
    let mut x = vec![0.0f32; batch * hw * hw];
    let mut y = vec![0i32; batch];
    let n_batches = shard.len().div_ceil(batch).min(max_batches).max(1);
    let mut acc = vec![0.0f64; params.len()];
    for _ in 0..n_batches {
        cursor.next_batch(shard, &mut x, &mut y);
        let (grad, _) = engine.grad_step(params, &x, &y)?;
        for (a, &g) in acc.iter_mut().zip(&grad) {
            *a += g as f64;
        }
    }
    Ok(acc
        .into_iter()
        .map(|v| (v / n_batches as f64) as f32)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_solvers() {
        assert_eq!(
            LocalSolver::parse("gd", 0.1).unwrap(),
            LocalSolver::Gd { lr: 0.1 }
        );
        assert_eq!(
            LocalSolver::parse("dane", 0.2).unwrap(),
            LocalSolver::Dane { lr: 0.2 }
        );
        assert!(LocalSolver::parse("sgd9", 0.1).is_err());
    }

    #[test]
    fn cursor_covers_all_examples() {
        let ds = crate::data::synthetic::generate(
            &crate::data::synthetic::SyntheticConfig::default(),
            10,
            1,
        );
        let mut cur = BatchCursor::new(ds.len(), 3);
        let mut seen = std::collections::BTreeSet::new();
        let mut x = vec![0.0f32; 2 * 28 * 28];
        let mut y = vec![0i32; 2];
        for _ in 0..5 {
            cur.next_batch(&ds, &mut x, &mut y);
            seen.extend(y.iter().copied());
        }
        // After one epoch (5 batches of 2 over 10 examples) we must have
        // seen every label present in the balanced set.
        assert_eq!(seen.len(), 10);
    }
}
