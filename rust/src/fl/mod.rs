//! Hierarchical federated learning engine (paper Algorithm 1).
//!
//! `aggregate` implements the data-weighted model averaging of Eqs. (6)
//! and (10); `solver` the local UE update rules (GD as in the paper, plus
//! a DANE-style gradient-corrected variant, §III-B); `engine` the
//! sequential reference implementation of Algorithm 1 over the PJRT
//! runtime; `metrics` the accuracy-vs-(simulated)-time curves of
//! Figs. 4/6. The parallel production path lives in `coordinator/`.

pub mod aggregate;
pub mod checkpoint;
pub mod engine;
pub mod metrics;
pub mod solver;

pub use aggregate::{cloud_aggregate, edge_aggregate, weighted_average};
pub use checkpoint::{load as load_checkpoint, save as save_checkpoint, CheckpointMeta};
pub use engine::{HflEngine, TrainRun, UeState};
pub use metrics::{CurvePoint, TrainingCurve};
pub use solver::LocalSolver;
