//! Model checkpointing: save/restore the flat parameter vector together
//! with run metadata, so long training runs (and the `hfl train` CLI) can
//! resume and trained models can be handed to evaluation tooling.
//!
//! Format: `<stem>.bin` (raw f32 little-endian, same layout as
//! `artifacts/init_params.bin`) + `<stem>.json` (metadata: param count,
//! cloud round, a, b, test accuracy).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Checkpoint metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub param_count: usize,
    pub cloud_round: u64,
    pub a: u64,
    pub b: u64,
    pub test_acc: f64,
}

/// Write `<stem>.bin` + `<stem>.json`. Returns the bin path.
pub fn save(stem: &Path, params: &[f32], meta: &CheckpointMeta) -> Result<PathBuf> {
    if params.len() != meta.param_count {
        bail!(
            "params length {} != meta.param_count {}",
            params.len(),
            meta.param_count
        );
    }
    if let Some(dir) = stem.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let bin = stem.with_extension("bin");
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for &p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(&bin, &bytes).with_context(|| format!("write {}", bin.display()))?;

    let json = Json::obj(vec![
        ("param_count", Json::num(meta.param_count as f64)),
        ("cloud_round", Json::num(meta.cloud_round as f64)),
        ("a", Json::num(meta.a as f64)),
        ("b", Json::num(meta.b as f64)),
        ("test_acc", Json::num(meta.test_acc)),
    ]);
    std::fs::write(stem.with_extension("json"), json.to_string())?;
    Ok(bin)
}

/// Load a checkpoint pair written by [`save`].
pub fn load(stem: &Path) -> Result<(Vec<f32>, CheckpointMeta)> {
    let json_text = std::fs::read_to_string(stem.with_extension("json"))
        .with_context(|| format!("read {}.json", stem.display()))?;
    let json = Json::parse(&json_text).map_err(|e| anyhow!("parse checkpoint meta: {e}"))?;
    let field = |name: &str| -> Result<f64> {
        json.get(name)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("checkpoint meta missing '{name}'"))
    };
    let meta = CheckpointMeta {
        param_count: field("param_count")? as usize,
        cloud_round: field("cloud_round")? as u64,
        a: field("a")? as u64,
        b: field("b")? as u64,
        test_acc: field("test_acc")?,
    };
    let bytes = std::fs::read(stem.with_extension("bin"))
        .with_context(|| format!("read {}.bin", stem.display()))?;
    if bytes.len() != meta.param_count * 4 {
        bail!(
            "checkpoint bin is {} bytes, expected {}",
            bytes.len(),
            meta.param_count * 4
        );
    }
    let params = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((params, meta))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> CheckpointMeta {
        CheckpointMeta {
            param_count: 5,
            cloud_round: 3,
            a: 35,
            b: 5,
            test_acc: 0.91,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("hfl_ckpt_{}", std::process::id()));
        let stem = dir.join("round3");
        let params = vec![1.0f32, -2.5, 3.25, 0.0, 9.75];
        save(&stem, &params, &meta()).unwrap();
        let (loaded, lmeta) = load(&stem).unwrap();
        assert_eq!(loaded, params);
        assert_eq!(lmeta, meta());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn length_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("hfl_ckpt_bad_{}", std::process::id()));
        let stem = dir.join("x");
        assert!(save(&stem, &[1.0, 2.0], &meta()).is_err());
        // Corrupt the bin after a good save.
        save(&stem, &[0.0; 5], &meta()).unwrap();
        std::fs::write(stem.with_extension("bin"), [0u8; 7]).unwrap();
        assert!(load(&stem).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_files_reported() {
        assert!(load(Path::new("/nonexistent/ckpt")).is_err());
    }
}
