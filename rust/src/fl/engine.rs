//! Sequential reference implementation of Algorithm 1 (hierarchical FL).
//!
//! Every UE trains `a` local iterations from its edge's current model;
//! the edge aggregates (Eq. (6)) after each of its `b` edge rounds; the
//! cloud aggregates (Eq. (10)) once per cloud round, evaluates on the
//! held-out set, and stamps the point with the *simulated* protocol time
//! from the delay model (Figs. 4/6 x-axis).
//!
//! The threaded production path (`coordinator/`) must produce bitwise
//! identical models to this engine for the same seed — UE updates are
//! independent within an edge round and aggregation order is fixed —
//! which the integration tests assert.

use anyhow::Result;

use super::aggregate::edge_aggregate;
use super::metrics::{CurvePoint, TrainingCurve};
use super::solver::{local_gradient_at, local_round, BatchCursor, LocalSolver};
use crate::data::Dataset;
use crate::runtime::Engine;

/// Per-UE training state.
#[derive(Debug)]
pub struct UeState {
    pub shard: Dataset,
    pub cursor: BatchCursor,
}

impl UeState {
    pub fn new(shard: Dataset, seed: u64) -> UeState {
        let cursor = BatchCursor::new(shard.len(), seed);
        UeState { shard, cursor }
    }

    /// Canonical per-UE seeding shared by the sequential engine and the
    /// threaded coordinator so both produce bitwise-identical runs.
    pub fn seeded(shard: Dataset, ue_id: usize, seed: u64) -> UeState {
        UeState::new(shard, seed ^ (0x9E37 + ue_id as u64 * 0x51_7CC1))
    }

    pub fn data_size(&self) -> u64 {
        self.shard.len() as u64
    }
}

/// One training run's parameters.
#[derive(Debug, Clone)]
pub struct TrainRun {
    /// Local iterations per edge round.
    pub a: u64,
    /// Edge rounds per cloud round.
    pub b: u64,
    /// Cloud rounds to execute.
    pub cloud_rounds: u64,
    /// Simulated seconds one cloud round costs (delay-model `T(a,b)`).
    pub round_time_s: f64,
    /// Evaluate every k cloud rounds (1 = every round).
    pub eval_every: u64,
}

/// The engine: model state + data + solver.
pub struct HflEngine<'e> {
    pub engine: &'e Engine,
    pub solver: LocalSolver,
    /// UE states, indexed by UE id.
    pub ues: Vec<UeState>,
    /// Edge membership (N_m for each edge).
    pub members: Vec<Vec<usize>>,
    /// Held-out test set.
    pub test: Dataset,
    /// Final global model of the last `train` call.
    pub global: Vec<f32>,
}

impl<'e> HflEngine<'e> {
    pub fn new(
        engine: &'e Engine,
        solver: LocalSolver,
        shards: Vec<Dataset>,
        members: Vec<Vec<usize>>,
        test: Dataset,
        seed: u64,
    ) -> HflEngine<'e> {
        let ues = shards
            .into_iter()
            .enumerate()
            .map(|(i, s)| UeState::seeded(s, i, seed))
            .collect();
        HflEngine {
            engine,
            solver,
            ues,
            members,
            test,
            global: Vec::new(),
        }
    }

    /// One edge round for edge `m` starting from `w_m`: every member
    /// trains `a` iterations, then Eq. (6). Returns (new w_m, mean loss).
    pub fn edge_round(&mut self, m: usize, w_m: &[f32], a: u64) -> Result<(Vec<f32>, f32)> {
        let member_ids = self.members[m].clone();
        // DANE correction: global-gradient estimate at w_m.
        let corrections: Vec<Vec<f32>> = if matches!(self.solver, LocalSolver::Dane { .. }) {
            let mut grads = Vec::with_capacity(member_ids.len());
            for &n in &member_ids {
                let ue = &mut self.ues[n];
                grads.push(local_gradient_at(
                    self.engine,
                    w_m,
                    &ue.shard,
                    &mut ue.cursor,
                    4,
                )?);
            }
            let weights: Vec<(f64, &[f32])> = member_ids
                .iter()
                .zip(&grads)
                .map(|(&n, g)| (self.ues[n].data_size() as f64, g.as_slice()))
                .collect();
            let global_grad = super::aggregate::weighted_average(&weights);
            grads
                .iter()
                .map(|g| {
                    global_grad
                        .iter()
                        .zip(g)
                        .map(|(gg, gn)| gg - gn)
                        .collect()
                })
                .collect()
        } else {
            vec![Vec::new(); member_ids.len()]
        };

        let mut models: Vec<(u64, Vec<f32>)> = Vec::with_capacity(member_ids.len());
        let mut loss_acc = 0.0f64;
        for (slot, &n) in member_ids.iter().enumerate() {
            let ue = &mut self.ues[n];
            let (w_n, loss) = local_round(
                self.engine,
                &self.solver,
                w_m,
                &ue.shard,
                &mut ue.cursor,
                a,
                &corrections[slot],
            )?;
            loss_acc += loss as f64;
            models.push((ue.data_size(), w_n));
        }
        let refs: Vec<(u64, &[f32])> = models.iter().map(|(d, m)| (*d, m.as_slice())).collect();
        Ok((
            edge_aggregate(&refs),
            (loss_acc / member_ids.len().max(1) as f64) as f32,
        ))
    }

    /// Run Algorithm 1 for `run.cloud_rounds` cloud rounds from the
    /// build-time initial model. Returns the training curve.
    pub fn train(&mut self, run: &TrainRun) -> Result<TrainingCurve> {
        let mut global = self.engine.init_params();
        let mut curve = TrainingCurve::new(run.a, run.b);
        // hfl-lint: allow(R3, wall_s on the training curve is observability, never simulated time)
        let t0 = std::time::Instant::now();

        // Round-0 point: the initial model.
        let (loss0, acc0) = self.engine.evaluate(&global, &self.test.x, &self.test.y)?;
        curve.push(CurvePoint {
            cloud_round: 0,
            sim_time_s: 0.0,
            wall_s: t0.elapsed().as_secs_f64(),
            test_acc: acc0,
            test_loss: loss0,
            train_loss: f32::NAN,
        });

        for round in 1..=run.cloud_rounds {
            let mut edge_models: Vec<(u64, Vec<f32>)> = Vec::with_capacity(self.members.len());
            let mut loss_acc = 0.0f64;
            let mut loss_cnt = 0usize;
            for m in 0..self.members.len() {
                if self.members[m].is_empty() {
                    continue;
                }
                let mut w_m = global.clone();
                for _k in 0..run.b {
                    let (next, loss) = self.edge_round(m, &w_m, run.a)?;
                    w_m = next;
                    loss_acc += loss as f64;
                    loss_cnt += 1;
                }
                let d_m: u64 = self.members[m]
                    .iter()
                    .map(|&n| self.ues[n].data_size())
                    .sum();
                edge_models.push((d_m, w_m));
            }
            let refs: Vec<(u64, &[f32])> =
                edge_models.iter().map(|(d, m)| (*d, m.as_slice())).collect();
            global = super::aggregate::cloud_aggregate(&refs);

            if round % run.eval_every == 0 || round == run.cloud_rounds {
                let (loss, acc) = self.engine.evaluate(&global, &self.test.x, &self.test.y)?;
                curve.push(CurvePoint {
                    cloud_round: round,
                    sim_time_s: round as f64 * run.round_time_s,
                    wall_s: t0.elapsed().as_secs_f64(),
                    test_acc: acc,
                    test_loss: loss,
                    train_loss: (loss_acc / loss_cnt.max(1) as f64) as f32,
                });
            }
        }
        self.global = global;
        Ok(curve)
    }
}
