//! Model aggregation: the data-size-weighted averages of Eqs. (6) and
//! (10). Hot-path code — called once per edge round per edge and once per
//! cloud round — so it is allocation-conscious: `weighted_average_into`
//! reuses the output buffer.

/// `out = Σ w_i x_i / Σ w_i` over equal-length vectors.
pub fn weighted_average_into(models: &[(f64, &[f32])], out: &mut [f32]) {
    assert!(!models.is_empty(), "aggregate of zero models");
    let dim = models[0].1.len();
    assert!(models.iter().all(|(_, m)| m.len() == dim));
    assert_eq!(out.len(), dim);
    let total: f64 = models.iter().map(|(w, _)| *w).sum();
    assert!(total > 0.0, "aggregate weights sum to {total}");

    // f64 accumulation: edge aggregates feed cloud aggregates, so keep
    // rounding error out of the hierarchy.
    out.iter_mut().for_each(|o| *o = 0.0);
    let mut acc = vec![0.0f64; dim];
    for (w, m) in models {
        let wn = *w / total;
        for (a, &v) in acc.iter_mut().zip(m.iter()) {
            *a += wn * v as f64;
        }
    }
    for (o, a) in out.iter_mut().zip(acc) {
        *o = a as f32;
    }
}

/// Allocating convenience wrapper.
pub fn weighted_average(models: &[(f64, &[f32])]) -> Vec<f32> {
    assert!(!models.is_empty(), "aggregate of zero models");
    let mut out = vec![0.0f32; models[0].1.len()];
    weighted_average_into(models, &mut out);
    out
}

/// Eq. (6): edge aggregation `ω_m = Σ_{n∈N_m} D_n ω_n / D_{N_m}`.
pub fn edge_aggregate(ue_models: &[(u64, &[f32])]) -> Vec<f32> {
    let weighted: Vec<(f64, &[f32])> = ue_models
        .iter()
        .map(|&(d, m)| (d as f64, m))
        .collect();
    weighted_average(&weighted)
}

/// Eq. (10): cloud aggregation `ω = Σ_m D_{N_m} ω_m / D`.
pub fn cloud_aggregate(edge_models: &[(u64, &[f32])]) -> Vec<f32> {
    edge_aggregate(edge_models)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_weights_is_mean() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [3.0f32, 4.0, 5.0];
        let avg = weighted_average(&[(1.0, &a), (1.0, &b)]);
        assert_eq!(avg, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn weights_proportional_to_data() {
        let a = [0.0f32];
        let b = [10.0f32];
        let avg = edge_aggregate(&[(900, &a), (100, &b)]);
        assert!((avg[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn single_model_identity() {
        let a = [1.5f32, -2.5];
        assert_eq!(weighted_average(&[(7.0, &a)]), a.to_vec());
    }

    #[test]
    fn hierarchy_equals_flat_average() {
        // Cloud(Edge(a,b), Edge(c)) must equal flat weighted average —
        // the algebraic identity FedAvg hierarchies rely on.
        let (m1, m2, m3) = ([1.0f32, 0.0], [0.0f32, 1.0], [4.0f32, 4.0]);
        let (d1, d2, d3) = (100u64, 300, 600);
        let e1 = edge_aggregate(&[(d1, &m1), (d2, &m2)]);
        let e2 = edge_aggregate(&[(d3, &m3)]);
        let cloud = cloud_aggregate(&[(d1 + d2, &e1), (d3, &e2)]);
        let flat = edge_aggregate(&[(d1, &m1), (d2, &m2), (d3, &m3)]);
        for (c, f) in cloud.iter().zip(&flat) {
            assert!((c - f).abs() < 1e-6, "{cloud:?} vs {flat:?}");
        }
    }

    #[test]
    #[should_panic(expected = "zero models")]
    fn empty_rejected() {
        weighted_average(&[]);
    }

    #[test]
    fn into_variant_reuses_buffer() {
        let a = [1.0f32, 2.0];
        let mut out = vec![9.0f32; 2];
        weighted_average_into(&[(2.0, &a)], &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }
}
