//! Training-curve records: the accuracy-vs-completion-time series of the
//! paper's Figs. 4 and 6.

use crate::metrics::Series;

/// One evaluation point along a training run.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    /// Cloud round index (1-based; 0 = initial model).
    pub cloud_round: u64,
    /// Simulated protocol completion time (seconds) per the delay model.
    pub sim_time_s: f64,
    /// Wall-clock seconds actually spent (PJRT compute).
    pub wall_s: f64,
    /// Held-out test accuracy.
    pub test_acc: f32,
    /// Held-out mean test loss.
    pub test_loss: f32,
    /// Mean training loss across UEs in the round.
    pub train_loss: f32,
}

/// A full run: configuration echo + the curve.
#[derive(Debug, Clone)]
pub struct TrainingCurve {
    pub a: u64,
    pub b: u64,
    pub points: Vec<CurvePoint>,
}

impl TrainingCurve {
    pub fn new(a: u64, b: u64) -> TrainingCurve {
        TrainingCurve {
            a,
            b,
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    /// Final test accuracy (0 if no points).
    pub fn final_acc(&self) -> f32 {
        self.points.last().map(|p| p.test_acc).unwrap_or(0.0)
    }

    /// First simulated time at which accuracy ≥ target (None if never) —
    /// the paper's "completion time to reach accuracy X" reading of
    /// Figs. 4/6.
    pub fn time_to_accuracy(&self, target: f32) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.test_acc >= target)
            .map(|p| p.sim_time_s)
    }

    /// Convert to a metrics table.
    pub fn to_series(&self) -> Series {
        let mut s = Series::new(&[
            "cloud_round",
            "sim_time_s",
            "wall_s",
            "test_acc",
            "test_loss",
            "train_loss",
        ]);
        for p in &self.points {
            s.push(vec![
                p.cloud_round as f64,
                p.sim_time_s,
                p.wall_s,
                p.test_acc as f64,
                p.test_loss as f64,
                p.train_loss as f64,
            ]);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve() -> TrainingCurve {
        let mut c = TrainingCurve::new(35, 5);
        for (i, acc) in [0.1f32, 0.5, 0.8, 0.9].iter().enumerate() {
            c.push(CurvePoint {
                cloud_round: i as u64,
                sim_time_s: i as f64 * 10.0,
                wall_s: i as f64,
                test_acc: *acc,
                test_loss: 1.0 - acc,
                train_loss: 1.0 - acc,
            });
        }
        c
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let c = curve();
        assert_eq!(c.time_to_accuracy(0.5), Some(10.0));
        assert_eq!(c.time_to_accuracy(0.85), Some(30.0));
        assert_eq!(c.time_to_accuracy(0.99), None);
        assert_eq!(c.final_acc(), 0.9);
    }

    #[test]
    fn series_shape() {
        let s = curve().to_series();
        assert_eq!(s.columns.len(), 6);
        assert_eq!(s.rows.len(), 4);
    }
}
