//! # hfl — Time Minimization in Hierarchical Federated Learning
//!
//! Production-grade reproduction of *"Time Minimization in Hierarchical
//! Federated Learning"* (Liu, Chua, Zhao — 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   wireless/delay model, the (a, b) iteration-count optimizer
//!   (Algorithm 2 + exact reference solvers), the UE-to-edge association
//!   strategies (Algorithm 3, greedy, random, exact MILP), an
//!   event-driven latency simulator, a threaded hierarchical-FedAvg
//!   training runtime (Algorithm 1), and a declarative scenario engine
//!   with time-varying dynamics + parallel fleet runner (`scenario/`).
//! * **L2 (python/compile/model.py, build-time only)** — LeNet-5 fwd/bwd
//!   in JAX over a flat parameter vector, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build-time only)** — the Pallas
//!   tiled-matmul kernel every dense layer and im2col convolution flows
//!   through.
//!
//! At runtime the rust binary is self-contained: `runtime/` loads the
//! `artifacts/*.hlo.txt` produced by `make artifacts` into a PJRT CPU
//! client and the FL engine executes them on the hot path; Python never
//! runs during serving/training.
//!
//! See DESIGN.md for the paper-to-module map and EXPERIMENTS.md for the
//! per-figure reproduction results.

pub mod assoc;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod fl;
pub mod metrics;
pub mod net;
pub mod opt;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
