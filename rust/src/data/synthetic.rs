//! Synthetic MNIST-like generator — the documented substitution for the
//! real MNIST download on this offline image (DESIGN.md §3).
//!
//! Ten fixed class "prototypes" are sampled once per seed as smoothed
//! random fields; each example is its class prototype warped by a random
//! integer translation, multiplied by a per-sample contrast, and
//! perturbed with pixel noise. The task is linearly non-trivial but
//! LeNet-learnable, producing accuracy-vs-time curves with the same
//! qualitative shape as the paper's MNIST figures (Figs. 4/6).

use super::Dataset;
use crate::util::Rng;

/// Generation knobs.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    pub hw: usize,
    pub num_classes: usize,
    /// Max |shift| in pixels applied to the prototype.
    pub max_shift: i64,
    /// Additive pixel-noise amplitude.
    pub noise: f64,
    /// Contrast jitter range (multiplier drawn from [1-c, 1+c]).
    pub contrast: f64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            hw: 28,
            num_classes: 10,
            max_shift: 3,
            noise: 0.15,
            contrast: 0.25,
        }
    }
}

/// Smooth a field with a separable 3x3 box filter, `passes` times.
fn smooth(field: &mut Vec<f64>, hw: usize, passes: usize) {
    let mut tmp = vec![0.0f64; hw * hw];
    for _ in 0..passes {
        for r in 0..hw {
            for c in 0..hw {
                let mut acc = 0.0;
                let mut cnt = 0.0;
                for dr in -1i64..=1 {
                    for dc in -1i64..=1 {
                        let (rr, cc) = (r as i64 + dr, c as i64 + dc);
                        if rr >= 0 && rr < hw as i64 && cc >= 0 && cc < hw as i64 {
                            acc += field[rr as usize * hw + cc as usize];
                            cnt += 1.0;
                        }
                    }
                }
                tmp[r * hw + c] = acc / cnt;
            }
        }
        std::mem::swap(field, &mut tmp);
    }
}

/// Build the per-class prototypes for a seed.
fn prototypes(cfg: &SyntheticConfig, seed: u64) -> Vec<Vec<f64>> {
    // hfl-lint: allow(R4, prototype stream is rooted at the dataset proto seed)
    let mut rng = Rng::new(seed ^ 0x70726f746f); // "proto"
    (0..cfg.num_classes)
        .map(|_| {
            let mut field: Vec<f64> = (0..cfg.hw * cfg.hw).map(|_| rng.f64()).collect();
            smooth(&mut field, cfg.hw, 3);
            // Normalize to [0, 1] and sharpen so classes are distinct.
            let (lo, hi) = field
                .iter()
                .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
                    (l.min(v), h.max(v))
                });
            field
                .iter()
                .map(|&v| {
                    let t = (v - lo) / (hi - lo).max(1e-9);
                    // Soft threshold: emphasize the blob structure.
                    1.0 / (1.0 + (-10.0 * (t - 0.5)).exp())
                })
                .collect()
        })
        .collect()
}

/// Generate `n` labeled examples. Labels are balanced round-robin so
/// every class appears ⌈n/10⌉ or ⌊n/10⌋ times.
///
/// `seed` fixes BOTH the class prototypes and the sample noise. Use
/// [`generate_split`] when several datasets (UE shards, test set) must
/// share one task definition: same `proto_seed` = same classes.
pub fn generate(cfg: &SyntheticConfig, n: usize, seed: u64) -> Dataset {
    generate_split(cfg, n, seed, seed)
}

/// Generate with independent prototype and sample seeds. Datasets built
/// with equal `proto_seed` belong to the same classification task.
pub fn generate_split(cfg: &SyntheticConfig, n: usize, proto_seed: u64, sample_seed: u64) -> Dataset {
    let protos = prototypes(cfg, proto_seed);
    // hfl-lint: allow(R4, sample-noise stream is rooted at the dataset sample seed)
    let mut rng = Rng::new(sample_seed ^ 0x73616d706c65); // "sample"
    let hw = cfg.hw;
    let mut x = Vec::with_capacity(n * hw * hw);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % cfg.num_classes;
        let proto = &protos[class];
        let (dr, dc) = (
            rng.int_range(-cfg.max_shift, cfg.max_shift),
            rng.int_range(-cfg.max_shift, cfg.max_shift),
        );
        let contrast = rng.range(1.0 - cfg.contrast, 1.0 + cfg.contrast);
        for r in 0..hw as i64 {
            for c in 0..hw as i64 {
                let (sr, sc) = (r - dr, c - dc);
                let base = if sr >= 0 && sr < hw as i64 && sc >= 0 && sc < hw as i64 {
                    proto[sr as usize * hw + sc as usize]
                } else {
                    0.0
                };
                let v = base * contrast + cfg.noise * (rng.f64() - 0.5);
                x.push(v.clamp(0.0, 1.0) as f32);
            }
        }
        y.push(class as i32);
    }
    let ds = Dataset {
        x,
        y,
        hw,
        num_classes: cfg.num_classes,
    };
    debug_assert!(ds.validate().is_ok());
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = SyntheticConfig::default();
        let a = generate(&cfg, 50, 9);
        let b = generate(&cfg, 50, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&cfg, 50, 10);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn split_seeds_share_task_but_not_samples() {
        let cfg = SyntheticConfig::default();
        let train = generate_split(&cfg, 40, 5, 100);
        let test = generate_split(&cfg, 40, 5, 200);
        assert_ne!(train.x, test.x, "different sample noise");
        // Same prototypes: nearest-prototype classification trained on
        // the train split must transfer to the test split.
        let protos = prototypes(&cfg, 5);
        let hw = cfg.hw;
        let mut correct = 0;
        for i in 0..test.len() {
            let xs = &test.x[i * hw * hw..(i + 1) * hw * hw];
            let best = (0..cfg.num_classes)
                .min_by(|&a, &b| {
                    let d = |c: usize| -> f64 {
                        xs.iter()
                            .zip(&protos[c])
                            .map(|(&p, &q)| (p as f64 - q).powi(2))
                            .sum()
                    };
                    d(a).total_cmp(&d(b))
                })
                .unwrap();
            if best as i32 == test.y[i] {
                correct += 1;
            }
        }
        assert!(correct > 24, "transfer acc {correct}/40");
    }

    #[test]
    fn valid_and_balanced() {
        let cfg = SyntheticConfig::default();
        let d = generate(&cfg, 100, 3);
        d.validate().unwrap();
        let h = d.class_histogram();
        assert!(h.iter().all(|&c| c == 10), "{h:?}");
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // Nearest-prototype classification on clean generation should beat
        // chance by a wide margin — sanity that the task is learnable.
        let cfg = SyntheticConfig::default();
        let protos = prototypes(&cfg, 5);
        let d = generate(&cfg, 200, 5);
        let hw = cfg.hw;
        let mut correct = 0;
        for i in 0..d.len() {
            let xs = &d.x[i * hw * hw..(i + 1) * hw * hw];
            let best = (0..cfg.num_classes)
                .min_by(|&a, &b| {
                    let da: f64 = xs
                        .iter()
                        .zip(&protos[a])
                        .map(|(&p, &q)| (p as f64 - q).powi(2))
                        .sum();
                    let db: f64 = xs
                        .iter()
                        .zip(&protos[b])
                        .map(|(&p, &q)| (p as f64 - q).powi(2))
                        .sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if best as i32 == d.y[i] {
                correct += 1;
            }
        }
        assert!(correct > 120, "nearest-proto acc {correct}/200");
    }

    #[test]
    fn prototypes_distinct() {
        let cfg = SyntheticConfig::default();
        let protos = prototypes(&cfg, 1);
        for a in 0..protos.len() {
            for b in (a + 1)..protos.len() {
                let d2: f64 = protos[a]
                    .iter()
                    .zip(&protos[b])
                    .map(|(&p, &q)| (p - q) * (p - q))
                    .sum();
                assert!(d2 > 1.0, "prototypes {a},{b} too close: {d2}");
            }
        }
    }
}
