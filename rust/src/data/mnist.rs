//! MNIST IDX-format loader. When a real MNIST copy is present (e.g.
//! `data/mnist/train-images-idx3-ubyte`), scenarios use it; otherwise the
//! synthetic generator stands in (DESIGN.md §3).

use std::io::Read;
use std::path::Path;

use super::Dataset;

const IDX_IMAGES_MAGIC: u32 = 0x0000_0803;
const IDX_LABELS_MAGIC: u32 = 0x0000_0801;

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_be_bytes(buf))
}

/// Parse an IDX3 image file into normalized pixels.
pub fn parse_idx_images(bytes: &[u8]) -> Result<(Vec<f32>, usize, usize), String> {
    let mut r = bytes;
    let magic = read_u32(&mut r).map_err(|e| e.to_string())?;
    if magic != IDX_IMAGES_MAGIC {
        return Err(format!("bad image magic {magic:#x}"));
    }
    let n = read_u32(&mut r).map_err(|e| e.to_string())? as usize;
    let rows = read_u32(&mut r).map_err(|e| e.to_string())? as usize;
    let cols = read_u32(&mut r).map_err(|e| e.to_string())? as usize;
    if rows != cols {
        return Err(format!("non-square images {rows}x{cols}"));
    }
    let mut pix = vec![0u8; n * rows * cols];
    r.read_exact(&mut pix)
        .map_err(|e| format!("truncated image data: {e}"))?;
    Ok((
        pix.iter().map(|&b| b as f32 / 255.0).collect(),
        n,
        rows,
    ))
}

/// Parse an IDX1 label file.
pub fn parse_idx_labels(bytes: &[u8]) -> Result<Vec<i32>, String> {
    let mut r = bytes;
    let magic = read_u32(&mut r).map_err(|e| e.to_string())?;
    if magic != IDX_LABELS_MAGIC {
        return Err(format!("bad label magic {magic:#x}"));
    }
    let n = read_u32(&mut r).map_err(|e| e.to_string())? as usize;
    let mut lab = vec![0u8; n];
    r.read_exact(&mut lab)
        .map_err(|e| format!("truncated label data: {e}"))?;
    Ok(lab.iter().map(|&b| b as i32).collect())
}

/// Load `(train, test)` from a directory holding the four canonical
/// MNIST files (optionally without the `-ubyte` suffix).
pub fn load_mnist_dir(dir: &Path) -> Result<(Dataset, Dataset), String> {
    let read = |names: &[&str]| -> Result<Vec<u8>, String> {
        for name in names {
            let p = dir.join(name);
            if p.exists() {
                return std::fs::read(&p).map_err(|e| format!("read {}: {e}", p.display()));
            }
        }
        Err(format!("none of {names:?} found in {}", dir.display()))
    };
    let load_pair = |img_names: &[&str], lab_names: &[&str]| -> Result<Dataset, String> {
        let (x, n, hw) = parse_idx_images(&read(img_names)?)?;
        let y = parse_idx_labels(&read(lab_names)?)?;
        if y.len() != n {
            return Err(format!("{n} images but {} labels", y.len()));
        }
        let ds = Dataset {
            x,
            y,
            hw,
            num_classes: 10,
        };
        ds.validate()?;
        Ok(ds)
    };
    let train = load_pair(
        &["train-images-idx3-ubyte", "train-images.idx3-ubyte"],
        &["train-labels-idx1-ubyte", "train-labels.idx1-ubyte"],
    )?;
    let test = load_pair(
        &["t10k-images-idx3-ubyte", "t10k-images.idx3-ubyte"],
        &["t10k-labels-idx1-ubyte", "t10k-labels.idx1-ubyte"],
    )?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx_images(n: usize, hw: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&IDX_IMAGES_MAGIC.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend_from_slice(&(hw as u32).to_be_bytes());
        b.extend_from_slice(&(hw as u32).to_be_bytes());
        b.extend((0..n * hw * hw).map(|i| (i % 251) as u8));
        b
    }

    fn idx_labels(n: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&IDX_LABELS_MAGIC.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend((0..n).map(|i| (i % 10) as u8));
        b
    }

    #[test]
    fn parse_roundtrip() {
        let (x, n, hw) = parse_idx_images(&idx_images(5, 4)).unwrap();
        assert_eq!((n, hw), (5, 4));
        assert_eq!(x.len(), 5 * 16);
        assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let y = parse_idx_labels(&idx_labels(5)).unwrap();
        assert_eq!(y, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut b = idx_images(1, 4);
        b[3] = 0x99;
        assert!(parse_idx_images(&b).is_err());
        let mut l = idx_labels(1);
        l[3] = 0x99;
        assert!(parse_idx_labels(&l).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let b = idx_images(5, 4);
        assert!(parse_idx_images(&b[..b.len() - 3]).is_err());
    }

    #[test]
    fn load_dir_end_to_end() {
        let dir = std::env::temp_dir().join(format!("hfl_mnist_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("train-images-idx3-ubyte"), idx_images(20, 28)).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), idx_labels(20)).unwrap();
        std::fs::write(dir.join("t10k-images-idx3-ubyte"), idx_images(10, 28)).unwrap();
        std::fs::write(dir.join("t10k-labels-idx1-ubyte"), idx_labels(10)).unwrap();
        let (train, test) = load_mnist_dir(&dir).unwrap();
        assert_eq!(train.len(), 20);
        assert_eq!(test.len(), 10);
        assert_eq!(train.hw, 28);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_files_reported() {
        let dir = std::env::temp_dir().join("hfl_mnist_missing");
        std::fs::create_dir_all(&dir).ok();
        assert!(load_mnist_dir(&dir).is_err());
    }
}
