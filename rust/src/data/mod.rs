//! Dataset substrate: MNIST IDX loading, a synthetic MNIST-like
//! generator (the offline substitution documented in DESIGN.md §3), and
//! IID / Dirichlet non-IID partitioning across UEs.

pub mod mnist;
pub mod partition;
pub mod synthetic;

pub use mnist::load_mnist_dir;
pub use partition::{partition_dirichlet, partition_iid};
pub use synthetic::generate;

/// An image-classification dataset in the layout the PJRT executables
/// expect: `x` is row-major `[n, hw, hw, 1]` in `[0, 1]`, `y` is `i32`
/// class ids.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub hw: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    fn pixels(&self) -> usize {
        self.hw * self.hw
    }

    /// Copy example `i`'s pixels into `out`.
    pub fn copy_example(&self, i: usize, out: &mut [f32]) {
        let p = self.pixels();
        out[..p].copy_from_slice(&self.x[i * p..(i + 1) * p]);
    }

    /// Materialize a subset by example indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let p = self.pixels();
        let mut x = Vec::with_capacity(idx.len() * p);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(&self.x[i * p..(i + 1) * p]);
            y.push(self.y[i]);
        }
        Dataset {
            x,
            y,
            hw: self.hw,
            num_classes: self.num_classes,
        }
    }

    /// Gather a batch (with wraparound) starting at a cursor over a
    /// permutation — the per-UE minibatch iterator the FL engine uses.
    pub fn fill_batch(
        &self,
        order: &[usize],
        cursor: usize,
        x_out: &mut [f32],
        y_out: &mut [i32],
    ) -> usize {
        let p = self.pixels();
        let batch = y_out.len();
        let mut cur = cursor;
        for i in 0..batch {
            let idx = order[cur % order.len()];
            x_out[i * p..(i + 1) * p].copy_from_slice(&self.x[idx * p..(idx + 1) * p]);
            y_out[i] = self.y[idx];
            cur += 1;
        }
        cur % order.len()
    }

    /// Per-class histogram (used by partitioner tests and non-IID stats).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &c in &self.y {
            h[c as usize] += 1;
        }
        h
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.x.len() != self.len() * self.pixels() {
            return Err(format!(
                "x length {} != {} examples x {} pixels",
                self.x.len(),
                self.len(),
                self.pixels()
            ));
        }
        for &c in &self.y {
            if c < 0 || c as usize >= self.num_classes {
                return Err(format!("label {c} out of range"));
            }
        }
        for &v in &self.x {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("pixel {v} outside [0,1]"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: vec![0.5; 3 * 4],
            y: vec![0, 1, 1],
            hw: 2,
            num_classes: 2,
        }
    }

    #[test]
    fn subset_and_histogram() {
        let d = tiny();
        d.validate().unwrap();
        let s = d.subset(&[1, 2]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.y, vec![1, 1]);
        assert_eq!(d.class_histogram(), vec![1, 2]);
    }

    #[test]
    fn fill_batch_wraps() {
        let d = tiny();
        let order = vec![0, 1, 2];
        let mut x = vec![0.0; 5 * 4];
        let mut y = vec![0i32; 5];
        let cur = d.fill_batch(&order, 0, &mut x, &mut y);
        assert_eq!(y, vec![0, 1, 1, 0, 1]);
        assert_eq!(cur, 2);
    }

    #[test]
    fn validate_rejects_bad() {
        let mut d = tiny();
        d.y[0] = 9;
        assert!(d.validate().is_err());
        let mut d2 = tiny();
        d2.x[0] = 2.0;
        assert!(d2.validate().is_err());
    }
}
