//! Partition a dataset across N UEs: IID (uniform shuffle) or label-skewed
//! non-IID via a per-UE Dirichlet class mixture (the standard FL
//! heterogeneity model).

use super::Dataset;
use crate::util::Rng;

/// IID partition: shuffle, then deal `per_ue` examples to each UE.
/// Requires `n_ues * per_ue <= dataset.len()`.
pub fn partition_iid(
    ds: &Dataset,
    n_ues: usize,
    per_ue: usize,
    rng: &mut Rng,
) -> Result<Vec<Dataset>, String> {
    if n_ues * per_ue > ds.len() {
        return Err(format!(
            "cannot deal {n_ues} x {per_ue} from {} examples",
            ds.len()
        ));
    }
    let perm = rng.permutation(ds.len());
    Ok((0..n_ues)
        .map(|u| ds.subset(&perm[u * per_ue..(u + 1) * per_ue]))
        .collect())
}

/// Dirichlet non-IID partition: UE u draws a class mixture
/// `p_u ~ Dir(alpha)`, then samples `per_ue` examples according to it
/// (with replacement across the class pools' order, without replacement
/// within a pool until exhausted, then wrapping).
pub fn partition_dirichlet(
    ds: &Dataset,
    n_ues: usize,
    per_ue: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Result<Vec<Dataset>, String> {
    if alpha <= 0.0 {
        return Err("alpha must be positive (use partition_iid for IID)".into());
    }
    // Class pools, shuffled.
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); ds.num_classes];
    for (i, &c) in ds.y.iter().enumerate() {
        pools[c as usize].push(i);
    }
    for pool in &mut pools {
        rng.shuffle(pool);
    }
    let mut cursor = vec![0usize; ds.num_classes];

    let mut out = Vec::with_capacity(n_ues);
    for _ in 0..n_ues {
        let mix = rng.dirichlet(alpha, ds.num_classes);
        let mut idx = Vec::with_capacity(per_ue);
        for _ in 0..per_ue {
            // Sample a class from the mixture, restricted to non-empty pools.
            let mut r = rng.f64();
            let mut class = ds.num_classes - 1;
            for (c, &p) in mix.iter().enumerate() {
                if r < p {
                    class = c;
                    break;
                }
                r -= p;
            }
            if pools[class].is_empty() {
                // Degenerate dataset (class absent): fall back to any class.
                class = (0..ds.num_classes)
                    .find(|&c| !pools[c].is_empty())
                    .ok_or("empty dataset")?;
            }
            let pool = &pools[class];
            let pick = pool[cursor[class] % pool.len()];
            cursor[class] += 1;
            idx.push(pick);
        }
        out.push(ds.subset(&idx));
    }
    Ok(out)
}

/// Non-IID-ness diagnostic: mean total-variation distance between each
/// UE's class distribution and the global one. 0 = perfectly IID.
pub fn label_skew(shards: &[Dataset]) -> f64 {
    if shards.is_empty() {
        return 0.0;
    }
    let k = shards[0].num_classes;
    let mut global = vec![0.0f64; k];
    let mut total = 0.0;
    for s in shards {
        for (c, &n) in s.class_histogram().iter().enumerate() {
            global[c] += n as f64;
            total += n as f64;
        }
    }
    for g in &mut global {
        *g /= total;
    }
    let mut acc = 0.0;
    for s in shards {
        let h = s.class_histogram();
        let n: usize = h.iter().sum();
        let tv: f64 = h
            .iter()
            .enumerate()
            .map(|(c, &cnt)| (cnt as f64 / n as f64 - global[c]).abs())
            .sum::<f64>()
            / 2.0;
        acc += tv;
    }
    acc / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn base() -> Dataset {
        generate(&SyntheticConfig::default(), 600, 1)
    }

    #[test]
    fn iid_shapes_and_disjoint() {
        let ds = base();
        let mut rng = Rng::new(2);
        let shards = partition_iid(&ds, 10, 50, &mut rng).unwrap();
        assert_eq!(shards.len(), 10);
        assert!(shards.iter().all(|s| s.len() == 50));
        // IID skew should be small.
        assert!(label_skew(&shards) < 0.25, "skew {}", label_skew(&shards));
    }

    #[test]
    fn iid_over_allocation_rejected() {
        let ds = base();
        let mut rng = Rng::new(2);
        assert!(partition_iid(&ds, 10, 100, &mut rng).is_err());
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let ds = base();
        let mut rng = Rng::new(3);
        let skewed = partition_dirichlet(&ds, 10, 50, 0.1, &mut rng).unwrap();
        let mut rng2 = Rng::new(3);
        let mild = partition_dirichlet(&ds, 10, 50, 100.0, &mut rng2).unwrap();
        assert!(
            label_skew(&skewed) > label_skew(&mild),
            "skewed {} vs mild {}",
            label_skew(&skewed),
            label_skew(&mild)
        );
        assert!(skewed.iter().all(|s| s.len() == 50));
    }

    #[test]
    fn dirichlet_rejects_bad_alpha() {
        let ds = base();
        let mut rng = Rng::new(4);
        assert!(partition_dirichlet(&ds, 5, 10, 0.0, &mut rng).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = base();
        let a = partition_dirichlet(&ds, 5, 20, 0.5, &mut Rng::new(7)).unwrap();
        let b = partition_dirichlet(&ds, 5, 20, 0.5, &mut Rng::new(7)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.y, y.y);
        }
    }
}
