//! The discrete-event engine.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::delay::{cloud_rounds_int, DelayInstance};
use crate::util::Rng;

/// Total-order wrapper for event timestamps.
///
/// `Ord` is the single source of truth: it uses IEEE-754 `total_cmp`, which
/// is total and panic-free (a NaN timestamp — impossible from the delay
/// model, but conceivable from a hostile spec — sorts last instead of
/// aborting mid-heap-operation). `PartialOrd`/`PartialEq` delegate *to*
/// `cmp`, never the other way around, so the four trait impls can't
/// disagree (the seed had `cmp` → inner `partial_cmp` → panic on NaN, with
/// derived `PartialEq` that ordered -0.0/+0.0 differently than `cmp`).
#[derive(Debug, Clone, Copy)]
struct OrdF64(f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Local iterations per edge round (paper: a).
    pub a: u64,
    /// Edge rounds per cloud round (paper: b).
    pub b: u64,
    /// Cloud rounds; `None` = derive from the accuracy model (⌈R⌉).
    pub rounds: Option<u64>,
    /// Lognormal jitter sigma on every compute/upload duration
    /// (0 = deterministic).
    pub jitter_sigma: f64,
    /// Probability a UE drops out of a given edge round.
    pub dropout_prob: f64,
    /// RNG seed for jitter/dropout.
    pub seed: u64,
    /// Absolute time the first round starts at. The scenario engine chains
    /// epochs by carrying one epoch's end time into the next epoch's
    /// `start_s`, so makespans accrue bit-exactly across re-solves.
    pub start_s: f64,
    /// Per-edge-round aggregation deadline τ_dl (seconds), measured from
    /// the round's start. A scheduled upload arriving after
    /// `t0 + deadline_s` is dropped at the barrier (counted in
    /// [`SimResult::late_uploads`]) and the barrier then closes exactly
    /// at the deadline — the edge cannot know further uploads stopped
    /// coming, so it waits the whole window out. `f64::INFINITY`
    /// (the default) disables the deadline: the barrier waits for the
    /// slowest scheduled member, the pre-deadline behavior.
    pub deadline_s: f64,
}

impl SimConfig {
    pub fn deterministic(a: u64, b: u64) -> SimConfig {
        SimConfig {
            a,
            b,
            rounds: None,
            jitter_sigma: 0.0,
            dropout_prob: 0.0,
            seed: 0,
            start_s: 0.0,
            deadline_s: f64::INFINITY,
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Absolute completion time (seconds): `start_s` + the makespan of the
    /// simulated rounds. With the default `start_s = 0` this is the plain
    /// protocol makespan.
    pub total_time_s: f64,
    /// Completion time of each cloud round.
    pub round_end_s: Vec<f64>,
    /// Events processed (engine throughput metric).
    pub events: u64,
    /// UE-round uploads dropped by failure injection.
    pub dropped_uploads: u64,
    /// UE-round uploads that missed the aggregation deadline (scheduled
    /// and computed, but arrived after the barrier closed).
    pub late_uploads: u64,
    /// UE-round uploads scheduled in total (every member of every edge
    /// round, dropouts and stragglers included) — the denominator of the
    /// participation rate.
    pub scheduled_uploads: u64,
    /// Cumulative time edges spent waiting at the cloud barrier.
    pub edge_barrier_wait_s: f64,
    /// Cumulative time the per-edge aggregation barrier waited — against
    /// the barrier that *actually closed*: the slowest aggregated member
    /// without a deadline, the deadline itself when it dropped someone.
    pub ue_barrier_wait_s: f64,
    /// Cloud rounds executed.
    pub rounds: u64,
}

impl SimResult {
    /// Uploads that made their barrier: scheduled − dropout − late.
    pub fn delivered_uploads(&self) -> u64 {
        self.scheduled_uploads - self.dropped_uploads - self.late_uploads
    }

    /// Fraction of scheduled uploads aggregated (1.0 when nothing ran).
    pub fn participation_rate(&self) -> f64 {
        if self.scheduled_uploads == 0 {
            1.0
        } else {
            self.delivered_uploads() as f64 / self.scheduled_uploads as f64
        }
    }

    /// Emit this chunk's per-round completion clocks as one trace record.
    /// The clocks are *simulated* time — deterministic trace content, not
    /// measured wall time.
    pub fn trace_rounds(&self, epoch: u64, sink: &mut dyn crate::trace::TraceSink) {
        if sink.enabled() && !self.round_end_s.is_empty() {
            sink.rounds(epoch, &self.round_end_s);
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// UE `ue_slot` of edge `edge` delivered its model for edge round `k`.
    /// Declared before [`Event::BarrierDeadline`] so an upload landing
    /// exactly on the deadline aggregates before the barrier closes.
    UeUploadDone { edge: usize, ue_slot: usize, k: u64 },
    /// τ_dl elapsed for edge round `k` of `edge`: the barrier closes now.
    /// Only scheduled when some member of the round missed the deadline.
    BarrierDeadline { edge: usize, k: u64 },
    /// Edge `edge` delivered its aggregate to the cloud.
    EdgeUploadDone { edge: usize },
}

type Heap = BinaryHeap<Reverse<(OrdF64, Event)>>;

/// Jittered duration: lognormal multiplier with median 1 (no rng draw at
/// σ = 0, keeping the deterministic stream byte-compatible).
#[inline]
fn dur(base: f64, sigma: f64, rng: &mut Rng) -> f64 {
    if sigma <= 0.0 {
        base
    } else {
        base * (sigma * rng.normal()).exp()
    }
}

/// Start edge round `k` of `edge` at `t0`: draw dropout + jitter per
/// member (identical draw order to the pre-deadline engine), enqueue the
/// arrivals that make the deadline, and — when some scheduled member
/// misses τ_dl — enqueue the barrier's forced close at `t0 + τ_dl`.
/// Returns `(ontime, forced)`: `forced` means the barrier closes at the
/// deadline rather than on the last arrival. When every member dropped
/// out (and nobody was merely late) the edge skips its remaining edge
/// rounds and forwards the stale aggregate immediately, exactly like the
/// pre-deadline engine; a round whose members all *missed the deadline*
/// instead waits the window out and continues — the edge only learns the
/// uploads are missing once τ_dl elapses.
#[allow(clippy::too_many_arguments)]
fn launch_round(
    inst: &DelayInstance,
    cfg: &SimConfig,
    edge: usize,
    k: u64,
    t0: f64,
    rng: &mut Rng,
    heap: &mut Heap,
    result: &mut SimResult,
) -> (usize, bool) {
    let e = &inst.per_edge[edge];
    let mut ontime = 0usize;
    let mut late = 0u64;
    for (slot, &(cmp, com)) in e.ue.iter().enumerate() {
        result.scheduled_uploads += 1;
        if cfg.dropout_prob > 0.0 && rng.f64() < cfg.dropout_prob {
            result.dropped_uploads += 1;
            continue;
        }
        let t =
            t0 + cfg.a as f64 * dur(cmp, cfg.jitter_sigma, rng) + dur(com, cfg.jitter_sigma, rng);
        if t > t0 + cfg.deadline_s {
            result.late_uploads += 1;
            late += 1;
            continue;
        }
        ontime += 1;
        heap.push(Reverse((
            OrdF64(t),
            Event::UeUploadDone { edge, ue_slot: slot, k },
        )));
    }
    let forced = late > 0;
    if forced {
        heap.push(Reverse((
            OrdF64(t0 + cfg.deadline_s),
            Event::BarrierDeadline { edge, k },
        )));
    } else if ontime == 0 {
        // Every member dropped out this round: the edge skips its b edge
        // rounds and forwards the stale aggregate.
        let tb = t0 + dur(e.backhaul_s, cfg.jitter_sigma, rng);
        heap.push(Reverse((OrdF64(tb), Event::EdgeUploadDone { edge })));
    }
    (ontime, forced)
}

/// Advance `edge` past an aggregation barrier that closed at `t_close`:
/// account the straggler wait against the close that actually happened,
/// then start the next edge round or upload the aggregate to the cloud.
#[allow(clippy::too_many_arguments)]
fn advance_edge(
    inst: &DelayInstance,
    cfg: &SimConfig,
    edge: usize,
    t_close: f64,
    rng: &mut Rng,
    heap: &mut Heap,
    result: &mut SimResult,
    edge_round: &mut [u64],
    pending: &mut [usize],
    forced: &mut [bool],
    first_arrival: &mut [f64],
) {
    // Straggler cost: barrier close − first arrival. Without a deadline
    // the close IS the last arrival (the historical accounting); with a
    // forced close it is the deadline, never the late member that was
    // dropped (the pre-fix accounting would have charged the barrier for
    // an upload it did not wait for).
    if first_arrival[edge].is_finite() {
        result.ue_barrier_wait_s += t_close - first_arrival[edge];
    }
    first_arrival[edge] = f64::INFINITY;
    edge_round[edge] += 1;
    if edge_round[edge] < cfg.b {
        let (ontime, f) =
            launch_round(inst, cfg, edge, edge_round[edge], t_close, rng, heap, result);
        pending[edge] = ontime;
        forced[edge] = f;
    } else {
        // b edge rounds done: upload aggregate to the cloud.
        let tb = t_close + dur(inst.per_edge[edge].backhaul_s, cfg.jitter_sigma, rng);
        heap.push(Reverse((OrdF64(tb), Event::EdgeUploadDone { edge })));
    }
}

/// Run the protocol. See module docs.
pub fn simulate(inst: &DelayInstance, cfg: &SimConfig) -> SimResult {
    let rounds = cfg.rounds.unwrap_or_else(|| {
        cloud_rounds_int(
            cfg.a as f64,
            cfg.b as f64,
            inst.eps,
            inst.c_const,
            inst.gamma,
            inst.zeta,
        )
    });
    // hfl-lint: allow(R4, simulator noise stream is rooted at the caller-forked cfg.seed)
    let mut rng = Rng::new(cfg.seed);
    let m_edges = inst.per_edge.len();

    let mut result = SimResult {
        total_time_s: 0.0,
        round_end_s: Vec::with_capacity(rounds as usize),
        events: 0,
        dropped_uploads: 0,
        late_uploads: 0,
        scheduled_uploads: 0,
        edge_barrier_wait_s: 0.0,
        ue_barrier_wait_s: 0.0,
        rounds,
    };

    // Edges without members do not take part in a round at all: nothing
    // to aggregate, nothing to upload (matching `DelayInstance::round_time`,
    // which excludes memberless edges from T(a,b)). Edges whose members
    // all *drop out* in a given round still forward their stale aggregate
    // — that is the partial-participation path below, not this one.
    let participating = inst.per_edge.iter().filter(|e| !e.ue.is_empty()).count();

    let mut now = cfg.start_s;
    for _round in 0..rounds {
        let mut heap: Heap = BinaryHeap::new();

        // Edge state for this cloud round.
        let mut edge_round: Vec<u64> = vec![0; m_edges]; // current k
        let mut pending: Vec<usize> = vec![0; m_edges]; // uploads still awaited
        let mut forced: Vec<bool> = vec![false; m_edges]; // deadline closes the barrier
        let mut first_arrival: Vec<f64> = vec![f64::INFINITY; m_edges];
        let mut edges_pending = participating;
        let mut edge_done_at: Vec<f64> = vec![f64::NAN; m_edges];

        // Kick off edge round 0 at `now` for every participating edge.
        for m in 0..m_edges {
            if inst.per_edge[m].ue.is_empty() {
                continue;
            }
            let (ontime, f) = launch_round(inst, cfg, m, 0, now, &mut rng, &mut heap, &mut result);
            pending[m] = ontime;
            forced[m] = f;
        }

        let mut cloud_round_end = now;
        while let Some(Reverse((OrdF64(t), ev))) = heap.pop() {
            result.events += 1;
            match ev {
                Event::UeUploadDone { edge, ue_slot, k } => {
                    debug_assert_eq!(k, edge_round[edge]);
                    let _ = ue_slot;
                    first_arrival[edge] = first_arrival[edge].min(t);
                    pending[edge] -= 1;
                    // A forced barrier holds until its deadline even once
                    // every on-time member arrived.
                    if pending[edge] == 0 && !forced[edge] {
                        advance_edge(
                            inst,
                            cfg,
                            edge,
                            t,
                            &mut rng,
                            &mut heap,
                            &mut result,
                            &mut edge_round,
                            &mut pending,
                            &mut forced,
                            &mut first_arrival,
                        );
                    }
                }
                Event::BarrierDeadline { edge, k } => {
                    // Every on-time arrival of this round timestamps at or
                    // before the deadline (and the UeUploadDone variant
                    // wins timestamp ties), so the round's arrivals are
                    // all accounted for by now.
                    debug_assert_eq!(k, edge_round[edge]);
                    debug_assert!(forced[edge]);
                    debug_assert_eq!(pending[edge], 0);
                    forced[edge] = false;
                    advance_edge(
                        inst,
                        cfg,
                        edge,
                        t,
                        &mut rng,
                        &mut heap,
                        &mut result,
                        &mut edge_round,
                        &mut pending,
                        &mut forced,
                        &mut first_arrival,
                    );
                }
                Event::EdgeUploadDone { edge } => {
                    edge_done_at[edge] = t;
                    edges_pending -= 1;
                    cloud_round_end = cloud_round_end.max(t);
                    if edges_pending == 0 {
                        break;
                    }
                }
            }
        }
        // Cloud barrier wait accounting (participating edges only; the
        // excluded ones kept their NaN sentinel).
        for &done in &edge_done_at {
            if done.is_finite() {
                result.edge_barrier_wait_s += cloud_round_end - done;
            }
        }
        now = cloud_round_end;
        result.round_end_s.push(now);
    }
    result.total_time_s = now;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayInstance, EdgeDelays};

    fn inst() -> DelayInstance {
        DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.005, 0.3), (0.008, 0.2)],
                    backhaul_s: 0.01,
                },
                EdgeDelays {
                    ue: vec![(0.004, 0.25), (0.010, 0.15), (0.002, 0.4)],
                    backhaul_s: 0.02,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        }
    }

    #[test]
    fn deterministic_matches_closed_form() {
        let i = inst();
        for &(a, b) in &[(1u64, 1u64), (10, 5), (35, 5), (30, 7)] {
            let cfg = SimConfig::deterministic(a, b);
            let res = simulate(&i, &cfg);
            let rounds = cloud_rounds_int(a as f64, b as f64, i.eps, i.c_const, i.gamma, i.zeta);
            let expect = rounds as f64 * i.round_time(a as f64, b as f64);
            assert!(
                (res.total_time_s - expect).abs() < 1e-9,
                "a={a} b={b}: sim {} vs closed form {expect}",
                res.total_time_s
            );
            assert_eq!(res.rounds, rounds);
            assert_eq!(res.round_end_s.len(), rounds as usize);
        }
    }

    #[test]
    fn explicit_round_count_respected() {
        let i = inst();
        let cfg = SimConfig {
            rounds: Some(3),
            ..SimConfig::deterministic(10, 4)
        };
        let res = simulate(&i, &cfg);
        assert_eq!(res.rounds, 3);
        let expect = 3.0 * i.round_time(10.0, 4.0);
        assert!((res.total_time_s - expect).abs() < 1e-9);
    }

    #[test]
    fn jitter_changes_but_stays_near_deterministic() {
        let i = inst();
        let det = simulate(&i, &SimConfig::deterministic(10, 4)).total_time_s;
        let cfg = SimConfig {
            jitter_sigma: 0.1,
            seed: 7,
            ..SimConfig::deterministic(10, 4)
        };
        let jit = simulate(&i, &cfg).total_time_s;
        assert!(jit != det);
        // Max-of-lognormals has positive bias: jittered ≥ 0.8x det, ≤ 2x.
        assert!(jit > det * 0.8 && jit < det * 2.0, "jit {jit} det {det}");
    }

    #[test]
    fn dropout_reduces_or_keeps_makespan_and_counts_drops() {
        let i = inst();
        let cfg = SimConfig {
            dropout_prob: 0.5,
            seed: 3,
            ..SimConfig::deterministic(10, 4)
        };
        let res = simulate(&i, &cfg);
        assert!(res.dropped_uploads > 0);
        // Dropping stragglers can only shorten a barrier round.
        let det = simulate(&i, &SimConfig::deterministic(10, 4));
        assert!(res.total_time_s <= det.total_time_s + 1e-9);
    }

    #[test]
    fn full_dropout_still_terminates() {
        let i = inst();
        let cfg = SimConfig {
            dropout_prob: 1.0,
            seed: 1,
            ..SimConfig::deterministic(10, 4)
        };
        let res = simulate(&i, &cfg);
        // Only backhaul remains.
        let expect_round = i
            .per_edge
            .iter()
            .map(|e| e.backhaul_s)
            .fold(0.0, f64::max);
        assert!((res.total_time_s - res.rounds as f64 * expect_round).abs() < 1e-9);
    }

    #[test]
    fn memberless_edge_does_not_gate_the_round() {
        // Regression: a churn-emptied edge used to inject its backhaul
        // into every cloud round (here 9 s/round vs the live edge's
        // ~1.06 s), in both the simulator and the closed form.
        let i = DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.005, 0.3)],
                    backhaul_s: 0.01,
                },
                EdgeDelays {
                    ue: vec![],
                    backhaul_s: 9.0,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        let res = simulate(&i, &SimConfig::deterministic(10, 3));
        let expect = res.rounds as f64 * i.round_time(10.0, 3.0);
        assert!((res.total_time_s - expect).abs() < 1e-9);
        assert!(
            res.total_time_s < 5.0,
            "empty edge's 9s backhaul leaked into the makespan: {}",
            res.total_time_s
        );
        // A fully-drained instance terminates with zero-time rounds.
        let ghost = DelayInstance {
            per_edge: vec![EdgeDelays {
                ue: vec![],
                backhaul_s: 3.0,
            }],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        let res = simulate(&ghost, &SimConfig::deterministic(5, 2));
        assert_eq!(res.total_time_s, 0.0);
        assert_eq!(res.events, 0);
    }

    #[test]
    fn barrier_waits_nonnegative() {
        let i = inst();
        let res = simulate(&i, &SimConfig::deterministic(20, 6));
        assert!(res.edge_barrier_wait_s >= 0.0);
        assert!(res.ue_barrier_wait_s >= 0.0);
        assert!(res.events > 0);
    }

    #[test]
    fn ordf64_total_order_on_equal_timestamps() {
        use std::cmp::Ordering;
        // Equal timestamps — the case two UEs finishing simultaneously
        // produces — must compare Equal through every trait consistently.
        let (x, y) = (OrdF64(1.25), OrdF64(1.25));
        assert_eq!(x.cmp(&y), Ordering::Equal);
        assert_eq!(x.partial_cmp(&y), Some(Ordering::Equal));
        assert!(x == y);
        // Ordering is total and panic-free, NaN included (sorts after
        // every finite value instead of aborting the heap operation).
        assert_eq!(OrdF64(1.0).cmp(&OrdF64(2.0)), Ordering::Less);
        assert_eq!(OrdF64(f64::NAN).cmp(&OrdF64(f64::INFINITY)), Ordering::Greater);
        assert_eq!(OrdF64(f64::NAN).cmp(&OrdF64(f64::NAN)), Ordering::Equal);
        // A heap of duplicated timestamps drains without panicking and in
        // nondecreasing order.
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<OrdF64>> =
            [2.0, 1.0, 1.0, 3.0, 1.0]
                .into_iter()
                .map(|t| std::cmp::Reverse(OrdF64(t)))
                .collect();
        let mut prev = f64::NEG_INFINITY;
        while let Some(std::cmp::Reverse(OrdF64(t))) = heap.pop() {
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn start_offset_chains_epochs_bit_exactly() {
        // Running R rounds in one call must equal running them in two
        // chained calls whose second starts where the first ended — the
        // identity the scenario engine's epoch accrual rests on.
        let i = inst();
        let whole = simulate(
            &i,
            &SimConfig {
                rounds: Some(6),
                ..SimConfig::deterministic(10, 4)
            },
        );
        let first = simulate(
            &i,
            &SimConfig {
                rounds: Some(2),
                ..SimConfig::deterministic(10, 4)
            },
        );
        let second = simulate(
            &i,
            &SimConfig {
                rounds: Some(4),
                start_s: first.total_time_s,
                ..SimConfig::deterministic(10, 4)
            },
        );
        assert_eq!(whole.total_time_s.to_bits(), second.total_time_s.to_bits());
    }

    #[test]
    fn seeded_runs_reproduce() {
        let i = inst();
        let cfg = SimConfig {
            jitter_sigma: 0.2,
            dropout_prob: 0.1,
            seed: 99,
            ..SimConfig::deterministic(8, 3)
        };
        let r1 = simulate(&i, &cfg);
        let r2 = simulate(&i, &cfg);
        assert_eq!(r1.total_time_s, r2.total_time_s);
        assert_eq!(r1.dropped_uploads, r2.dropped_uploads);
    }

    /// One slow straggler: arrivals at t0+0.1 and t0+1.0 each edge round.
    fn straggler_inst() -> DelayInstance {
        DelayInstance {
            per_edge: vec![EdgeDelays {
                ue: vec![(0.0, 0.1), (0.0, 1.0)],
                backhaul_s: 0.05,
            }],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        }
    }

    #[test]
    fn no_deadline_never_schedules_a_forced_close_bitwise() {
        // deadline = ∞ and "deadline so large nobody is late" must be the
        // same simulation, bit for bit, jitter/dropout rng stream
        // included — the strict-generalization property at the sim level.
        let i = inst();
        let base = SimConfig {
            jitter_sigma: 0.15,
            dropout_prob: 0.05,
            seed: 42,
            ..SimConfig::deterministic(12, 4)
        };
        let huge = SimConfig {
            deadline_s: 1e12,
            ..base.clone()
        };
        let a = simulate(&i, &base);
        let b = simulate(&i, &huge);
        assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!(a.dropped_uploads, b.dropped_uploads);
        assert_eq!(a.ue_barrier_wait_s.to_bits(), b.ue_barrier_wait_s.to_bits());
        assert_eq!(b.late_uploads, 0);
        assert_eq!(a.scheduled_uploads, b.scheduled_uploads);
    }

    #[test]
    fn straggler_wait_pinned_without_deadline() {
        // Regression pin of the pre-deadline accounting: the barrier
        // closes on the slowest scheduled member, and the straggler wait
        // is (last − first) per edge round.
        let i = straggler_inst();
        let cfg = SimConfig {
            rounds: Some(3),
            ..SimConfig::deterministic(1, 2)
        };
        let res = simulate(&i, &cfg);
        // τ = max(0.1, 1.0) = 1.0; T = 2·1.0 + 0.05 per cloud round.
        assert!((res.total_time_s - 3.0 * 2.05).abs() < 1e-9);
        // Wait = 1.0 − 0.1 = 0.9 per edge round, 2 per cloud round, 3 rounds.
        assert!((res.ue_barrier_wait_s - 0.9 * 6.0).abs() < 1e-9);
        assert_eq!(res.late_uploads, 0);
        assert_eq!(res.scheduled_uploads, 12);
        assert_eq!(res.delivered_uploads(), 12);
        assert!((res.participation_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deadline_drops_stragglers_and_closes_at_the_deadline() {
        let i = straggler_inst();
        let cfg = SimConfig {
            rounds: Some(3),
            deadline_s: 0.5,
            ..SimConfig::deterministic(1, 2)
        };
        let res = simulate(&i, &cfg);
        // The slow member (arrival +1.0) misses τ_dl = 0.5 every round:
        // the barrier closes at exactly the deadline.
        assert!((res.total_time_s - 3.0 * (2.0 * 0.5 + 0.05)).abs() < 1e-9);
        assert_eq!(res.late_uploads, 6, "one late member x 2 edge rounds x 3");
        assert_eq!(res.dropped_uploads, 0);
        assert_eq!(res.scheduled_uploads, 12);
        assert_eq!(res.delivered_uploads(), 6);
        assert!((res.participation_rate() - 0.5).abs() < 1e-12);
        // Straggler wait is measured against the barrier that actually
        // closed (the deadline), NOT the slowest scheduled member:
        // 0.5 − 0.1 per edge round — not the pre-fix 1.0 − 0.1.
        assert!((res.ue_barrier_wait_s - 0.4 * 6.0).abs() < 1e-9);
    }

    #[test]
    fn arrival_exactly_on_the_deadline_is_aggregated() {
        let i = DelayInstance {
            per_edge: vec![EdgeDelays {
                ue: vec![(0.0, 0.5)],
                backhaul_s: 0.1,
            }],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        let cfg = SimConfig {
            rounds: Some(2),
            deadline_s: 0.5,
            ..SimConfig::deterministic(1, 1)
        };
        let res = simulate(&i, &cfg);
        assert_eq!(res.late_uploads, 0, "t == t0 + τ_dl is on time");
        assert!((res.total_time_s - 2.0 * 0.6).abs() < 1e-9);
        assert!((res.participation_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_late_round_waits_out_the_deadline_and_continues() {
        // A round whose only member misses τ_dl: the edge cannot skip
        // ahead (it only learns the upload is missing at the deadline),
        // so each edge round costs exactly τ_dl and the stale aggregate
        // goes up after the b rounds.
        let i = DelayInstance {
            per_edge: vec![EdgeDelays {
                ue: vec![(0.0, 1.0)],
                backhaul_s: 0.1,
            }],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        let cfg = SimConfig {
            rounds: Some(2),
            deadline_s: 0.5,
            ..SimConfig::deterministic(1, 2)
        };
        let res = simulate(&i, &cfg);
        assert!((res.total_time_s - 2.0 * (2.0 * 0.5 + 0.1)).abs() < 1e-9);
        assert_eq!(res.late_uploads, 4);
        assert_eq!(res.delivered_uploads(), 0);
        assert_eq!(res.participation_rate(), 0.0);
        // Nobody arrived: no straggler wait accrues.
        assert_eq!(res.ue_barrier_wait_s, 0.0);
    }

    #[test]
    fn deadline_with_jitter_and_dropout_reproduces_and_terminates() {
        // No cross-run makespan comparison here: with a shared rng,
        // barrier-close order differs between deadline and no-deadline
        // runs, so later draws land on different edges and the two runs
        // simulate *different* random worlds (the deadline-shortens-
        // barriers property only holds per-realization, i.e. in the
        // deterministic tests above and the jitter-free scenario test).
        let i = inst();
        let cfg = SimConfig {
            jitter_sigma: 0.3,
            dropout_prob: 0.1,
            deadline_s: 0.6,
            seed: 17,
            ..SimConfig::deterministic(10, 4)
        };
        let r1 = simulate(&i, &cfg);
        let r2 = simulate(&i, &cfg);
        assert_eq!(r1.total_time_s.to_bits(), r2.total_time_s.to_bits());
        assert_eq!(r1.late_uploads, r2.late_uploads);
        assert_eq!(
            r1.scheduled_uploads,
            r1.delivered_uploads() + r1.dropped_uploads + r1.late_uploads
        );
        assert!(r1.total_time_s.is_finite() && r1.total_time_s > 0.0);
        // Every edge round is bounded by its deadline, so the makespan is
        // bounded by rounds·(b·τ_dl + jittered backhaul) — sanity-check a
        // generous version of that bound instead of a cross-run one.
        let backhaul_max = i.per_edge.iter().map(|e| e.backhaul_s).fold(0.0, f64::max);
        let bound = r1.rounds as f64 * (4.0 * 0.6 + 100.0 * backhaul_max);
        assert!(r1.total_time_s <= bound, "{} > {bound}", r1.total_time_s);
    }
}
