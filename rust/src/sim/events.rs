//! The discrete-event engine.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::delay::{cloud_rounds_int, DelayInstance};
use crate::util::Rng;

/// Total-order wrapper for event timestamps.
///
/// `Ord` is the single source of truth: it uses IEEE-754 `total_cmp`, which
/// is total and panic-free (a NaN timestamp — impossible from the delay
/// model, but conceivable from a hostile spec — sorts last instead of
/// aborting mid-heap-operation). `PartialOrd`/`PartialEq` delegate *to*
/// `cmp`, never the other way around, so the four trait impls can't
/// disagree (the seed had `cmp` → inner `partial_cmp` → panic on NaN, with
/// derived `PartialEq` that ordered -0.0/+0.0 differently than `cmp`).
#[derive(Debug, Clone, Copy)]
struct OrdF64(f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Local iterations per edge round (paper: a).
    pub a: u64,
    /// Edge rounds per cloud round (paper: b).
    pub b: u64,
    /// Cloud rounds; `None` = derive from the accuracy model (⌈R⌉).
    pub rounds: Option<u64>,
    /// Lognormal jitter sigma on every compute/upload duration
    /// (0 = deterministic).
    pub jitter_sigma: f64,
    /// Probability a UE drops out of a given edge round.
    pub dropout_prob: f64,
    /// RNG seed for jitter/dropout.
    pub seed: u64,
    /// Absolute time the first round starts at. The scenario engine chains
    /// epochs by carrying one epoch's end time into the next epoch's
    /// `start_s`, so makespans accrue bit-exactly across re-solves.
    pub start_s: f64,
}

impl SimConfig {
    pub fn deterministic(a: u64, b: u64) -> SimConfig {
        SimConfig {
            a,
            b,
            rounds: None,
            jitter_sigma: 0.0,
            dropout_prob: 0.0,
            seed: 0,
            start_s: 0.0,
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Absolute completion time (seconds): `start_s` + the makespan of the
    /// simulated rounds. With the default `start_s = 0` this is the plain
    /// protocol makespan.
    pub total_time_s: f64,
    /// Completion time of each cloud round.
    pub round_end_s: Vec<f64>,
    /// Events processed (engine throughput metric).
    pub events: u64,
    /// UE-round uploads dropped by failure injection.
    pub dropped_uploads: u64,
    /// Cumulative time edges spent waiting at the cloud barrier.
    pub edge_barrier_wait_s: f64,
    /// Cumulative time the per-edge aggregation barrier waited on its
    /// slowest member (straggler cost).
    pub ue_barrier_wait_s: f64,
    /// Cloud rounds executed.
    pub rounds: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// UE `ue_slot` of edge `edge` delivered its model for edge round `k`.
    UeUploadDone { edge: usize, ue_slot: usize, k: u64 },
    /// Edge `edge` delivered its aggregate to the cloud.
    EdgeUploadDone { edge: usize },
}

/// Run the protocol. See module docs.
pub fn simulate(inst: &DelayInstance, cfg: &SimConfig) -> SimResult {
    let rounds = cfg.rounds.unwrap_or_else(|| {
        cloud_rounds_int(
            cfg.a as f64,
            cfg.b as f64,
            inst.eps,
            inst.c_const,
            inst.gamma,
            inst.zeta,
        )
    });
    let mut rng = Rng::new(cfg.seed);
    let m_edges = inst.per_edge.len();

    let mut result = SimResult {
        total_time_s: 0.0,
        round_end_s: Vec::with_capacity(rounds as usize),
        events: 0,
        dropped_uploads: 0,
        edge_barrier_wait_s: 0.0,
        ue_barrier_wait_s: 0.0,
        rounds,
    };

    // Jittered duration: lognormal multiplier with median 1.
    let dur = |base: f64, rng: &mut Rng| -> f64 {
        if cfg.jitter_sigma <= 0.0 {
            base
        } else {
            base * (cfg.jitter_sigma * rng.normal()).exp()
        }
    };

    // Edges without members do not take part in a round at all: nothing
    // to aggregate, nothing to upload (matching `DelayInstance::round_time`,
    // which excludes memberless edges from T(a,b)). Edges whose members
    // all *drop out* in a given round still forward their stale aggregate
    // — that is the partial-participation path below, not this one.
    let participating = inst.per_edge.iter().filter(|e| !e.ue.is_empty()).count();

    let mut now = cfg.start_s;
    for _round in 0..rounds {
        let mut heap: BinaryHeap<Reverse<(OrdF64, Event)>> = BinaryHeap::new();

        // Edge state for this cloud round.
        let mut edge_round: Vec<u64> = vec![0; m_edges]; // current k
        let mut pending: Vec<usize> = vec![0; m_edges]; // uploads still awaited
        let mut first_arrival: Vec<f64> = vec![f64::INFINITY; m_edges];
        let mut edges_pending = participating;
        let mut edge_done_at: Vec<f64> = vec![f64::NAN; m_edges];

        // Kick off edge round 0 at `now` for every participating edge.
        for (m, e) in inst.per_edge.iter().enumerate() {
            if e.ue.is_empty() {
                continue;
            }
            let mut live = 0;
            for (slot, &(cmp, com)) in e.ue.iter().enumerate() {
                if cfg.dropout_prob > 0.0 && rng.f64() < cfg.dropout_prob {
                    result.dropped_uploads += 1;
                    continue;
                }
                live += 1;
                let t = now + cfg.a as f64 * dur(cmp, &mut rng) + dur(com, &mut rng);
                heap.push(Reverse((
                    OrdF64(t),
                    Event::UeUploadDone {
                        edge: m,
                        ue_slot: slot,
                        k: 0,
                    },
                )));
            }
            pending[m] = live;
            // Every member dropped out this round: the edge skips its b
            // edge rounds and forwards the stale aggregate.
            if live == 0 {
                let t = now + dur(e.backhaul_s, &mut rng);
                heap.push(Reverse((OrdF64(t), Event::EdgeUploadDone { edge: m })));
            }
        }

        let mut cloud_round_end = now;
        while let Some(Reverse((OrdF64(t), ev))) = heap.pop() {
            result.events += 1;
            match ev {
                Event::UeUploadDone { edge, ue_slot, k } => {
                    debug_assert_eq!(k, edge_round[edge]);
                    let _ = ue_slot;
                    first_arrival[edge] = first_arrival[edge].min(t);
                    pending[edge] -= 1;
                    if pending[edge] > 0 {
                        continue;
                    }
                    // Barrier complete: straggler wait = last - first.
                    if first_arrival[edge].is_finite() {
                        result.ue_barrier_wait_s += t - first_arrival[edge];
                    }
                    first_arrival[edge] = f64::INFINITY;
                    edge_round[edge] += 1;
                    if edge_round[edge] < cfg.b {
                        // Next edge round: every member restarts at `t`.
                        let k_next = edge_round[edge];
                        let mut live = 0;
                        for (slot, &(cmp, com)) in inst.per_edge[edge].ue.iter().enumerate() {
                            if cfg.dropout_prob > 0.0 && rng.f64() < cfg.dropout_prob {
                                result.dropped_uploads += 1;
                                continue;
                            }
                            live += 1;
                            let tn = t + cfg.a as f64 * dur(cmp, &mut rng) + dur(com, &mut rng);
                            heap.push(Reverse((
                                OrdF64(tn),
                                Event::UeUploadDone {
                                    edge,
                                    ue_slot: slot,
                                    k: k_next,
                                },
                            )));
                        }
                        pending[edge] = live;
                        if live == 0 {
                            // Everyone dropped: skip straight to backhaul.
                            let tb = t + dur(inst.per_edge[edge].backhaul_s, &mut rng);
                            heap.push(Reverse((OrdF64(tb), Event::EdgeUploadDone { edge })));
                        }
                    } else {
                        // b edge rounds done: upload aggregate to the cloud.
                        let tb = t + dur(inst.per_edge[edge].backhaul_s, &mut rng);
                        heap.push(Reverse((OrdF64(tb), Event::EdgeUploadDone { edge })));
                    }
                }
                Event::EdgeUploadDone { edge } => {
                    edge_done_at[edge] = t;
                    edges_pending -= 1;
                    cloud_round_end = cloud_round_end.max(t);
                    if edges_pending == 0 {
                        break;
                    }
                }
            }
        }
        // Cloud barrier wait accounting (participating edges only; the
        // excluded ones kept their NaN sentinel).
        for &done in &edge_done_at {
            if done.is_finite() {
                result.edge_barrier_wait_s += cloud_round_end - done;
            }
        }
        now = cloud_round_end;
        result.round_end_s.push(now);
    }
    result.total_time_s = now;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayInstance, EdgeDelays};

    fn inst() -> DelayInstance {
        DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.005, 0.3), (0.008, 0.2)],
                    backhaul_s: 0.01,
                },
                EdgeDelays {
                    ue: vec![(0.004, 0.25), (0.010, 0.15), (0.002, 0.4)],
                    backhaul_s: 0.02,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        }
    }

    #[test]
    fn deterministic_matches_closed_form() {
        let i = inst();
        for &(a, b) in &[(1u64, 1u64), (10, 5), (35, 5), (30, 7)] {
            let cfg = SimConfig::deterministic(a, b);
            let res = simulate(&i, &cfg);
            let rounds = cloud_rounds_int(a as f64, b as f64, i.eps, i.c_const, i.gamma, i.zeta);
            let expect = rounds as f64 * i.round_time(a as f64, b as f64);
            assert!(
                (res.total_time_s - expect).abs() < 1e-9,
                "a={a} b={b}: sim {} vs closed form {expect}",
                res.total_time_s
            );
            assert_eq!(res.rounds, rounds);
            assert_eq!(res.round_end_s.len(), rounds as usize);
        }
    }

    #[test]
    fn explicit_round_count_respected() {
        let i = inst();
        let cfg = SimConfig {
            rounds: Some(3),
            ..SimConfig::deterministic(10, 4)
        };
        let res = simulate(&i, &cfg);
        assert_eq!(res.rounds, 3);
        let expect = 3.0 * i.round_time(10.0, 4.0);
        assert!((res.total_time_s - expect).abs() < 1e-9);
    }

    #[test]
    fn jitter_changes_but_stays_near_deterministic() {
        let i = inst();
        let det = simulate(&i, &SimConfig::deterministic(10, 4)).total_time_s;
        let cfg = SimConfig {
            jitter_sigma: 0.1,
            seed: 7,
            ..SimConfig::deterministic(10, 4)
        };
        let jit = simulate(&i, &cfg).total_time_s;
        assert!(jit != det);
        // Max-of-lognormals has positive bias: jittered ≥ 0.8x det, ≤ 2x.
        assert!(jit > det * 0.8 && jit < det * 2.0, "jit {jit} det {det}");
    }

    #[test]
    fn dropout_reduces_or_keeps_makespan_and_counts_drops() {
        let i = inst();
        let cfg = SimConfig {
            dropout_prob: 0.5,
            seed: 3,
            ..SimConfig::deterministic(10, 4)
        };
        let res = simulate(&i, &cfg);
        assert!(res.dropped_uploads > 0);
        // Dropping stragglers can only shorten a barrier round.
        let det = simulate(&i, &SimConfig::deterministic(10, 4));
        assert!(res.total_time_s <= det.total_time_s + 1e-9);
    }

    #[test]
    fn full_dropout_still_terminates() {
        let i = inst();
        let cfg = SimConfig {
            dropout_prob: 1.0,
            seed: 1,
            ..SimConfig::deterministic(10, 4)
        };
        let res = simulate(&i, &cfg);
        // Only backhaul remains.
        let expect_round = i
            .per_edge
            .iter()
            .map(|e| e.backhaul_s)
            .fold(0.0, f64::max);
        assert!((res.total_time_s - res.rounds as f64 * expect_round).abs() < 1e-9);
    }

    #[test]
    fn memberless_edge_does_not_gate_the_round() {
        // Regression: a churn-emptied edge used to inject its backhaul
        // into every cloud round (here 9 s/round vs the live edge's
        // ~1.06 s), in both the simulator and the closed form.
        let i = DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.005, 0.3)],
                    backhaul_s: 0.01,
                },
                EdgeDelays {
                    ue: vec![],
                    backhaul_s: 9.0,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        let res = simulate(&i, &SimConfig::deterministic(10, 3));
        let expect = res.rounds as f64 * i.round_time(10.0, 3.0);
        assert!((res.total_time_s - expect).abs() < 1e-9);
        assert!(
            res.total_time_s < 5.0,
            "empty edge's 9s backhaul leaked into the makespan: {}",
            res.total_time_s
        );
        // A fully-drained instance terminates with zero-time rounds.
        let ghost = DelayInstance {
            per_edge: vec![EdgeDelays {
                ue: vec![],
                backhaul_s: 3.0,
            }],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        let res = simulate(&ghost, &SimConfig::deterministic(5, 2));
        assert_eq!(res.total_time_s, 0.0);
        assert_eq!(res.events, 0);
    }

    #[test]
    fn barrier_waits_nonnegative() {
        let i = inst();
        let res = simulate(&i, &SimConfig::deterministic(20, 6));
        assert!(res.edge_barrier_wait_s >= 0.0);
        assert!(res.ue_barrier_wait_s >= 0.0);
        assert!(res.events > 0);
    }

    #[test]
    fn ordf64_total_order_on_equal_timestamps() {
        use std::cmp::Ordering;
        // Equal timestamps — the case two UEs finishing simultaneously
        // produces — must compare Equal through every trait consistently.
        let (x, y) = (OrdF64(1.25), OrdF64(1.25));
        assert_eq!(x.cmp(&y), Ordering::Equal);
        assert_eq!(x.partial_cmp(&y), Some(Ordering::Equal));
        assert!(x == y);
        // Ordering is total and panic-free, NaN included (sorts after
        // every finite value instead of aborting the heap operation).
        assert_eq!(OrdF64(1.0).cmp(&OrdF64(2.0)), Ordering::Less);
        assert_eq!(OrdF64(f64::NAN).cmp(&OrdF64(f64::INFINITY)), Ordering::Greater);
        assert_eq!(OrdF64(f64::NAN).cmp(&OrdF64(f64::NAN)), Ordering::Equal);
        // A heap of duplicated timestamps drains without panicking and in
        // nondecreasing order.
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<OrdF64>> =
            [2.0, 1.0, 1.0, 3.0, 1.0]
                .into_iter()
                .map(|t| std::cmp::Reverse(OrdF64(t)))
                .collect();
        let mut prev = f64::NEG_INFINITY;
        while let Some(std::cmp::Reverse(OrdF64(t))) = heap.pop() {
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn start_offset_chains_epochs_bit_exactly() {
        // Running R rounds in one call must equal running them in two
        // chained calls whose second starts where the first ended — the
        // identity the scenario engine's epoch accrual rests on.
        let i = inst();
        let whole = simulate(
            &i,
            &SimConfig {
                rounds: Some(6),
                ..SimConfig::deterministic(10, 4)
            },
        );
        let first = simulate(
            &i,
            &SimConfig {
                rounds: Some(2),
                ..SimConfig::deterministic(10, 4)
            },
        );
        let second = simulate(
            &i,
            &SimConfig {
                rounds: Some(4),
                start_s: first.total_time_s,
                ..SimConfig::deterministic(10, 4)
            },
        );
        assert_eq!(whole.total_time_s.to_bits(), second.total_time_s.to_bits());
    }

    #[test]
    fn seeded_runs_reproduce() {
        let i = inst();
        let cfg = SimConfig {
            jitter_sigma: 0.2,
            dropout_prob: 0.1,
            seed: 99,
            ..SimConfig::deterministic(8, 3)
        };
        let r1 = simulate(&i, &cfg);
        let r2 = simulate(&i, &cfg);
        assert_eq!(r1.total_time_s, r2.total_time_s);
        assert_eq!(r1.dropped_uploads, r2.dropped_uploads);
    }
}
