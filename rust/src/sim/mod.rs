//! Event-driven latency simulator for the hierarchical FL protocol.
//!
//! Replays Algorithm 1's timing as discrete events — UE compute, UE→edge
//! upload, edge aggregation barrier, edge→cloud upload, cloud barrier —
//! over a [`DelayInstance`]. With deterministic delays the simulated
//! makespan equals the closed-form `R_int · T(a,b)` of `delay/` exactly
//! (property-tested), which validates both; the simulator additionally
//! supports what the closed form cannot express:
//!
//! * per-event lognormal jitter (`jitter_sigma`) — straggler modeling;
//! * per-round UE dropout (`dropout_prob`) — failure injection (the edge
//!   aggregates whoever arrived, like partial-participation FedAvg);
//! * deadline-aware aggregation (`deadline_s`): the per-edge barrier
//!   closes at τ_dl and drops late uploads as partial participation,
//!   with straggler-wait accounted against the barrier that actually
//!   closed;
//! * per-round timelines and barrier-wait accounting (who is the
//!   bottleneck, how much time edges idle at the cloud barrier);
//! * an absolute start offset (`SimConfig::start_s`) so the scenario
//!   engine (`scenario/`) can chain epoch simulations — re-associating and
//!   re-solving (a, b) between chunks of rounds — while the makespan
//!   accrues bit-exactly across the whole run.

pub mod events;

pub use events::{simulate, SimConfig, SimResult};
