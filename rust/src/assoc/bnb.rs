//! Exact solvers for the association MILP (paper problem (39)).
//!
//! Two independent exact methods, used to measure the optimality gap of
//! Algorithm 3 (`benches/assoc_optimality.rs`):
//!
//! * [`solve_exact_bnb`] — depth-first branch-and-bound over χ, the
//!   approach the paper names (and dismisses as exponential). Practical
//!   for N ≲ 16.
//! * [`solve_exact_matching`] — a polynomial exact method the paper does
//!   not exploit: binary-search the min-max threshold z over the distinct
//!   link latencies and test feasibility with a max-flow (Dinic) on the
//!   bipartite UE→edge graph with per-edge capacity. Scales to thousands
//!   of UEs; also cross-checks the B&B.
//!
//! Both solvers are reachable through the shared `AssocPolicy` trait as
//! `incremental::{BnbPolicy, ExactMatchingPolicy}`: the policies build
//! the active-subset latency table with the scoring core's expressions
//! (bitwise-equal to [`LatencyTable::build`] slicing) and delegate here.
//! Neither has an incremental form, so the warm engine re-runs them cold
//! every epoch — warm == cold trivially.

use super::{Association, LatencyTable};

/// Branch-and-bound on problem (39). UEs are branched in order of
/// decreasing best-case latency (hardest first); edges are tried in order
/// of increasing latency for the UE. Prunes on the incumbent bound and on
/// capacity. `incumbent` seeds the bound (e.g. Algorithm 3's solution).
pub fn solve_exact_bnb(
    table: &LatencyTable,
    cap: usize,
    incumbent: Option<&Association>,
) -> Result<Association, String> {
    let (n, m) = (table.num_ues, table.num_edges);
    if n > m * cap {
        return Err(format!("infeasible: {n} UEs > {m} edges x capacity {cap}"));
    }

    // Branch order: UEs whose best link is worst go first. Best-case
    // latencies are computed once up front — evaluating them inside the
    // comparator rescans all m edges per comparison (O(n log n · m)).
    let best_lat: Vec<f64> = (0..n)
        .map(|ue| {
            (0..m)
                .map(|e| table.of(ue, e))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| best_lat[b].total_cmp(&best_lat[a]));

    // Per-UE edge preference (ascending latency).
    let prefs: Vec<Vec<usize>> = (0..n)
        .map(|ue| {
            let mut es: Vec<usize> = (0..m).collect();
            es.sort_by(|&a, &b| table.of(ue, a).total_cmp(&table.of(ue, b)));
            es
        })
        .collect();

    let mut best_obj = incumbent
        .map(|a| table.max_latency(a))
        .unwrap_or(f64::INFINITY);
    let mut best_assign: Option<Vec<usize>> = incumbent.map(|a| a.edge_of.clone());

    let mut assign = vec![usize::MAX; n];
    let mut load = vec![0usize; m];

    fn dfs(
        depth: usize,
        cur_max: f64,
        order: &[usize],
        prefs: &[Vec<usize>],
        table: &LatencyTable,
        cap: usize,
        assign: &mut Vec<usize>,
        load: &mut Vec<usize>,
        best_obj: &mut f64,
        best_assign: &mut Option<Vec<usize>>,
    ) {
        if cur_max >= *best_obj {
            return; // bound
        }
        if depth == order.len() {
            *best_obj = cur_max;
            *best_assign = Some(assign.clone());
            return;
        }
        let ue = order[depth];
        for &e in &prefs[ue] {
            if load[e] >= cap {
                continue;
            }
            let lat = table.of(ue, e);
            if lat >= *best_obj {
                break; // prefs ascending: all further edges are worse
            }
            assign[ue] = e;
            load[e] += 1;
            dfs(
                depth + 1,
                cur_max.max(lat),
                order,
                prefs,
                table,
                cap,
                assign,
                load,
                best_obj,
                best_assign,
            );
            load[e] -= 1;
            assign[ue] = usize::MAX;
        }
    }

    dfs(
        0,
        0.0,
        &order,
        &prefs,
        table,
        cap,
        &mut assign,
        &mut load,
        &mut best_obj,
        &mut best_assign,
    );

    let edge_of = best_assign.ok_or_else(|| "no feasible assignment".to_string())?;
    let assoc = Association::new(edge_of, m);
    assoc.validate(cap)?;
    Ok(assoc)
}

/// Polynomial exact min-max association: binary search the threshold over
/// sorted distinct latencies; feasibility via Dinic max-flow on
/// source → UEs → edges(cap) → sink.
pub fn solve_exact_matching(table: &LatencyTable, cap: usize) -> Result<Association, String> {
    let (n, m) = (table.num_ues, table.num_edges);
    if n > m * cap {
        return Err(format!("infeasible: {n} UEs > {m} edges x capacity {cap}"));
    }
    let mut thresholds: Vec<f64> = table.latency_s.clone();
    // total_cmp: NaN latencies (degenerate channels) sort last instead of
    // panicking. dedup() compares with PartialEq, so NaN runs never
    // collapse — and neither NaN nor the +inf a down-edge-poisoned column
    // carries is a real objective (a non-finite link can never be
    // assigned), so drop every non-finite candidate before the search.
    thresholds.sort_by(|a, b| a.total_cmp(b));
    thresholds.dedup();
    thresholds.retain(|z| z.is_finite());
    if thresholds.is_empty() {
        return Err("no feasible assignment: every link latency is non-finite".to_string());
    }

    // Binary search the smallest feasible threshold.
    let feasible = |z: f64| -> Option<Vec<usize>> {
        let mut flow = Dinic::new(n + m + 2);
        let (src, snk) = (n + m, n + m + 1);
        let mut ue_arcs = vec![Vec::new(); n];
        for ue in 0..n {
            flow.add_edge(src, ue, 1);
            for e in 0..m {
                if table.of(ue, e) <= z {
                    let arc = flow.add_edge(ue, n + e, 1);
                    ue_arcs[ue].push((arc, e));
                }
            }
        }
        for e in 0..m {
            flow.add_edge(n + e, snk, cap as i64);
        }
        if flow.max_flow(src, snk) != n as i64 {
            return None;
        }
        let mut edge_of = vec![usize::MAX; n];
        for ue in 0..n {
            for &(arc, e) in &ue_arcs[ue] {
                if flow.arc_flow(arc) > 0 {
                    edge_of[ue] = e;
                }
            }
        }
        Some(edge_of)
    };

    let (mut lo, mut hi) = (0usize, thresholds.len() - 1);
    if feasible(thresholds[hi]).is_none() {
        return Err("no feasible assignment at max threshold".to_string());
    }
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(thresholds[mid]).is_some() {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let edge_of = feasible(thresholds[lo]).expect("checked feasible");
    let assoc = Association::new(edge_of, m);
    assoc.validate(cap)?;
    Ok(assoc)
}

// ---------------------------------------------------------------------
// Dinic max-flow (unit/bulk capacities; also the feasibility oracle for
// the aggregated probes in `assoc::flow`).
// ---------------------------------------------------------------------

pub(crate) struct Dinic {
    // edges: (to, cap); paired with reverse edge at idx ^ 1.
    to: Vec<usize>,
    cap: Vec<i64>,
    head: Vec<Vec<usize>>,
    level: Vec<i32>,
    iter: Vec<usize>,
    initial_cap: Vec<i64>,
}

impl Dinic {
    pub(crate) fn new(nodes: usize) -> Dinic {
        Dinic {
            to: Vec::new(),
            cap: Vec::new(),
            head: vec![Vec::new(); nodes],
            level: vec![0; nodes],
            iter: vec![0; nodes],
            initial_cap: Vec::new(),
        }
    }

    /// Returns the arc index of the forward edge.
    pub(crate) fn add_edge(&mut self, from: usize, to: usize, cap: i64) -> usize {
        let idx = self.to.len();
        self.to.push(to);
        self.cap.push(cap);
        self.initial_cap.push(cap);
        self.head[from].push(idx);
        self.to.push(from);
        self.cap.push(0);
        self.initial_cap.push(0);
        self.head[to].push(idx + 1);
        idx
    }

    pub(crate) fn arc_flow(&self, arc: usize) -> i64 {
        self.initial_cap[arc] - self.cap[arc]
    }

    fn bfs(&mut self, src: usize, snk: usize) -> bool {
        self.level.iter_mut().for_each(|l| *l = -1);
        let mut queue = std::collections::VecDeque::new();
        self.level[src] = 0;
        queue.push_back(src);
        while let Some(v) = queue.pop_front() {
            for &e in &self.head[v] {
                if self.cap[e] > 0 && self.level[self.to[e]] < 0 {
                    self.level[self.to[e]] = self.level[v] + 1;
                    queue.push_back(self.to[e]);
                }
            }
        }
        self.level[snk] >= 0
    }

    fn dfs(&mut self, v: usize, snk: usize, f: i64) -> i64 {
        if v == snk {
            return f;
        }
        while self.iter[v] < self.head[v].len() {
            let e = self.head[v][self.iter[v]];
            let u = self.to[e];
            if self.cap[e] > 0 && self.level[u] == self.level[v] + 1 {
                let d = self.dfs(u, snk, f.min(self.cap[e]));
                if d > 0 {
                    self.cap[e] -= d;
                    self.cap[e ^ 1] += d;
                    return d;
                }
            }
            self.iter[v] += 1;
        }
        0
    }

    pub(crate) fn max_flow(&mut self, src: usize, snk: usize) -> i64 {
        let mut flow = 0;
        while self.bfs(src, snk) {
            self.iter.iter_mut().for_each(|i| *i = 0);
            loop {
                let f = self.dfs(src, snk, i64::MAX);
                if f == 0 {
                    break;
                }
                flow += f;
            }
        }
        flow
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::{greedy, time_minimized};
    use crate::net::{Channel, SystemParams, Topology};

    fn table(edges: usize, ues: usize, seed: u64) -> (Topology, Channel, LatencyTable) {
        let t = Topology::sample(&SystemParams::default(), edges, ues, seed);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        let lt = LatencyTable::build(&t, &ch, 20.0);
        (t, ch, lt)
    }

    #[test]
    fn bnb_and_matching_agree() {
        for seed in 0..5 {
            let (_t, _ch, lt) = table(3, 9, seed);
            let bnb = solve_exact_bnb(&lt, 4, None).unwrap();
            let mat = solve_exact_matching(&lt, 4).unwrap();
            let (o1, o2) = (lt.max_latency(&bnb), lt.max_latency(&mat));
            assert!(
                (o1 - o2).abs() < 1e-12,
                "seed {seed}: bnb {o1} vs matching {o2}"
            );
        }
    }

    #[test]
    fn exact_never_worse_than_heuristics() {
        for seed in 0..5 {
            let (_t, ch, lt) = table(3, 12, seed + 100);
            let exact = solve_exact_matching(&lt, 5).unwrap();
            let opt = lt.max_latency(&exact);
            let g = greedy(&ch, 5).unwrap();
            let p = time_minimized(&ch, 5).unwrap();
            assert!(opt <= lt.max_latency(&g) + 1e-12);
            assert!(opt <= lt.max_latency(&p) + 1e-12);
        }
    }

    #[test]
    fn incumbent_seed_preserved_when_optimal() {
        let (_t, _ch, lt) = table(2, 6, 11);
        let exact = solve_exact_matching(&lt, 3).unwrap();
        // Seeding B&B with the optimum returns something no worse.
        let seeded = solve_exact_bnb(&lt, 3, Some(&exact)).unwrap();
        assert!(lt.max_latency(&seeded) <= lt.max_latency(&exact) + 1e-12);
    }

    #[test]
    fn matching_scales_to_hundreds() {
        let (_t, _ch, lt) = table(5, 300, 13);
        let a = solve_exact_matching(&lt, 100).unwrap();
        a.validate(100).unwrap();
    }

    #[test]
    fn infeasible_reported() {
        let (_t, _ch, lt) = table(2, 10, 17);
        assert!(solve_exact_bnb(&lt, 4, None).is_err());
        assert!(solve_exact_matching(&lt, 4).is_err());
    }

    #[test]
    fn poisoned_down_edge_column_never_enters_the_search() {
        // subset_latency_table poisons a down edge's whole column to +inf
        // under the outage process; those values must not surface as
        // binary-search thresholds (the old dedup left them in, so an
        // infeasible probe at z = +inf could "succeed" via poisoned arcs).
        for seed in 0..5 {
            let (_t, _ch, mut lt) = table(3, 9, 40 + seed);
            let m = lt.num_edges;
            for ue in 0..lt.num_ues {
                lt.latency_s[ue * m] = f64::INFINITY;
            }
            let a = solve_exact_matching(&lt, 5).unwrap();
            a.validate(5).unwrap();
            assert!(
                a.edge_of.iter().all(|&e| e != 0),
                "seed {seed}: a UE landed on the down edge"
            );
            let obj = lt.max_latency(&a);
            assert!(obj.is_finite(), "seed {seed}: objective {obj} is not a real latency");
        }
    }

    #[test]
    fn poisoned_columns_can_make_matching_infeasible() {
        // 9 UEs across 3 edges with cap 4 is feasible, but with two edges
        // down only 4 slots remain: the solver must report infeasibility,
        // not return an assignment through +inf links.
        let (_t, _ch, mut lt) = table(3, 9, 51);
        let m = lt.num_edges;
        for ue in 0..lt.num_ues {
            lt.latency_s[ue * m] = f64::INFINITY;
            lt.latency_s[ue * m + 1] = f64::INFINITY;
        }
        assert!(solve_exact_matching(&lt, 4).is_err());
    }

    #[test]
    fn all_nan_table_errs_without_panicking() {
        // Degenerate-channel shape: every candidate threshold is NaN, so
        // the retained set is empty and the solver must err gracefully
        // instead of indexing thresholds[len - 1] on an empty vec.
        let (_t, _ch, mut lt) = table(2, 6, 19);
        for z in lt.latency_s.iter_mut() {
            *z = f64::NAN;
        }
        assert!(solve_exact_matching(&lt, 4).is_err());
    }
}
