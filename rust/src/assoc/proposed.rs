//! Algorithm 3 — the paper's time-minimized UE-to-edge association.
//!
//! The paper's pseudo-code is terse; two readings are implemented and
//! compared (`benches/assoc_optimality.rs`, EXPERIMENTS.md §Deviations):
//!
//! * [`time_minimized`] (primary) — **global-SNR-order assignment**: walk
//!   all (UE, edge) pairs by decreasing uplink SNR `g_{n,m} p_n / N_0`
//!   and assign each UE the first time it appears, respecting the
//!   per-edge bandwidth capacity. This operationalizes the paper's
//!   "the UE n' and edge server m' with largest uplink channel SNR are
//!   chosen" selection rule, which the conflict-resolution loop keeps
//!   applying until a fixed point; it reproduces the paper's Fig. 5
//!   ordering (proposed < greedy < random) and lands within a few
//!   percent of the exact matching optimum.
//! * [`time_minimized_claims`] (literal) — the line-by-line reading:
//!   every edge claims its top-SNR UEs, then double-claims are resolved
//!   pairwise as written. On our topologies this variant does NOT beat
//!   per-edge greedy (it strands bottleneck UEs on whichever edge
//!   claimed them), which is why it is kept only as an ablation.
//!
//! `refine_swaps` adds an optional 1-move local search on the min-max
//! latency objective (38) — an extension, off by default.

use super::incremental::{AssocCtx, AssocPolicy, ProposedPolicy};
use super::{Association, LatencyTable};
use crate::net::Channel;

/// Primary Algorithm 3: global-SNR-order assignment under capacity `cap`.
///
/// Thin wrapper over [`ProposedPolicy`]'s cold path: all (UE, edge) pairs
/// are considered in (SNR desc, UE asc, edge asc) order — realized as a
/// lazy k-way merge over per-UE candidate rows instead of materializing
/// and sorting the O(U·M) pair list — and each UE is assigned the first
/// time it surfaces on a non-full edge. Bit-identical to the seed's
/// full-sort sweep (the stable pair-index tie-break *is* UE asc, edge
/// asc), including on degenerate NaN/∞ SNR worlds, where `total_cmp`
/// keeps the order deterministic instead of panicking mid-sort.
///
/// Returns an error when the instance is infeasible (`N > M·cap`).
pub fn time_minimized(channel: &Channel, cap: usize) -> Result<Association, String> {
    let ids: Vec<usize> = (0..channel.num_ues).collect();
    let ctx = AssocCtx {
        channel,
        topo: None,
        edge_up: None,
    };
    let edge_of = ProposedPolicy.assign_cold(&ctx, &ids, cap)?;
    let assoc = Association::new(edge_of, channel.num_edges);
    assoc.validate(cap)?;
    Ok(assoc)
}

/// Literal claims-then-conflict-resolution reading of Algorithm 3
/// (ablation; see module docs).
pub fn time_minimized_claims(channel: &Channel, cap: usize) -> Result<Association, String> {
    let (n_ues, n_edges) = (channel.num_ues, channel.num_edges);
    if n_ues > n_edges * cap {
        return Err(format!(
            "infeasible: {n_ues} UEs > {n_edges} edges x capacity {cap}"
        ));
    }

    // claimed_by[n] = edges currently claiming UE n.
    let mut sets: Vec<Vec<usize>> = vec![Vec::new(); n_edges];
    let mut claimed_by: Vec<Vec<usize>> = vec![Vec::new(); n_ues];

    // Line 1-3: each edge claims "the N_m UEs with largest SNR" — read as
    // the balanced member-set size, capped by the bandwidth constraint.
    let claim = n_ues.div_ceil(n_edges).min(cap);
    for m in 0..n_edges {
        let mut order: Vec<usize> = (0..n_ues).collect();
        order.sort_by(|&a, &b| channel.snr_of(b, m).total_cmp(&channel.snr_of(a, m)));
        for &n in order.iter().take(claim) {
            sets[m].push(n);
            claimed_by[n].push(m);
        }
    }

    // Line 4-8: resolve double claims.
    loop {
        let Some((ue, mi, mj)) = claimed_by.iter().enumerate().find_map(|(n, ms)| {
            (ms.len() >= 2).then(|| (n, ms[ms.len() - 1], ms[ms.len() - 2]))
        }) else {
            break;
        };
        // Candidate pool: UEs claimed by nobody.
        let pool: Vec<usize> = (0..n_ues).filter(|&n| claimed_by[n].is_empty()).collect();
        if pool.is_empty() {
            // No replacement: keep the UE on its better-SNR edge
            // (deterministic tie-break the paper leaves implicit).
            let keep = if channel.snr_of(ue, mi) >= channel.snr_of(ue, mj) {
                mi
            } else {
                mj
            };
            let drop = if keep == mi { mj } else { mi };
            sets[drop].retain(|&x| x != ue);
            claimed_by[ue].retain(|&m| m != drop);
            continue;
        }
        // (n', m') = argmax SNR over pool x {mi, mj}.
        let (mut best, mut best_snr) = ((pool[0], mi), f64::NEG_INFINITY);
        for &n in &pool {
            for &m in &[mi, mj] {
                let s = channel.snr_of(n, m);
                if s > best_snr {
                    best_snr = s;
                    best = (n, m);
                }
            }
        }
        let (n_new, m_new) = best;
        sets[m_new].retain(|&x| x != ue);
        claimed_by[ue].retain(|&m| m != m_new);
        sets[m_new].push(n_new);
        claimed_by[n_new].push(m_new);
    }

    // Assign leftovers to their best-SNR edge with spare capacity.
    let mut edge_of = vec![usize::MAX; n_ues];
    for (m, set) in sets.iter().enumerate() {
        for &n in set {
            edge_of[n] = m;
        }
    }
    let mut load: Vec<usize> = sets.iter().map(Vec::len).collect();
    for n in 0..n_ues {
        if edge_of[n] != usize::MAX {
            continue;
        }
        let m = (0..n_edges)
            .filter(|&m| load[m] < cap)
            .max_by(|&a, &b| channel.snr_of(n, a).total_cmp(&channel.snr_of(n, b)))
            .ok_or_else(|| "no edge with spare capacity".to_string())?;
        edge_of[n] = m;
        load[m] += 1;
    }

    let assoc = Association::new(edge_of, n_edges);
    assoc.validate(cap)?;
    Ok(assoc)
}

/// Extension (ablation): greedy 1-move local search on the min-max
/// latency objective (38), starting from any feasible association.
/// Repeatedly relocates a bottleneck UE to the edge that most reduces the
/// system maximum, until a fixed point.
pub fn refine_swaps(
    assoc: &Association,
    table: &LatencyTable,
    cap: usize,
    max_rounds: usize,
) -> Association {
    let mut cur = assoc.clone();
    let mut load = cur.load();
    for _ in 0..max_rounds {
        // Locate the bottleneck UE.
        let (bott_ue, bott_lat) = cur
            .edge_of
            .iter()
            .enumerate()
            .map(|(n, &m)| (n, table.of(n, m)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        // Try moving it to its best edge among those with spare capacity.
        let from = cur.edge_of[bott_ue];
        let best = (0..cur.num_edges)
            .filter(|&m| m != from && load[m] < cap)
            .min_by(|&a, &b| table.of(bott_ue, a).total_cmp(&table.of(bott_ue, b)));
        match best {
            Some(m) if table.of(bott_ue, m) < bott_lat => {
                cur.edge_of[bott_ue] = m;
                load[from] -= 1;
                load[m] += 1;
            }
            _ => break, // bottleneck cannot improve: fixed point
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Channel, SystemParams, Topology};

    fn setup(edges: usize, ues: usize, seed: u64) -> (Topology, Channel) {
        let t = Topology::sample(&SystemParams::default(), edges, ues, seed);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        (t, ch)
    }

    #[test]
    fn produces_feasible_association() {
        let (_t, ch) = setup(5, 100, 1);
        for a in [time_minimized(&ch, 20).unwrap(), time_minimized_claims(&ch, 20).unwrap()] {
            a.validate(20).unwrap();
            assert_eq!(a.num_ues(), 100);
        }
    }

    #[test]
    fn infeasible_when_over_capacity() {
        let (_t, ch) = setup(2, 50, 2);
        assert!(time_minimized(&ch, 20).is_err());
        assert!(time_minimized_claims(&ch, 20).is_err());
    }

    #[test]
    fn tight_capacity_fills_exactly() {
        let (_t, ch) = setup(5, 100, 3);
        assert_eq!(time_minimized(&ch, 20).unwrap().load(), vec![20; 5]);
        assert_eq!(time_minimized_claims(&ch, 20).unwrap().load(), vec![20; 5]);
    }

    #[test]
    fn slack_capacity_ok() {
        let (_t, ch) = setup(8, 40, 4);
        time_minimized(&ch, 20).unwrap().validate(20).unwrap();
        time_minimized_claims(&ch, 20).unwrap().validate(20).unwrap();
    }

    #[test]
    fn global_order_beats_greedy_on_average() {
        // The property the paper claims in Fig. 5 — averaged over seeds.
        let mut prop = 0.0;
        let mut greedy = 0.0;
        for seed in 0..10u64 {
            let (t, ch) = setup(8, 100, 100 + seed);
            let table = LatencyTable::build(&t, &ch, 20.0);
            prop += table.max_latency(&time_minimized(&ch, 20).unwrap());
            greedy += table.max_latency(&crate::assoc::greedy(&ch, 20).unwrap());
        }
        assert!(prop < greedy, "proposed {prop} vs greedy {greedy}");
    }

    #[test]
    fn refine_never_worsens() {
        let (t, ch) = setup(5, 100, 5);
        let a = time_minimized(&ch, 20).unwrap();
        let table = LatencyTable::build(&t, &ch, 20.0);
        let before = table.max_latency(&a);
        let refined = refine_swaps(&a, &table, 20, 1000);
        refined.validate(20).unwrap();
        assert!(table.max_latency(&refined) <= before + 1e-12);
    }
}
