//! Random-association baseline (paper §V-C): UEs are assigned uniformly
//! at random among edges with remaining bandwidth capacity.
//!
//! Deliberately *not* behind the `AssocPolicy` trait: the outcome is a
//! function of the rng stream, not of any link score, so there is
//! nothing for the warm engine to cache. The scenario loop re-draws it
//! cold every epoch in both `assoc_resolve` modes, consuming the same
//! rng stream either way (which keeps warm and cold trajectories
//! bitwise-identical for this strategy too).

use super::Association;
use crate::util::Rng;

pub fn random(
    num_ues: usize,
    num_edges: usize,
    cap: usize,
    rng: &mut Rng,
) -> Result<Association, String> {
    if num_ues > num_edges * cap {
        return Err(format!(
            "infeasible: {num_ues} UEs > {num_edges} edges x capacity {cap}"
        ));
    }
    let mut load = vec![0usize; num_edges];
    let mut edge_of = vec![0usize; num_ues];
    // Shuffle UE order so capacity pressure is not biased toward low ids.
    let order = rng.permutation(num_ues);
    for n in order {
        let open: Vec<usize> = (0..num_edges).filter(|&m| load[m] < cap).collect();
        let m = *rng.choose(&open);
        edge_of[n] = m;
        load[m] += 1;
    }
    let assoc = Association::new(edge_of, num_edges);
    assoc.validate(cap)?;
    Ok(assoc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasible_and_deterministic_per_seed() {
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let a = random(100, 5, 20, &mut r1).unwrap();
        let b = random(100, 5, 20, &mut r2).unwrap();
        assert_eq!(a, b);
        a.validate(20).unwrap();
    }

    #[test]
    fn tight_instance_fills_all_edges() {
        let mut rng = Rng::new(1);
        let a = random(100, 5, 20, &mut rng).unwrap();
        assert_eq!(a.load(), vec![20; 5]);
    }

    #[test]
    fn infeasible_detected() {
        let mut rng = Rng::new(1);
        assert!(random(101, 5, 20, &mut rng).is_err());
    }

    #[test]
    fn spreads_across_edges() {
        let mut rng = Rng::new(5);
        let a = random(200, 10, 100, &mut rng).unwrap();
        let load = a.load();
        assert!(load.iter().all(|&l| l > 0), "load {load:?}");
    }
}
