//! Sub-problem II: UE-to-edge association (paper §IV-D).
//!
//! Four strategies, matching the paper's evaluation (§V-C):
//!
//! * [`proposed`] — Algorithm 3, the paper's time-minimized association;
//! * [`greedy`] — per-edge max-SNR selection under the bandwidth cap;
//! * [`random`] — uniform random assignment under the bandwidth cap;
//! * [`bnb`] — exact solutions of the MILP epigraph form (39): a
//!   branch-and-bound solver (the baseline the paper calls impractical)
//!   plus a polynomial threshold-matching solver used to cross-check it.
//!
//! [`flow`] adds the optimality-certificate layer on top: an
//! LP-relaxation lower bound on the min-max objective that scales to
//! 100k+-UE worlds ([`flow_lower_bound`]), a min-cost-flow assignment
//! ([`solve_flow`]) and the [`Certificate`] type ([`certify`]) that any
//! strategy's result can be checked against.
//!
//! All strategies produce an [`Association`] that is validated against the
//! paper's constraints (3)/(13c)–(13e).
//!
//! The strategies are implemented behind the [`AssocPolicy`] trait in
//! [`incremental`], which also provides [`MaintainedAssociation`] — the
//! dirty-set warm engine the scenario loop uses to re-associate 100k-UE
//! worlds: per epoch it re-scores only the changed UEs (O(dirty·M)
//! float work plus cheap O(U) integer bookkeeping) instead of
//! re-scoring and re-sorting all O(U·M) links, and the maps stay
//! bitwise-equal to the cold rebuild (see the module docs for the
//! argument).

pub mod bnb;
pub mod flow;
pub mod greedy;
pub mod incremental;
pub mod proposed;
pub mod random;

use crate::net::{Channel, Topology};

pub use bnb::{solve_exact_bnb, solve_exact_matching};
pub use flow::{certify, flow_lower_bound, solve_flow, Certificate};
pub use greedy::greedy;
pub use incremental::{
    cold_reference_map, cold_reference_map_masked, policy_for, AssocCtx, AssocPolicy, BnbPolicy,
    ExactMatchingPolicy, GreedyPolicy, MaintainedAssociation, ProposedPolicy, WorldDelta,
};
pub use proposed::{time_minimized, time_minimized_claims};
pub use random::random;

/// A UE→edge association χ: `edge_of[n] = m` ⟺ χ_{n,m} = 1.
#[derive(Debug, Clone, PartialEq)]
pub struct Association {
    pub edge_of: Vec<usize>,
    pub num_edges: usize,
}

impl Association {
    pub fn new(edge_of: Vec<usize>, num_edges: usize) -> Association {
        Association { edge_of, num_edges }
    }

    pub fn num_ues(&self) -> usize {
        self.edge_of.len()
    }

    /// UEs per edge (|N_m| for every m).
    pub fn load(&self) -> Vec<usize> {
        let mut load = vec![0usize; self.num_edges];
        for &m in &self.edge_of {
            load[m] += 1;
        }
        load
    }

    /// The member set N_m for each edge.
    pub fn members(&self) -> Vec<Vec<usize>> {
        let mut members = vec![Vec::new(); self.num_edges];
        for (n, &m) in self.edge_of.iter().enumerate() {
            members[m].push(n);
        }
        members
    }

    /// Check the paper's association constraints (3)/(13c)-(13e):
    /// each UE on exactly one edge (by construction) and no edge above the
    /// bandwidth capacity `cap` (`usize::MAX` disables the check).
    pub fn validate(&self, cap: usize) -> Result<(), String> {
        for (n, &m) in self.edge_of.iter().enumerate() {
            if m >= self.num_edges {
                return Err(format!("UE {n} mapped to nonexistent edge {m}"));
            }
        }
        if cap != usize::MAX {
            for (m, &k) in self.load().iter().enumerate() {
                if k > cap {
                    return Err(format!("edge {m} hosts {k} UEs > capacity {cap}"));
                }
            }
        }
        Ok(())
    }
}

/// Per-link one-round latency `l_{n,m} = a·t_n^cmp + d_n / r_{n,m}` used by
/// every association strategy (the objective of problem (38)).
#[derive(Debug, Clone)]
pub struct LatencyTable {
    pub num_ues: usize,
    pub num_edges: usize,
    /// Row-major [ue][edge].
    pub latency_s: Vec<f64>,
}

impl LatencyTable {
    /// Build from a topology + channel for a given local-iteration count a.
    pub fn build(topo: &Topology, channel: &Channel, a: f64) -> LatencyTable {
        let (n, m) = (topo.num_ues(), topo.num_edges());
        let mut lat = Vec::with_capacity(n * m);
        for ue in &topo.ues {
            let t_cmp = crate::delay::ue_compute_time(ue);
            for em in 0..m {
                let r = channel.rate_of(ue.id, em);
                lat.push(a * t_cmp + ue.model_bits / r);
            }
        }
        LatencyTable {
            num_ues: n,
            num_edges: m,
            latency_s: lat,
        }
    }

    #[inline]
    pub fn of(&self, ue: usize, edge: usize) -> f64 {
        self.latency_s[ue * self.num_edges + edge]
    }

    /// The min-max objective (38) for an association.
    pub fn max_latency(&self, assoc: &Association) -> f64 {
        assoc
            .edge_of
            .iter()
            .enumerate()
            .map(|(n, &m)| self.of(n, m))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{SystemParams, Topology};

    fn setup() -> (Topology, Channel) {
        let t = Topology::sample(&SystemParams::default(), 3, 12, 5);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        (t, ch)
    }

    #[test]
    fn association_helpers() {
        let a = Association::new(vec![0, 1, 1, 2, 0], 3);
        assert_eq!(a.load(), vec![2, 2, 1]);
        assert_eq!(a.members()[1], vec![1, 2]);
        assert!(a.validate(2).is_ok());
        assert!(a.validate(1).is_err());
    }

    #[test]
    fn validate_rejects_bad_edge() {
        let a = Association::new(vec![0, 7], 3);
        assert!(a.validate(usize::MAX).is_err());
    }

    #[test]
    fn latency_table_positive_and_sane() {
        let (t, ch) = setup();
        let lt = LatencyTable::build(&t, &ch, 10.0);
        for n in 0..lt.num_ues {
            for m in 0..lt.num_edges {
                assert!(lt.of(n, m) > 0.0);
            }
        }
        // More local iterations => strictly larger link latency.
        let lt2 = LatencyTable::build(&t, &ch, 20.0);
        assert!(lt2.of(0, 0) > lt.of(0, 0));
    }

    #[test]
    fn degenerate_channel_never_panics() {
        // A UE parked on top of its edge server under a zero-bandwidth
        // allocation: noise_w(0) = 0 makes every SNR `g·p/0 = +inf`, and
        // the Shannon rate `0·log2(1+inf)` evaluates to NaN, so every
        // link latency is NaN too. Before the total_cmp hardening the
        // SNR/latency sorts panicked on these values.
        let mut params = SystemParams::default();
        params.ue_bandwidth_hz = 0.0;
        let mut topo = Topology::sample(&params, 2, 8, 3);
        topo.ues[0].pos = topo.edges[0].pos; // co-located: maximal gain
        let ch = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        assert!(ch.snr_of(0, 0).is_infinite());
        assert!(ch.rate_of(0, 0).is_nan());

        // SNR-order strategies stay deterministic and feasible.
        time_minimized(&ch, 4).unwrap().validate(4).unwrap();
        time_minimized_claims(&ch, 4).unwrap().validate(4).unwrap();
        greedy(&ch, 4).unwrap().validate(4).unwrap();

        // Latency-based exact solvers see all-NaN latencies: they must
        // fail gracefully (NaN satisfies no threshold) or terminate —
        // never abort mid-sort.
        let table = LatencyTable::build(&topo, &ch, 20.0);
        assert!(table.of(0, 0).is_nan());
        assert!(solve_exact_matching(&table, 4).is_err());
        let _ = solve_exact_bnb(&table, 4, None);
    }

    #[test]
    fn max_latency_is_max() {
        let (t, ch) = setup();
        let lt = LatencyTable::build(&t, &ch, 5.0);
        let assoc = Association::new(vec![0; 12], 3);
        let expect = (0..12).map(|n| lt.of(n, 0)).fold(0.0, f64::max);
        assert_eq!(lt.max_latency(&assoc), expect);
    }
}
