//! Incremental association engine — the per-epoch re-association hot path.
//!
//! The scenario engine re-associates every epoch, but an epoch's dynamics
//! touch only a few rows of the world: mobility moves some UEs (changing
//! their channel rows), churn removes/re-adds a few, load drift is slow.
//! The seed implementation re-scored every (UE, edge) link and re-sorted
//! the full O(U·M) pair list per epoch, which caps scenario worlds at a
//! few hundred UEs. [`MaintainedAssociation`] mirrors
//! `delay::MaintainedInstance`: it keeps per-UE sorted candidate lists
//! (edge rankings keyed by the policy's scoring metric) alive across
//! epochs and reprocesses only a *dirty set* — UEs whose channel rows
//! moved (mobility), arrived/departed (churn), or whose serving edge's
//! load drifted past a hysteresis threshold.
//!
//! The proposed/greedy/exact/B&B strategies are refactored behind the
//! [`AssocPolicy`] trait so the warm (maintained) and cold (from-scratch)
//! paths share one scoring core ([`AssocPolicy::score`] /
//! [`AssocPolicy::fill_scores`]) and one assignment core per family
//! (`merge_assign` for the global-order policies, `edgewise_take` for the
//! per-edge ones). Sharing the cores is what makes the warm path
//! **bitwise-identical** to a cold rebuild:
//!
//! * a clean UE's channel row is unchanged, so re-deriving its candidate
//!   row would sort bitwise-equal scores with the same comparator and
//!   produce the same permutation — the cache *is* the cold row;
//! * Algorithm 3's global-SNR-order sweep assigns every UE its top
//!   candidate whenever the all-argmax load map respects the capacity:
//!   take the first rejected pair (u, m) in the global order — every UE
//!   assigned before it got its own top choice, so the cap UEs filling m
//!   plus u itself are all argmax-of-m, i.e. the argmax load of m would
//!   be ≥ cap + 1. Contrapositive: argmax loads ≤ cap ⇒ no rejection ⇒
//!   the sweep *is* the argmax map. Fast-path epochs therefore cost only
//!   the O(dirty·M) re-scoring plus O(U) integer bookkeeping (load
//!   recounts, map rewrite — no float work, no sorting); the engine
//!   falls back to the shared merge sweep (over cached rows) only when
//!   some argmax load exceeds the capacity — both bitwise equal to cold;
//! * every path orders links identically — score desc, then UE id asc,
//!   then edge id asc — so a UE equidistant from two edges deterministically
//!   lands on the lower edge id, warm and cold alike.
//!
//! The hysteresis threshold re-scores an edge's members once its load
//! drifts ≥ `hysteresis · cap` since they were last scored. Under the
//! paper's fixed per-UE bandwidth the scoring metric is load-independent,
//! so hysteresis only bounds cache staleness for load-coupled scoring
//! extensions (`Channel::rate_equal_share`) and **cannot change the
//! output** — property-tested below, and the reason warm == cold holds
//! for every hysteresis value.

use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

use super::LatencyTable;
use crate::config::AssocStrategy;
use crate::delay::{ue_compute_time, upload_time};
use crate::net::{Channel, Topology};
use crate::trace::{Counter, NullSink, TraceSink};
use crate::util::ShardPool;

/// Read-only world view the policies score against. `topo` is only
/// required by the latency-keyed policies (exact / B&B); the SNR-keyed
/// ones run from the channel alone. `edge_up` is the outage mask:
/// `Some(mask)` excludes down edges from every assignment (their links
/// are skipped by the sweeps / poisoned to +∞ latency), `None` means all
/// edges serve. Scores themselves never change with the mask — only
/// availability does — which is what keeps the warm engine's cached
/// candidate rows valid across outage transitions.
pub struct AssocCtx<'a> {
    pub channel: &'a Channel,
    pub topo: Option<&'a Topology>,
    pub edge_up: Option<&'a [bool]>,
}

/// One association strategy behind a common scoring core. Higher score =
/// more preferred link; ties break by lower UE id, then lower edge id.
/// `assign_cold` is the from-scratch path; the warm path in
/// [`MaintainedAssociation`] reuses the same scores and assignment cores,
/// which is what keeps warm and cold bitwise-identical.
pub trait AssocPolicy {
    fn name(&self) -> &'static str;

    /// Preference score of one (UE, edge) link — the shared scoring core.
    fn score(&self, ctx: &AssocCtx, ue: usize, edge: usize) -> f64;

    /// Score a full UE row into `out` (cleared first). Policies whose
    /// scores are precomputed tables override this with a copy.
    fn fill_scores(&self, ctx: &AssocCtx, ue: usize, out: &mut Vec<f64>) {
        out.clear();
        let m = ctx.channel.num_edges;
        for e in 0..m {
            out.push(self.score(ctx, ue, e));
        }
    }

    /// From-scratch assignment of `ids` (ascending global UE ids) under
    /// per-edge capacity `cap`; returns the serving edge per `ids` entry.
    fn assign_cold(&self, ctx: &AssocCtx, ids: &[usize], cap: usize) -> Result<Vec<usize>, String>;
}

/// Algorithm 3 (the paper's proposal): global-SNR-order assignment.
pub struct ProposedPolicy;

/// Per-edge max-SNR selection under the bandwidth cap (paper §V-C).
pub struct GreedyPolicy;

/// Exact min-max association via threshold search + matching, keyed by
/// the paper's link latency `a·t^cmp + d/r` at a fixed `a`.
pub struct ExactMatchingPolicy {
    pub a: f64,
}

/// Exact branch-and-bound on MILP (39) (the baseline the paper dismisses
/// as exponential), same latency key as [`ExactMatchingPolicy`].
pub struct BnbPolicy {
    pub a: f64,
}

/// The [`AssocPolicy`] for a scenario strategy (`a` parameterizes the
/// latency-keyed policies; the SNR-keyed ones ignore it). Random has no
/// policy: it is rng-driven and re-drawn cold every epoch.
pub fn policy_for(strategy: AssocStrategy, a: f64) -> Result<Box<dyn AssocPolicy>, String> {
    match strategy {
        AssocStrategy::Proposed => Ok(Box::new(ProposedPolicy)),
        AssocStrategy::Greedy => Ok(Box::new(GreedyPolicy)),
        AssocStrategy::Exact => Ok(Box::new(ExactMatchingPolicy { a })),
        AssocStrategy::Random => {
            Err("random association is rng-driven and has no AssocPolicy".to_string())
        }
    }
}

fn check_feasible(k: usize, m: usize, cap: usize) -> Result<(), String> {
    if k > m * cap {
        return Err(format!("infeasible: {k} UEs > {m} edges x capacity {cap}"));
    }
    Ok(())
}

/// [`check_feasible`] against the outage mask: only up edges carry load.
fn check_feasible_masked(
    k: usize,
    m: usize,
    edge_up: Option<&[bool]>,
    cap: usize,
) -> Result<(), String> {
    match edge_up {
        None => check_feasible(k, m, cap),
        Some(mask) => {
            let up = mask.iter().filter(|&&u| u).count();
            if k > up * cap {
                return Err(format!(
                    "infeasible: {k} UEs > {up} up edges (of {m}) x capacity {cap}"
                ));
            }
            Ok(())
        }
    }
}

/// Is edge `e` serving under the (optional) outage mask?
#[inline]
fn edge_is_up(edge_up: Option<&[bool]>, e: usize) -> bool {
    match edge_up {
        None => true,
        Some(mask) => mask[e],
    }
}

/// Guard for the latency-keyed solvers under an outage mask: the +∞
/// poisoning of down edges excludes them whenever any *finite* link
/// exists, but a UE whose rate to every up edge is 0 (the degenerate
/// zero-bandwidth channel) has ∞ latency everywhere, and at threshold ∞
/// the min-max matching may route through a down edge. Fail loudly
/// instead of silently serving from a failed edge.
fn check_assignment_up(
    edge_up: Option<&[bool]>,
    edge_of: &[usize],
    solver: &str,
) -> Result<(), String> {
    if let Some(mask) = edge_up {
        if let Some(&bad) = edge_of.iter().find(|&&e| !mask[e]) {
            return Err(format!(
                "{solver} routed a UE to down edge {bad}: every up-edge link is ∞-latency \
                 (degenerate channel) — no finite masked assignment exists"
            ));
        }
    }
    Ok(())
}

/// First serving edge of a score-sorted candidate row — the cached
/// argmax the proposed fast path keys on. With every edge down (only
/// reachable on an infeasible world the caller already rejected) it
/// degrades to the raw row head.
#[inline]
fn first_up(row: &[u16], edge_up: Option<&[bool]>) -> u16 {
    match edge_up {
        None => row[0],
        Some(mask) => row
            .iter()
            .copied()
            .find(|&e| mask[e as usize])
            .unwrap_or(row[0]),
    }
}

fn check_edge_width(m: usize) -> Result<(), String> {
    if m > u16::MAX as usize {
        return Err(format!("{m} edges exceed the u16 candidate-row width"));
    }
    Ok(())
}

/// Sort one UE's candidate row (edge ids) by score desc, edge id asc —
/// the tie-break every path shares.
fn fill_candidate_row<P: AssocPolicy + ?Sized>(
    policy: &P,
    ctx: &AssocCtx,
    ue: usize,
    scratch: &mut Vec<f64>,
    row: &mut [u16],
) {
    policy.fill_scores(ctx, ue, scratch);
    for (e, slot) in row.iter_mut().enumerate() {
        *slot = e as u16;
    }
    row.sort_unstable_by(|&x, &y| {
        scratch[y as usize]
            .total_cmp(&scratch[x as usize])
            .then_with(|| x.cmp(&y))
    });
}

/// Lazy k-way merge head: the next unconsidered candidate of one UE.
struct Head {
    score: f64,
    ue: u32,
    cursor: u32,
}

impl PartialEq for Head {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Head {}

impl PartialOrd for Head {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Head {
    /// Max-heap order: higher score first, then lower UE index.
    fn cmp(&self, other: &Self) -> Ordering {
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.ue.cmp(&self.ue))
    }
}

/// Global-order greedy assignment as a lazy k-way merge over per-UE
/// candidate rows — exactly the sorted-pair sweep of Algorithm 3 (pairs
/// by score desc, UE asc, edge asc; assign a UE the first time it
/// surfaces on a non-full edge), without materializing the O(U·M) pair
/// list. `row_of[i]` is the row number of `ids[i]` inside `rows` (stride
/// `num_edges`); `score` re-derives a head's key (the shared scoring
/// core, so cached and fresh rows see identical keys). A down edge
/// (`edge_up`) is treated exactly like a full one — skipping its pairs
/// from the global sweep, which is the same assignment the sweep would
/// produce on a world without that edge (removing pairs from a sorted
/// list preserves the relative order of the rest).
fn merge_assign(
    ids: &[usize],
    rows: &[u16],
    row_of: &[usize],
    num_edges: usize,
    cap: usize,
    edge_up: Option<&[bool]>,
    pool: ShardPool,
    score: &(dyn Fn(usize, usize) -> f64 + Sync),
) -> Result<Vec<usize>, String> {
    let k = ids.len();
    check_feasible_masked(k, num_edges, edge_up, cap)?;
    let mut edge_of = vec![usize::MAX; k];
    let mut load = vec![0usize; num_edges];
    // The heap is *seeded* shard-parallel (each head's key is a pure
    // per-UE score) and built with one heapify. The pop loop stays
    // serial, but its output is a pure function of the heap's *content*:
    // the `Head` order is strict (distinct `ue` indices break every score
    // tie), so each pop returns the unique maximum of the current set no
    // matter how the heap was assembled — bitwise-identical to the old
    // push-seeded sweep for any thread count.
    let w = pool.shard_width(k);
    let ranges: Vec<(usize, usize)> = (0..k)
        .step_by(w.max(1))
        .map(|lo| (lo, (lo + w).min(k)))
        .collect();
    let seeds: Vec<Vec<Head>> = pool.map(ranges, |_, (lo, hi)| {
        (lo..hi)
            .map(|i| {
                let e = rows[row_of[i] * num_edges] as usize;
                Head {
                    score: score(ids[i], e),
                    ue: i as u32,
                    cursor: 0,
                }
            })
            .collect()
    });
    let mut heap = BinaryHeap::from(seeds.concat());
    let mut assigned = 0usize;
    while let Some(h) = heap.pop() {
        let i = h.ue as usize;
        let row = &rows[row_of[i] * num_edges..row_of[i] * num_edges + num_edges];
        let e = row[h.cursor as usize] as usize;
        if edge_is_up(edge_up, e) && load[e] < cap {
            edge_of[i] = e;
            load[e] += 1;
            assigned += 1;
            if assigned == k {
                break;
            }
        } else {
            let cursor = h.cursor + 1;
            if (cursor as usize) < num_edges {
                let e2 = row[cursor as usize] as usize;
                heap.push(Head {
                    score: score(ids[i], e2),
                    ue: h.ue,
                    cursor,
                });
            }
        }
    }
    if assigned != k {
        return Err("merge sweep left UEs unassigned".to_string());
    }
    Ok(edge_of)
}

/// Split ascending `ids` at the boundaries of a `width`-wide UE-id range
/// partition: slice `s` holds exactly the ids in `[s·width, (s+1)·width)`
/// — the ids shard `s` owns. Because the partition is by id *range*, the
/// per-shard slices concatenated in shard order are `ids` itself, which
/// is what makes every shard-order fold below equal its serial
/// counterpart.
fn shard_id_slices<'a>(ids: &'a [usize], width: usize, nshards: usize) -> Vec<&'a [usize]> {
    let mut slices = Vec::with_capacity(nshards);
    let mut rest = ids;
    for s in 0..nshards {
        let bound = (s + 1) * width;
        let cut = rest.partition_point(|&u| u < bound);
        let (head, tail) = rest.split_at(cut);
        slices.push(head);
        rest = tail;
    }
    debug_assert!(rest.is_empty(), "ids outside the shard partition");
    slices
}

/// Visitor fed one ranked UE at a time; return `false` to stop the edge.
type RankVisitor<'a> = dyn FnMut(usize) -> bool + 'a;

/// Per-edge sequential selection: edge 0 takes its best `cap` eligible
/// UEs, then edge 1, … — the greedy baseline's shared assignment core.
/// `for_each_ranked(e, visit)` must feed edge `e`'s UE ranking (global
/// ids, best first) to `visit` until it returns `false`. A down edge
/// (`edge_up`) takes nothing — identical to removing it from the walk.
fn edgewise_take(
    ids: &[usize],
    n_total: usize,
    num_edges: usize,
    cap: usize,
    edge_up: Option<&[bool]>,
    for_each_ranked: &mut dyn FnMut(usize, &mut RankVisitor),
) -> Result<Vec<usize>, String> {
    let k = ids.len();
    check_feasible_masked(k, num_edges, edge_up, cap)?;
    let mut edge_of_g = vec![usize::MAX; n_total];
    let mut eligible = vec![false; n_total];
    for &ue in ids {
        eligible[ue] = true;
    }
    let mut remaining = k;
    for e in 0..num_edges {
        if remaining == 0 {
            break;
        }
        if !edge_is_up(edge_up, e) {
            continue;
        }
        let mut taken = 0usize;
        let mut visit = |ue: usize| -> bool {
            if taken == cap {
                return false; // guard against a caller that ignores `false`
            }
            if !eligible[ue] || edge_of_g[ue] != usize::MAX {
                return true;
            }
            edge_of_g[ue] = e;
            taken += 1;
            remaining -= 1;
            taken < cap && remaining > 0
        };
        for_each_ranked(e, &mut visit);
    }
    if remaining != 0 {
        return Err("edgewise walk left UEs unassigned".to_string());
    }
    Ok(ids.iter().map(|&ue| edge_of_g[ue]).collect())
}

/// Latency table restricted to `ids`, built with the exact expressions of
/// [`LatencyTable::build`] so subset and full tables agree bitwise. Down
/// edges (outage mask) are poisoned to +∞ latency: the min-max threshold
/// search and the B&B bound both refuse an ∞ link whenever a finite
/// assignment exists, which the masked feasibility check guarantees.
/// Crate-visible: the scenario certify hook builds the same table for the
/// flow lower bound so bound and achieved share one latency definition.
pub(crate) fn subset_latency_table(
    ctx: &AssocCtx,
    a: f64,
    ids: &[usize],
) -> Result<LatencyTable, String> {
    let topo = ctx
        .topo
        .ok_or_else(|| "latency-keyed policy needs AssocCtx::topo".to_string())?;
    let m = ctx.channel.num_edges;
    let mut lat = Vec::with_capacity(ids.len() * m);
    for &ue in ids {
        let u = &topo.ues[ue];
        let t_cmp = ue_compute_time(u);
        for e in 0..m {
            if edge_is_up(ctx.edge_up, e) {
                lat.push(a * t_cmp + u.model_bits / ctx.channel.rate_of(ue, e));
            } else {
                lat.push(f64::INFINITY);
            }
        }
    }
    Ok(LatencyTable {
        num_ues: ids.len(),
        num_edges: m,
        latency_s: lat,
    })
}

impl AssocPolicy for ProposedPolicy {
    fn name(&self) -> &'static str {
        "proposed"
    }

    fn score(&self, ctx: &AssocCtx, ue: usize, edge: usize) -> f64 {
        ctx.channel.snr_of(ue, edge)
    }

    fn fill_scores(&self, ctx: &AssocCtx, ue: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(ctx.channel.snr_row(ue));
    }

    fn assign_cold(&self, ctx: &AssocCtx, ids: &[usize], cap: usize) -> Result<Vec<usize>, String> {
        let m = ctx.channel.num_edges;
        check_feasible_masked(ids.len(), m, ctx.edge_up, cap)?;
        check_edge_width(m)?;
        let mut rows = vec![0u16; ids.len() * m];
        let mut scratch = Vec::with_capacity(m);
        for (i, &ue) in ids.iter().enumerate() {
            fill_candidate_row(self, ctx, ue, &mut scratch, &mut rows[i * m..(i + 1) * m]);
        }
        let row_of: Vec<usize> = (0..ids.len()).collect();
        merge_assign(
            ids,
            &rows,
            &row_of,
            m,
            cap,
            ctx.edge_up,
            ShardPool::serial(),
            &|ue, e| self.score(ctx, ue, e),
        )
    }
}

impl AssocPolicy for GreedyPolicy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn score(&self, ctx: &AssocCtx, ue: usize, edge: usize) -> f64 {
        ctx.channel.snr_of(ue, edge)
    }

    fn fill_scores(&self, ctx: &AssocCtx, ue: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(ctx.channel.snr_row(ue));
    }

    fn assign_cold(&self, ctx: &AssocCtx, ids: &[usize], cap: usize) -> Result<Vec<usize>, String> {
        let m = ctx.channel.num_edges;
        let k = ids.len();
        check_feasible_masked(k, m, ctx.edge_up, cap)?;
        let mut scores = vec![0.0f64; k * m];
        let mut scratch = Vec::with_capacity(m);
        for (i, &ue) in ids.iter().enumerate() {
            self.fill_scores(ctx, ue, &mut scratch);
            scores[i * m..(i + 1) * m].copy_from_slice(&scratch);
        }
        let mut rank: Vec<Vec<u32>> = Vec::with_capacity(m);
        for e in 0..m {
            let mut order: Vec<u32> = (0..k as u32).collect();
            order.sort_unstable_by(|&x, &y| {
                scores[y as usize * m + e]
                    .total_cmp(&scores[x as usize * m + e])
                    .then_with(|| ids[x as usize].cmp(&ids[y as usize]))
            });
            rank.push(order);
        }
        let n_total = ids.last().map_or(0, |&ue| ue + 1);
        let mut feed = |e: usize, visit: &mut dyn FnMut(usize) -> bool| {
            for &i in &rank[e] {
                if !visit(ids[i as usize]) {
                    break;
                }
            }
        };
        edgewise_take(ids, n_total, m, cap, ctx.edge_up, &mut feed)
    }
}

impl AssocPolicy for ExactMatchingPolicy {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn score(&self, ctx: &AssocCtx, ue: usize, edge: usize) -> f64 {
        let topo = ctx.topo.expect("latency-keyed policy needs AssocCtx::topo");
        let u = &topo.ues[ue];
        -(self.a * ue_compute_time(u)
            + upload_time(u.model_bits, ctx.channel.rate_of(ue, edge)))
    }

    fn assign_cold(&self, ctx: &AssocCtx, ids: &[usize], cap: usize) -> Result<Vec<usize>, String> {
        check_feasible_masked(ids.len(), ctx.channel.num_edges, ctx.edge_up, cap)?;
        let table = subset_latency_table(ctx, self.a, ids)?;
        let assoc = super::solve_exact_matching(&table, cap)?;
        check_assignment_up(ctx.edge_up, &assoc.edge_of, "exact matching")?;
        Ok(assoc.edge_of)
    }
}

impl AssocPolicy for BnbPolicy {
    fn name(&self) -> &'static str {
        "bnb"
    }

    fn score(&self, ctx: &AssocCtx, ue: usize, edge: usize) -> f64 {
        let topo = ctx.topo.expect("latency-keyed policy needs AssocCtx::topo");
        let u = &topo.ues[ue];
        -(self.a * ue_compute_time(u)
            + upload_time(u.model_bits, ctx.channel.rate_of(ue, edge)))
    }

    fn assign_cold(&self, ctx: &AssocCtx, ids: &[usize], cap: usize) -> Result<Vec<usize>, String> {
        check_feasible_masked(ids.len(), ctx.channel.num_edges, ctx.edge_up, cap)?;
        let table = subset_latency_table(ctx, self.a, ids)?;
        let assoc = super::solve_exact_bnb(&table, cap, None)?;
        check_assignment_up(ctx.edge_up, &assoc.edge_of, "bnb")?;
        Ok(assoc.edge_of)
    }
}

/// What one epoch changed about the world. The caller contract the warm
/// path's exactness rests on: **every** UE whose channel row changed must
/// appear in `moved` (or `arrived`, whose rows are recomputed at the
/// arrival position).
#[derive(Debug, Clone, Default)]
pub struct WorldDelta {
    /// Active UEs whose channel row was recomputed in place (mobility).
    pub moved: Vec<usize>,
    /// UEs that became active this epoch.
    pub arrived: Vec<usize>,
    /// UEs that left this epoch.
    pub departed: Vec<usize>,
    /// Edge servers that went *down* this epoch (outage process). Their
    /// members are displaced: the warm engine marks them dirty itself,
    /// so they need not be listed UE-by-UE here.
    pub downed: Vec<usize>,
    /// Edge servers that came back *up* this epoch.
    pub restored: Vec<usize>,
}

impl WorldDelta {
    pub fn is_empty(&self) -> bool {
        self.moved.is_empty()
            && self.arrived.is_empty()
            && self.departed.is_empty()
            && self.downed.is_empty()
            && self.restored.is_empty()
    }

    /// Every UE the delta touches *directly*, ascending and deduplicated.
    /// UEs displaced by a `downed` edge are not listed (the delta names
    /// the edge, not its members); callers that maintain per-UE state
    /// must additionally diff serving edges, which is exactly what the
    /// scenario engine's `last_assoc` diff feeds `sync_delta`.
    pub fn touched(&self) -> Vec<usize> {
        let mut t: Vec<usize> = self
            .moved
            .iter()
            .chain(&self.arrived)
            .chain(&self.departed)
            .copied()
            .collect();
        t.sort_unstable();
        t.dedup();
        t
    }
}

/// Greedy-ranking key: iterating a `BTreeSet<RankKey>` ascending yields
/// UEs best-first (score desc, UE id asc) — the shared greedy order.
#[derive(Debug, Clone, Copy)]
struct RankKey {
    score: f64,
    ue: u32,
}

impl PartialEq for RankKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for RankKey {}

impl PartialOrd for RankKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for RankKey {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .score
            .total_cmp(&self.score)
            .then_with(|| self.ue.cmp(&other.ue))
    }
}

/// Policy-specific cached candidate state.
enum WarmState {
    /// Algorithm 3: per-UE candidate rows + cached argmax edge.
    Proposed { rows: Vec<u16>, top: Vec<u16> },
    /// Greedy: per-edge total rankings as ordered sets (+ the score table
    /// needed to remove stale keys).
    Greedy {
        scores: Vec<f64>,
        rank: Vec<BTreeSet<RankKey>>,
    },
    /// Latency-keyed exact policies have no incremental form: re-run the
    /// shared cold path every epoch (still through the same scoring
    /// core, so warm and cold stay identical).
    Cold,
}

/// Incrementally-maintained UE→edge association (see module docs for the
/// dirty-set rules and the warm == cold equality argument).
pub struct MaintainedAssociation {
    strategy: AssocStrategy,
    num_ues: usize,
    num_edges: usize,
    cap: usize,
    hysteresis: f64,
    active: Vec<bool>,
    /// Serving edge per global UE id (`usize::MAX` = inactive).
    edge_of: Vec<usize>,
    /// Per-edge load of the current association.
    load: Vec<usize>,
    /// Per-edge load when the edge's members were last (re-)scored — the
    /// hysteresis reference point.
    scored_load: Vec<usize>,
    /// Outage mask: `false` edges serve nobody. Maintained from the
    /// deltas' `downed`/`restored` lists; all-up at build.
    edge_up: Vec<bool>,
    /// The up-mask changed since the last reassign: cached argmaxes must
    /// be retargeted to the best *up* edge (integer row walks only — the
    /// scores themselves are unaffected by availability).
    mask_changed: bool,
    dirty: Vec<bool>,
    dirty_list: Vec<usize>,
    /// Intra-instance fork/join pool. The resolved thread count is the
    /// engine's shard count (UE-id range partition); it is purely a speed
    /// knob — every maintenance pass produces bitwise-identical state for
    /// any value (see `util::par` and the module docs).
    pool: ShardPool,
    state: WarmState,
    /// Cumulative UEs whose candidate state was reprocessed (the
    /// dirty-set sizes; cold fallbacks add the full active count).
    pub reassociations: u64,
    /// Epochs that ran a full (cold-equivalent) assignment pass.
    pub full_rebuilds: u64,
}

impl MaintainedAssociation {
    /// Build from a world snapshot: the first pass scores everyone, so it
    /// is exactly the shared cold path.
    pub fn new(
        strategy: AssocStrategy,
        topo: &Topology,
        channel: &Channel,
        active: &[bool],
        cap: usize,
        hysteresis: f64,
        provisional_a: f64,
    ) -> Result<MaintainedAssociation, String> {
        Self::new_traced(
            strategy,
            topo,
            channel,
            active,
            cap,
            hysteresis,
            provisional_a,
            &mut NullSink,
        )
    }

    /// [`Self::new`] plus telemetry: dirty-set size / path counters go to
    /// `sink`. The built association is identical to the untraced call.
    #[allow(clippy::too_many_arguments)]
    pub fn new_traced(
        strategy: AssocStrategy,
        topo: &Topology,
        channel: &Channel,
        active: &[bool],
        cap: usize,
        hysteresis: f64,
        provisional_a: f64,
        sink: &mut dyn TraceSink,
    ) -> Result<MaintainedAssociation, String> {
        Self::new_sharded(
            strategy,
            topo,
            channel,
            active,
            cap,
            hysteresis,
            provisional_a,
            1,
            sink,
        )
    }

    /// [`Self::new_traced`] with the maintenance pool sized up front
    /// (`intra_threads`; 0 = one per core), so the initial full-fleet
    /// build itself runs shard-parallel. The built association is
    /// bitwise-identical for every thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn new_sharded(
        strategy: AssocStrategy,
        topo: &Topology,
        channel: &Channel,
        active: &[bool],
        cap: usize,
        hysteresis: f64,
        provisional_a: f64,
        intra_threads: usize,
        sink: &mut dyn TraceSink,
    ) -> Result<MaintainedAssociation, String> {
        let n = topo.num_ues();
        let m = topo.num_edges();
        check_edge_width(m)?;
        if hysteresis.is_nan() || hysteresis < 0.0 {
            return Err(format!("assoc hysteresis must be >= 0, got {hysteresis}"));
        }
        let state = match strategy {
            AssocStrategy::Proposed => WarmState::Proposed {
                rows: vec![0u16; n * m],
                top: vec![0u16; n],
            },
            AssocStrategy::Greedy => WarmState::Greedy {
                scores: vec![0.0f64; n * m],
                rank: Vec::new(),
            },
            AssocStrategy::Exact => WarmState::Cold,
            AssocStrategy::Random => {
                return Err("random association cannot be maintained warm".to_string())
            }
        };
        let mut ma = MaintainedAssociation {
            strategy,
            num_ues: n,
            num_edges: m,
            cap,
            hysteresis,
            active: active.to_vec(),
            edge_of: vec![usize::MAX; n],
            load: vec![0usize; m],
            scored_load: vec![0usize; m],
            edge_up: vec![true; m],
            mask_changed: false,
            dirty: vec![false; n],
            dirty_list: Vec::new(),
            pool: ShardPool::new(intra_threads),
            state,
            reassociations: 0,
            full_rebuilds: 0,
        };
        for ue in 0..n {
            ma.mark_dirty(ue);
        }
        ma.reassign(topo, channel, provisional_a, sink)?;
        ma.scored_load.copy_from_slice(&ma.load);
        Ok(ma)
    }

    fn mark_dirty(&mut self, ue: usize) {
        if !self.dirty[ue] {
            self.dirty[ue] = true;
            self.dirty_list.push(ue);
        }
    }

    /// Set the maintenance thread count (0 = one per core). Purely a
    /// speed knob: every later pass produces bitwise-identical state for
    /// any value (property-tested in `tests/parallel.rs`).
    pub fn set_intra_threads(&mut self, threads: usize) {
        self.pool = ShardPool::new(threads);
    }

    /// Resolved maintenance thread count.
    pub fn intra_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Apply one epoch's [`WorldDelta`] and recompute the association.
    /// `active` is the caller's post-delta mask (cross-checked in debug
    /// builds and adopted as the source of truth).
    pub fn sync(
        &mut self,
        topo: &Topology,
        channel: &Channel,
        active: &[bool],
        delta: &WorldDelta,
        provisional_a: f64,
    ) -> Result<(), String> {
        self.sync_traced(topo, channel, active, delta, provisional_a, &mut NullSink)
    }

    /// [`Self::sync`] plus telemetry. The resulting association (and the
    /// `reassociations`/`full_rebuilds` bookkeeping) is identical to the
    /// untraced call — the sink only observes.
    pub fn sync_traced(
        &mut self,
        topo: &Topology,
        channel: &Channel,
        active: &[bool],
        delta: &WorldDelta,
        provisional_a: f64,
        sink: &mut dyn TraceSink,
    ) -> Result<(), String> {
        for &ue in &delta.departed {
            self.active[ue] = false;
        }
        for &ue in &delta.arrived {
            self.active[ue] = true;
            self.mark_dirty(ue);
        }
        for &ue in &delta.moved {
            self.mark_dirty(ue);
        }
        // Outage transitions. A recovered edge only changes availability
        // (its candidates re-enter every sweep through the mask); a downed
        // edge additionally displaces its current members, which the
        // engine marks dirty itself — the delta names edges, not UEs. The
        // displacement scan is a single O(N) pass against a per-edge mask
        // regardless of how many edges failed this epoch.
        for &e in &delta.restored {
            if !self.edge_up[e] {
                self.edge_up[e] = true;
                self.mask_changed = true;
            }
        }
        let mut downed_now: Option<Vec<bool>> = None;
        for &e in &delta.downed {
            if self.edge_up[e] {
                self.edge_up[e] = false;
                self.mask_changed = true;
                downed_now.get_or_insert_with(|| vec![false; self.num_edges])[e] = true;
            }
        }
        if let Some(downed) = downed_now {
            for ue in 0..self.num_ues {
                let e = self.edge_of[ue];
                if self.active[ue] && e != usize::MAX && downed[e] {
                    self.mark_dirty(ue);
                }
            }
        }
        debug_assert_eq!(self.active.as_slice(), active, "delta disagrees with active mask");
        self.active.copy_from_slice(active);

        // Hysteresis: an edge whose load drifted >= hysteresis * cap
        // since its members were last scored re-scores them (output-
        // neutral under load-independent scoring; see module docs).
        if self.hysteresis.is_finite() {
            let thresh = (self.hysteresis * self.cap as f64).max(1.0);
            let mut tripped: Vec<usize> = Vec::new();
            for e in 0..self.num_edges {
                if self.load[e].abs_diff(self.scored_load[e]) as f64 >= thresh {
                    tripped.push(e);
                }
            }
            if !tripped.is_empty() {
                let before = self.dirty_list.len();
                for ue in 0..self.num_ues {
                    let e = self.edge_of[ue];
                    if self.active[ue] && e != usize::MAX && tripped.binary_search(&e).is_ok() {
                        self.mark_dirty(ue);
                    }
                }
                let rescored = (self.dirty_list.len() - before) as u64;
                if rescored > 0 && sink.enabled() {
                    sink.counter(Counter::AssocRescored, rescored);
                }
                for &e in &tripped {
                    self.scored_load[e] = self.load[e];
                }
            }
        }
        self.reassign(topo, channel, provisional_a, sink)
    }

    /// The current association as the scenario engine consumes it
    /// (`None` = inactive).
    pub fn edge_of_global(&self) -> Vec<Option<usize>> {
        self.edge_of
            .iter()
            .map(|&e| if e == usize::MAX { None } else { Some(e) })
            .collect()
    }

    /// Per-edge load of the current association.
    pub fn load(&self) -> &[usize] {
        &self.load
    }

    /// Recompute the association from the dirty set — the shard-parallel
    /// epoch maintenance pass.
    ///
    /// **Why any thread count is bitwise-identical.** The per-UE state is
    /// struct-of-arrays (`rows`/`top`/`scores`/`edge_of`/`active` are flat
    /// arrays indexed by global UE id), partitioned into `pool.threads()`
    /// contiguous id-range shards of width `ceil(N / threads)`. Every
    /// parallel phase either (a) writes only its own shard's slice
    /// (`chunks_mut`), with each element a pure function of that UE's
    /// inputs — so the array contents never depend on scheduling — or
    /// (b) returns a per-shard partial (id list, load histogram, head
    /// seeds) that is folded **in ascending shard order**: concatenating
    /// range-sharded id lists yields the globally ascending id order, and
    /// integer histogram sums are order-free anyway. The one sequential
    /// stage left, the merge sweep's heap pop loop, is a pure function of
    /// the heap's content (strict `Head` order), not of seeding order.
    /// Trace counters are folded from per-shard counts the same way, so a
    /// sink observes identical streams for every thread count.
    fn reassign(
        &mut self,
        topo: &Topology,
        channel: &Channel,
        provisional_a: f64,
        sink: &mut dyn TraceSink,
    ) -> Result<(), String> {
        let m = self.num_edges;
        let n = self.num_ues;
        let cap = self.cap;
        let pool = self.pool;
        let width = pool.shard_width(n);
        let nshards = if n == 0 { 1 } else { n.div_ceil(width) };
        let traced = sink.enabled();
        // Per-shard dirty sets (UE-id range partition). The shard-order
        // fold of their sizes is the serial dirty count — the counter the
        // sink sees is identical for every thread count.
        let mut dirty_shards: Vec<Vec<usize>> = vec![Vec::new(); nshards];
        for &ue in &self.dirty_list {
            dirty_shards[ue / width].push(ue);
        }
        let dirty_total: u64 = dirty_shards.iter().map(|b| b.len() as u64).sum();
        debug_assert_eq!(dirty_total, self.dirty_list.len() as u64);
        if traced {
            sink.counter(Counter::AssocDirty, dirty_total);
        }
        let dirty_shards = &dirty_shards;
        // Active ids, ascending: per-shard collects concatenated in shard
        // order are already globally sorted (range sharding).
        let id_parts: Vec<Vec<usize>> =
            pool.map(self.active.chunks(width).collect(), |s, chunk| {
                chunk
                    .iter()
                    .enumerate()
                    .filter_map(|(j, &a)| if a { Some(s * width + j) } else { None })
                    .collect()
            });
        let ids: Vec<usize> = id_parts.concat();
        // `None` when every edge serves, so outage-free worlds take the
        // exact pre-outage paths (and error messages).
        let mask: Option<&[bool]> = if self.edge_up.iter().all(|&u| u) {
            None
        } else {
            Some(self.edge_up.as_slice())
        };
        check_feasible_masked(ids.len(), m, mask, cap)?;
        let ctx = AssocCtx {
            channel,
            topo: Some(topo),
            edge_up: mask,
        };
        let ctx = &ctx;
        if ids.is_empty() {
            for x in self.edge_of.iter_mut() {
                *x = usize::MAX;
            }
        } else {
            match &mut self.state {
                WarmState::Proposed { rows, top } => {
                    let policy = ProposedPolicy;
                    // Shard-parallel dirty re-scoring: each shard owns a
                    // disjoint rows/top slice and walks only its own
                    // dirty bucket.
                    let work: Vec<(&mut [u16], &mut [u16])> = rows
                        .chunks_mut(width * m)
                        .zip(top.chunks_mut(width))
                        .collect();
                    let processed: Vec<u64> = pool.map(work, |s, (row_chunk, top_chunk)| {
                        let mut scratch = Vec::with_capacity(m);
                        for &ue in &dirty_shards[s] {
                            let local = ue - s * width;
                            let row = &mut row_chunk[local * m..(local + 1) * m];
                            fill_candidate_row(&policy, ctx, ue, &mut scratch, row);
                            top_chunk[local] = first_up(row, mask);
                        }
                        dirty_shards[s].len() as u64
                    });
                    self.reassociations += processed.iter().sum::<u64>();
                    if self.mask_changed && traced {
                        sink.counter(Counter::AssocMaskRetargets, 1);
                    }
                    if self.mask_changed {
                        // Availability changed but no score did: retarget
                        // every cached argmax to its best *up* edge by
                        // walking the cached rows — integer work only, no
                        // re-scoring, no re-sorting. This is what keeps an
                        // outage epoch incremental instead of a cold
                        // rebuild. Shard-parallel: each shard rewrites its
                        // own top slice from its own (read-only) rows.
                        let work: Vec<(&[u16], &mut [u16])> = rows
                            .chunks(width * m)
                            .zip(top.chunks_mut(width))
                            .collect();
                        pool.map(work, |_, (row_chunk, top_chunk)| {
                            for (local, t) in top_chunk.iter_mut().enumerate() {
                                *t = first_up(&row_chunk[local * m..(local + 1) * m], mask);
                            }
                        });
                    }
                    // Per-shard argmax-load histograms, folded edge-wise
                    // in shard order (integer sums).
                    let top_ro: &[u16] = top;
                    let partial: Vec<Vec<u32>> =
                        pool.map(shard_id_slices(&ids, width, nshards), |_, slice| {
                            let mut counts = vec![0u32; m];
                            for &ue in slice {
                                counts[top_ro[ue] as usize] += 1;
                            }
                            counts
                        });
                    let mut argmax_load = vec![0usize; m];
                    for p in &partial {
                        for (acc, &c) in argmax_load.iter_mut().zip(p) {
                            *acc += c as usize;
                        }
                    }
                    if argmax_load.iter().all(|&l| l <= cap) {
                        // Fast path: the global sweep would assign every
                        // UE its top candidate (see module docs). Each
                        // shard rewrites its own edge_of range.
                        if traced {
                            sink.counter(Counter::AssocFastPath, 1);
                        }
                        let work: Vec<((&mut [usize], &[bool]), &[u16])> = self
                            .edge_of
                            .chunks_mut(width)
                            .zip(self.active.chunks(width))
                            .zip(top_ro.chunks(width))
                            .collect();
                        pool.map(work, |_, ((eo, act), tp)| {
                            for ((e, &a), &t) in eo.iter_mut().zip(act).zip(tp) {
                                *e = if a { t as usize } else { usize::MAX };
                            }
                        });
                    } else {
                        // Capacity binds somewhere: run the shared merge
                        // sweep over the cached rows (parallel-seeded,
                        // content-deterministic pop loop).
                        if traced {
                            sink.counter(Counter::AssocMergeSweep, 1);
                        }
                        self.full_rebuilds += 1;
                        self.reassociations += ids.len() as u64;
                        let assigned =
                            merge_assign(&ids, rows, &ids, m, cap, mask, pool, &|ue, e| {
                                policy.score(ctx, ue, e)
                            })?;
                        for x in self.edge_of.iter_mut() {
                            *x = usize::MAX;
                        }
                        for (i, &ue) in ids.iter().enumerate() {
                            self.edge_of[ue] = assigned[i];
                        }
                    }
                }
                WarmState::Greedy { scores, rank } => {
                    let policy = GreedyPolicy;
                    let dirty_list: &[usize] = &self.dirty_list;
                    if rank.is_empty() {
                        // First pass: bulk build. Phase 1 (shard-parallel)
                        // scores every UE row into the shard's slice.
                        let chunks: Vec<&mut [f64]> = scores.chunks_mut(width * m).collect();
                        pool.map(chunks, |s, chunk| {
                            let mut scratch = Vec::with_capacity(m);
                            for local in 0..chunk.len() / m {
                                policy.fill_scores(ctx, s * width + local, &mut scratch);
                                chunk[local * m..(local + 1) * m].copy_from_slice(&scratch);
                            }
                        });
                        // Phase 2 (edge-parallel): each edge's ranking is
                        // a pure function of its score column.
                        let scores_ro: &[f64] = scores;
                        *rank = pool.map((0..m).collect(), |_, e| {
                            let mut order: Vec<RankKey> = (0..n)
                                .map(|ue| RankKey {
                                    score: scores_ro[ue * m + e],
                                    ue: ue as u32,
                                })
                                .collect();
                            order.sort_unstable();
                            order.into_iter().collect()
                        });
                    } else {
                        // Incremental pass in three barriers, parallel
                        // along two axes. A (edge-parallel): drop the
                        // dirty UEs' stale keys — each worker owns whole
                        // BTreeSets, and set contents are order-free.
                        let scores_ro: &[f64] = scores;
                        let sets: Vec<&mut BTreeSet<RankKey>> = rank.iter_mut().collect();
                        pool.map(sets, |e, set| {
                            for &ue in dirty_list {
                                set.remove(&RankKey {
                                    score: scores_ro[ue * m + e],
                                    ue: ue as u32,
                                });
                            }
                        });
                        // B (shard-parallel): re-score the dirty rows.
                        let chunks: Vec<&mut [f64]> = scores.chunks_mut(width * m).collect();
                        pool.map(chunks, |s, chunk| {
                            let mut scratch = Vec::with_capacity(m);
                            for &ue in &dirty_shards[s] {
                                let local = ue - s * width;
                                policy.fill_scores(ctx, ue, &mut scratch);
                                chunk[local * m..(local + 1) * m].copy_from_slice(&scratch);
                            }
                        });
                        // C (edge-parallel): insert the fresh keys.
                        let scores_ro: &[f64] = scores;
                        let sets: Vec<&mut BTreeSet<RankKey>> = rank.iter_mut().collect();
                        pool.map(sets, |e, set| {
                            for &ue in dirty_list {
                                set.insert(RankKey {
                                    score: scores_ro[ue * m + e],
                                    ue: ue as u32,
                                });
                            }
                        });
                    }
                    self.reassociations += dirty_total;
                    let mut feed = |e: usize, visit: &mut dyn FnMut(usize) -> bool| {
                        for key in rank[e].iter() {
                            if !visit(key.ue as usize) {
                                break;
                            }
                        }
                    };
                    let assigned = edgewise_take(&ids, n, m, cap, mask, &mut feed)?;
                    for x in self.edge_of.iter_mut() {
                        *x = usize::MAX;
                    }
                    for (i, &ue) in ids.iter().enumerate() {
                        self.edge_of[ue] = assigned[i];
                    }
                }
                WarmState::Cold => {
                    let policy = policy_for(self.strategy, provisional_a)?;
                    let assigned = policy.assign_cold(ctx, &ids, cap)?;
                    if traced {
                        sink.counter(Counter::AssocMergeSweep, 1);
                    }
                    self.reassociations += ids.len() as u64;
                    self.full_rebuilds += 1;
                    for x in self.edge_of.iter_mut() {
                        *x = usize::MAX;
                    }
                    for (i, &ue) in ids.iter().enumerate() {
                        self.edge_of[ue] = assigned[i];
                    }
                }
            }
        }
        for &ue in &self.dirty_list {
            self.dirty[ue] = false;
        }
        self.dirty_list.clear();
        self.mask_changed = false;
        // Load recount: per-shard histograms folded edge-wise in shard
        // order (integer sums — identical for any thread count).
        let load_partial: Vec<Vec<u32>> =
            pool.map(self.edge_of.chunks(width).collect(), |_, chunk| {
                let mut counts = vec![0u32; m];
                for &e in chunk {
                    if e != usize::MAX {
                        counts[e] += 1;
                    }
                }
                counts
            });
        for l in self.load.iter_mut() {
            *l = 0;
        }
        for p in &load_partial {
            for (acc, &c) in self.load.iter_mut().zip(p) {
                *acc += c as usize;
            }
        }
        debug_assert!(
            self.load
                .iter()
                .zip(&self.edge_up)
                .all(|(&l, &up)| up || l == 0),
            "a down edge kept members"
        );
        Ok(())
    }

    /// The engine's current outage mask (true = serving).
    pub fn edge_up(&self) -> &[bool] {
        &self.edge_up
    }
}

/// Cold reference: the policy's from-scratch map over the active set, in
/// the engine's global-id layout. Shared by tests and benches as the
/// ground truth the warm path must reproduce bitwise.
pub fn cold_reference_map(
    strategy: AssocStrategy,
    topo: &Topology,
    channel: &Channel,
    active: &[bool],
    cap: usize,
    provisional_a: f64,
) -> Result<Vec<Option<usize>>, String> {
    cold_reference_map_masked(strategy, topo, channel, active, None, cap, provisional_a)
}

/// [`cold_reference_map`] under an outage mask: down edges take nobody.
#[allow(clippy::too_many_arguments)]
pub fn cold_reference_map_masked(
    strategy: AssocStrategy,
    topo: &Topology,
    channel: &Channel,
    active: &[bool],
    edge_up: Option<&[bool]>,
    cap: usize,
    provisional_a: f64,
) -> Result<Vec<Option<usize>>, String> {
    let n = topo.num_ues();
    let ids: Vec<usize> = (0..n).filter(|&u| active[u]).collect();
    let mut out = vec![None; n];
    if ids.is_empty() {
        return Ok(out);
    }
    let ctx = AssocCtx {
        channel,
        topo: Some(topo),
        edge_up,
    };
    let assigned = policy_for(strategy, provisional_a)?.assign_cold(&ctx, &ids, cap)?;
    for (i, &ue) in ids.iter().enumerate() {
        out[ue] = Some(assigned[i]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Position, SystemParams};
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn world(edges: usize, ues: usize, seed: u64) -> (Topology, Channel) {
        let t = Topology::sample(&SystemParams::default(), edges, ues, seed);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        (t, ch)
    }

    /// One synthetic churn+mobility epoch; returns the delta applied.
    fn drift(
        topo: &mut Topology,
        channel: &mut Channel,
        active: &mut [bool],
        rng: &mut Rng,
    ) -> WorldDelta {
        let n = topo.num_ues();
        let area = topo.params.area_m;
        let mut delta = WorldDelta::default();
        for _ in 0..rng.below(4) + 1 {
            let ue = rng.below(n as u64) as usize;
            if active[ue] && !delta.moved.contains(&ue) {
                topo.ues[ue].pos = Position {
                    x: rng.range(0.0, area),
                    y: rng.range(0.0, area),
                };
                channel.recompute_ue(&topo.params, &topo.ues[ue], &topo.edges);
                delta.moved.push(ue);
            }
        }
        for _ in 0..rng.below(3) {
            let ue = rng.below(n as u64) as usize;
            if active[ue] && !delta.moved.contains(&ue) && !delta.departed.contains(&ue) {
                active[ue] = false;
                delta.departed.push(ue);
            }
        }
        for _ in 0..rng.below(3) {
            let ue = rng.below(n as u64) as usize;
            if !active[ue] && !delta.departed.contains(&ue) && !delta.arrived.contains(&ue) {
                active[ue] = true;
                topo.ues[ue].pos = Position {
                    x: rng.range(0.0, area),
                    y: rng.range(0.0, area),
                };
                channel.recompute_ue(&topo.params, &topo.ues[ue], &topo.edges);
                delta.arrived.push(ue);
            }
        }
        delta
    }

    fn assert_warm_equals_cold(
        strategy: AssocStrategy,
        edges: usize,
        ues: usize,
        cap: usize,
        hysteresis: f64,
        seed: u64,
        epochs: usize,
    ) {
        let (mut topo, mut channel) = world(edges, ues, seed);
        let mut active = vec![true; ues];
        let a = 20.0;
        let mut ma =
            MaintainedAssociation::new(strategy, &topo, &channel, &active, cap, hysteresis, a)
                .unwrap();
        let mut rng = Rng::new(seed ^ 0xD21F7);
        for epoch in 0..epochs {
            let cold = cold_reference_map(strategy, &topo, &channel, &active, cap, a).unwrap();
            assert_eq!(
                ma.edge_of_global(),
                cold,
                "{} warm != cold at epoch {epoch} (seed {seed})",
                policy_for(strategy, a).unwrap().name()
            );
            let delta = drift(&mut topo, &mut channel, &mut active, &mut rng);
            ma.sync(&topo, &channel, &active, &delta, a).unwrap();
        }
    }

    #[test]
    fn proposed_warm_equals_cold_under_drift() {
        // Slack capacity: the argmax fast path dominates.
        assert_warm_equals_cold(AssocStrategy::Proposed, 5, 40, 20, 0.25, 1, 12);
        // Tight capacity: the merge fallback engages.
        assert_warm_equals_cold(AssocStrategy::Proposed, 3, 55, 20, 0.25, 2, 12);
    }

    #[test]
    fn greedy_warm_equals_cold_under_drift() {
        assert_warm_equals_cold(AssocStrategy::Greedy, 4, 48, 20, 0.25, 3, 12);
    }

    #[test]
    fn exact_fallback_warm_equals_cold_under_drift() {
        assert_warm_equals_cold(AssocStrategy::Exact, 3, 18, 8, 0.25, 4, 6);
    }

    #[test]
    fn prop_warm_equals_cold_any_hysteresis() {
        check("assoc warm == cold for any hysteresis", 12, |rng| {
            let strategy = if rng.f64() < 0.5 {
                AssocStrategy::Proposed
            } else {
                AssocStrategy::Greedy
            };
            let edges = rng.int_range(2, 6) as usize;
            let ues = rng.int_range(edges as i64, (edges * 18) as i64) as usize;
            let hysteresis = rng.range(0.0, 2.0);
            let seed = rng.next_u64();
            assert_warm_equals_cold(strategy, edges, ues, 20, hysteresis, seed, 8);
        });
    }

    #[test]
    fn merge_fallback_engages_when_capacity_binds() {
        // Everyone piled near one edge: argmax loads must exceed cap.
        let (mut topo, mut channel) = world(3, 55, 7);
        let magnet = topo.edges[0].pos;
        for ue in topo.ues.iter_mut() {
            ue.pos = magnet;
        }
        for ue in &topo.ues {
            channel.recompute_ue(&topo.params, ue, &topo.edges);
        }
        let active = vec![true; 55];
        let ma = MaintainedAssociation::new(
            AssocStrategy::Proposed,
            &topo,
            &channel,
            &active,
            20,
            0.25,
            20.0,
        )
        .unwrap();
        assert!(ma.full_rebuilds >= 1, "capacity-bound world must merge");
        let cold =
            cold_reference_map(AssocStrategy::Proposed, &topo, &channel, &active, 20, 20.0)
                .unwrap();
        assert_eq!(ma.edge_of_global(), cold);
        assert!(ma.load().iter().all(|&l| l <= 20));
    }

    #[test]
    fn equidistant_ue_tie_breaks_by_edge_id_warm_and_cold() {
        // UE 0 exactly between edges 0 and 1: both links have bitwise-
        // identical distance, hence gain, hence SNR. Every path must pick
        // the lower edge id.
        let (mut topo, mut channel) = world(2, 10, 5);
        topo.edges[0].pos = Position { x: 100.0, y: 250.0 };
        topo.edges[1].pos = Position { x: 300.0, y: 250.0 };
        topo.ues[0].pos = Position { x: 200.0, y: 250.0 };
        channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        assert_eq!(
            channel.snr_of(0, 0).to_bits(),
            channel.snr_of(0, 1).to_bits(),
            "tie premise: equidistant links have identical SNR"
        );
        let active = vec![true; 10];
        for strategy in [AssocStrategy::Proposed, AssocStrategy::Greedy] {
            let cold = cold_reference_map(strategy, &topo, &channel, &active, 20, 20.0).unwrap();
            assert_eq!(cold[0], Some(0), "{strategy:?} cold tie-break");
            let mut ma =
                MaintainedAssociation::new(strategy, &topo, &channel, &active, 20, 0.25, 20.0)
                    .unwrap();
            assert_eq!(ma.edge_of_global()[0], Some(0), "{strategy:?} warm tie-break");
            // Move the UE off and back onto the midpoint: the dirty-set
            // re-score must reproduce the same deterministic tie-break.
            topo.ues[0].pos = Position { x: 120.0, y: 250.0 };
            channel.recompute_ue(&topo.params, &topo.ues[0], &topo.edges);
            let delta = WorldDelta {
                moved: vec![0],
                ..Default::default()
            };
            ma.sync(&topo, &channel, &active, &delta, 20.0).unwrap();
            topo.ues[0].pos = Position { x: 200.0, y: 250.0 };
            channel.recompute_ue(&topo.params, &topo.ues[0], &topo.edges);
            ma.sync(&topo, &channel, &active, &delta, 20.0).unwrap();
            assert_eq!(ma.edge_of_global()[0], Some(0), "{strategy:?} re-scored tie");
        }
    }

    #[test]
    fn emptied_and_refilled_edge_leaks_no_stale_members() {
        // Mirror of the PR 3 empty-edge regression suite, at the
        // association layer: all members of one edge depart and other UEs
        // arrive in their place within a single epoch. The maintained map
        // must match the cold rebuild exactly — no stale member may
        // survive — and the internal load bookkeeping must agree.
        check("assoc empty+refill leaks nothing", 10, |rng| {
            let (mut topo, mut channel) = world(3, 30, rng.next_u64());
            let mut active = vec![true; 30];
            // Start with a third of the fleet parked inactive.
            for ue in 0..10 {
                active[ue * 3] = false;
            }
            let mut ma = MaintainedAssociation::new(
                AssocStrategy::Proposed,
                &topo,
                &channel,
                &active,
                20,
                0.25,
                20.0,
            )
            .unwrap();
            // Drain one edge completely...
            let victim = rng.below(3) as usize;
            let mut delta = WorldDelta::default();
            let map = ma.edge_of_global();
            for (ue, e) in map.iter().enumerate() {
                if *e == Some(victim) {
                    active[ue] = false;
                    delta.departed.push(ue);
                }
            }
            // ...and refill the world from the inactive pool, same epoch.
            let area = topo.params.area_m;
            for ue in 0..30 {
                if !active[ue] && !delta.departed.contains(&ue) {
                    active[ue] = true;
                    topo.ues[ue].pos = Position {
                        x: rng.range(0.0, area),
                        y: rng.range(0.0, area),
                    };
                    channel.recompute_ue(&topo.params, &topo.ues[ue], &topo.edges);
                    delta.arrived.push(ue);
                }
            }
            ma.sync(&topo, &channel, &active, &delta, 20.0).unwrap();
            let cold = cold_reference_map(
                AssocStrategy::Proposed,
                &topo,
                &channel,
                &active,
                20,
                20.0,
            )
            .unwrap();
            assert_eq!(ma.edge_of_global(), cold, "stale member leaked");
            for (ue, e) in ma.edge_of_global().iter().enumerate() {
                assert_eq!(e.is_some(), active[ue], "active/assigned mismatch");
            }
            let mut expect_load = vec![0usize; 3];
            for e in cold.iter().flatten() {
                expect_load[*e] += 1;
            }
            assert_eq!(ma.load(), expect_load.as_slice());
        });
    }

    #[test]
    fn outage_engine_matches_masked_cold_and_recovers_bitwise() {
        // Down an edge: the displaced members re-associate incrementally
        // and the map must equal the masked cold rebuild; restore it and
        // the original map comes back bit for bit.
        for strategy in [AssocStrategy::Proposed, AssocStrategy::Greedy, AssocStrategy::Exact] {
            for &hysteresis in &[0.0, 0.75] {
                let (topo, channel) = world(3, 30, 21);
                let active = vec![true; 30];
                let mut ma = MaintainedAssociation::new(
                    strategy,
                    &topo,
                    &channel,
                    &active,
                    20,
                    hysteresis,
                    20.0,
                )
                .unwrap();
                let before = ma.edge_of_global();
                let victim = 1usize;
                let delta_down = WorldDelta {
                    downed: vec![victim],
                    ..Default::default()
                };
                ma.sync(&topo, &channel, &active, &delta_down, 20.0).unwrap();
                let mut up = vec![true; 3];
                up[victim] = false;
                let cold = cold_reference_map_masked(
                    strategy,
                    &topo,
                    &channel,
                    &active,
                    Some(&up),
                    20,
                    20.0,
                )
                .unwrap();
                assert_eq!(ma.edge_of_global(), cold, "{strategy:?} h={hysteresis}");
                assert!(
                    cold.iter().flatten().all(|&e| e != victim),
                    "{strategy:?}: down edge kept members"
                );
                assert_eq!(ma.load()[victim], 0);
                assert!(!ma.edge_up()[victim]);
                // Recovery: the pre-outage association returns exactly.
                let delta_up = WorldDelta {
                    restored: vec![victim],
                    ..Default::default()
                };
                ma.sync(&topo, &channel, &active, &delta_up, 20.0).unwrap();
                assert_eq!(ma.edge_of_global(), before, "{strategy:?} h={hysteresis}");
            }
        }
    }

    #[test]
    fn outage_equals_departing_and_rejoining_the_displaced_members() {
        // The observational-equivalence property: an outage epoch and an
        // epoch that explicitly churn-departs the edge's members and
        // re-arrives them (with the edge masked) produce the same map.
        for strategy in [AssocStrategy::Proposed, AssocStrategy::Greedy, AssocStrategy::Exact] {
            let (topo, channel) = world(4, 44, 8);
            let active = vec![true; 44];
            let build = || {
                MaintainedAssociation::new(strategy, &topo, &channel, &active, 20, 0.25, 20.0)
                    .unwrap()
            };
            let mut via_outage = build();
            let mut via_churn = build();
            let victim = 2usize;
            let members: Vec<usize> = via_churn
                .edge_of_global()
                .iter()
                .enumerate()
                .filter(|(_, e)| **e == Some(victim))
                .map(|(ue, _)| ue)
                .collect();
            assert!(!members.is_empty(), "victim edge must host someone");
            via_outage
                .sync(
                    &topo,
                    &channel,
                    &active,
                    &WorldDelta {
                        downed: vec![victim],
                        ..Default::default()
                    },
                    20.0,
                )
                .unwrap();
            via_churn
                .sync(
                    &topo,
                    &channel,
                    &active,
                    &WorldDelta {
                        departed: members.clone(),
                        arrived: members,
                        downed: vec![victim],
                        ..Default::default()
                    },
                    20.0,
                )
                .unwrap();
            assert_eq!(
                via_outage.edge_of_global(),
                via_churn.edge_of_global(),
                "{strategy:?}"
            );
        }
    }

    #[test]
    fn bnb_and_matching_respect_the_outage_mask() {
        let (topo, channel) = world(3, 9, 17);
        let ids: Vec<usize> = (0..9).collect();
        let mut up = vec![true; 3];
        up[0] = false;
        let ctx = AssocCtx {
            channel: &channel,
            topo: Some(&topo),
            edge_up: Some(&up),
        };
        let b = BnbPolicy { a: 20.0 }.assign_cold(&ctx, &ids, 5).unwrap();
        let e = ExactMatchingPolicy { a: 20.0 }.assign_cold(&ctx, &ids, 5).unwrap();
        assert!(b.iter().all(|&m| m != 0), "bnb used a down edge");
        assert!(e.iter().all(|&m| m != 0), "matching used a down edge");
        // Same min-max objective over the masked table.
        let table = LatencyTable::build(&topo, &channel, 20.0);
        let ob = ids.iter().map(|&u| table.of(u, b[u])).fold(0.0, f64::max);
        let oe = ids.iter().map(|&u| table.of(u, e[u])).fold(0.0, f64::max);
        assert!((ob - oe).abs() < 1e-12, "bnb {ob} vs matching {oe}");
        // Masked infeasibility is detected up front (9 UEs > 2 up x 4).
        assert!(BnbPolicy { a: 20.0 }.assign_cold(&ctx, &ids, 4).is_err());
        assert!(ExactMatchingPolicy { a: 20.0 }.assign_cold(&ctx, &ids, 4).is_err());
        assert!(ProposedPolicy.assign_cold(&ctx, &ids, 4).is_err());
        assert!(GreedyPolicy.assign_cold(&ctx, &ids, 4).is_err());
    }

    #[test]
    fn policy_cold_paths_match_legacy_wrappers() {
        let (topo, channel) = world(5, 100, 11);
        let ids: Vec<usize> = (0..100).collect();
        let ctx = AssocCtx {
            channel: &channel,
            topo: Some(&topo),
            edge_up: None,
        };
        let p = ProposedPolicy.assign_cold(&ctx, &ids, 20).unwrap();
        assert_eq!(p, crate::assoc::time_minimized(&channel, 20).unwrap().edge_of);
        let g = GreedyPolicy.assign_cold(&ctx, &ids, 20).unwrap();
        assert_eq!(g, crate::assoc::greedy(&channel, 20).unwrap().edge_of);
        let table = LatencyTable::build(&topo, &channel, 20.0);
        let e = ExactMatchingPolicy { a: 20.0 }.assign_cold(&ctx, &ids, 25).unwrap();
        assert_eq!(
            e,
            crate::assoc::solve_exact_matching(&table, 25).unwrap().edge_of
        );
    }

    #[test]
    fn infeasible_and_empty_inputs() {
        let (topo, channel) = world(2, 50, 13);
        let ids: Vec<usize> = (0..50).collect();
        let ctx = AssocCtx {
            channel: &channel,
            topo: Some(&topo),
            edge_up: None,
        };
        assert!(ProposedPolicy.assign_cold(&ctx, &ids, 20).is_err());
        assert!(GreedyPolicy.assign_cold(&ctx, &ids, 20).is_err());
        assert_eq!(ProposedPolicy.assign_cold(&ctx, &[], 20).unwrap(), vec![]);
        let active = vec![false; 50];
        let ma = MaintainedAssociation::new(
            AssocStrategy::Proposed,
            &topo,
            &channel,
            &active,
            20,
            0.25,
            20.0,
        )
        .unwrap();
        assert!(ma.edge_of_global().iter().all(|e| e.is_none()));
        assert!(policy_for(AssocStrategy::Random, 1.0).is_err());
    }

    #[test]
    fn bnb_policy_agrees_with_matching_on_small_worlds() {
        let (topo, channel) = world(3, 9, 17);
        let ids: Vec<usize> = (0..9).collect();
        let ctx = AssocCtx {
            channel: &channel,
            topo: Some(&topo),
            edge_up: None,
        };
        let table = LatencyTable::build(&topo, &channel, 20.0);
        let b = BnbPolicy { a: 20.0 }.assign_cold(&ctx, &ids, 4).unwrap();
        let e = ExactMatchingPolicy { a: 20.0 }.assign_cold(&ctx, &ids, 4).unwrap();
        let ob = ids.iter().map(|&u| table.of(u, b[u])).fold(0.0, f64::max);
        let oe = ids.iter().map(|&u| table.of(u, e[u])).fold(0.0, f64::max);
        assert!((ob - oe).abs() < 1e-12, "bnb {ob} vs matching {oe}");
    }
}
