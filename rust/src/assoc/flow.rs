//! Flow-based lower bounds and optimality certificates for the
//! association problem (paper problem (39)).
//!
//! The exact solvers in [`bnb`](super::bnb) answer "how far from optimal
//! is Algorithm 3?" only at toy scale: branch-and-bound caps out near 16
//! UEs and the threshold-matching solver reruns a raw UE-level Dinic per
//! probe. This module scales the question to the 100k+-UE worlds the
//! scenario engine runs:
//!
//! * [`flow_lower_bound`] — the LP-relaxation lower bound on the min-max
//!   latency objective. The LP relaxation of the threshold-restricted
//!   assignment polytope is a transportation polytope, whose constraint
//!   matrix is totally unimodular — so fractional feasibility at a
//!   threshold `z` equals integral feasibility, and the smallest feasible
//!   `z` is simultaneously the LP bound and the exact min-max optimum.
//!   Feasibility is decided by max-flow on an *aggregated* network: UEs
//!   with identical admissible edge sets collapse into one supply node
//!   (flow decomposition makes the aggregation exact), shrinking the
//!   graph from `n·m` unit arcs to at most `min(n, 2^m)` group nodes over
//!   `m ≤ a few hundred` edge nodes.
//! * [`solve_flow`] — a min-cost-flow assignment (successive shortest
//!   paths with Johnson potentials): among all assignments achieving the
//!   optimal min-max threshold it minimizes total latency. Practical to a
//!   few thousand UEs; the *bound* is what runs at scale.
//! * [`Certificate`] — `{ lower_bound, achieved, gap }` for any
//!   [`Association`], checkable against every `AssocPolicy` result.
//!
//! Determinism (hfl-lint R1–R6): no hash-ordered collections — grouping
//! is an index sort over bit-masks; all float comparisons go through
//! `total_cmp` or plain operators; node and arc construction follows
//! fixed ascending orders (UE id, edge id, sorted mask), so Dinic and the
//! shortest-path solver see identical graphs on identical inputs, and the
//! Dijkstra heap breaks distance ties by node id.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::bnb::Dinic;
use super::{Association, LatencyTable};

/// An optimality certificate for an association under a latency table:
/// `lower_bound ≤ optimum ≤ achieved`, `gap = achieved - lower_bound`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Certificate {
    /// LP-relaxation (= exact, by total unimodularity) lower bound on the
    /// min-max latency objective.
    pub lower_bound: f64,
    /// Max link latency the certified association actually achieves.
    pub achieved: f64,
    /// `achieved - lower_bound`; zero certifies the association optimal.
    pub gap: f64,
}

impl Certificate {
    pub fn new(lower_bound: f64, achieved: f64) -> Certificate {
        Certificate {
            lower_bound,
            achieved,
            gap: achieved - lower_bound,
        }
    }

    /// Internal consistency: a finite bound that does not exceed the
    /// achieved objective. Both sides are maxima over entries of the same
    /// table, so the comparison needs no tolerance.
    pub fn holds(&self) -> bool {
        self.lower_bound.is_finite() && self.lower_bound <= self.achieved
    }
}

/// Certify an association: the flow lower bound next to the max latency
/// the association achieves on the same table.
pub fn certify(
    table: &LatencyTable,
    cap: usize,
    assoc: &Association,
) -> Result<Certificate, String> {
    let lower = flow_lower_bound(table, cap)?;
    Ok(Certificate::new(lower, table.max_latency(assoc)))
}

/// The LP-relaxation lower bound on the min-max association latency —
/// exact (equal to `solve_exact_matching`'s objective) at every scale.
///
/// Search structure: the optimum is attained at a table entry, and
/// feasibility at a threshold is monotone, so binary-search the sorted
/// distinct finite entries. The search window is pre-narrowed to
/// `[lb_best, ub]` where `lb_best = max_ue min_e l(ue,e)` (below it the
/// hardest UE has an empty admissible set) and `ub` is the makespan of a
/// deterministic capacity-respecting greedy pass (a feasibility witness),
/// so only the entries a probe could actually return are ever sorted.
pub fn flow_lower_bound(table: &LatencyTable, cap: usize) -> Result<f64, String> {
    let (n, m) = (table.num_ues, table.num_edges);
    if n == 0 {
        // max over an empty UE set — matches `LatencyTable::max_latency`
        // on an empty association.
        return Ok(0.0);
    }
    if m == 0 || n > m.saturating_mul(cap) {
        return Err(format!("infeasible: {n} UEs > {m} edges x capacity {cap}"));
    }

    // lb_best: every UE must land somewhere, so the worst best-case link
    // is a bound. Errs when some UE has no finite link at all (fully
    // degenerate or fully-masked row).
    let mut lb_best = f64::NEG_INFINITY;
    for ue in 0..n {
        let mut best = f64::INFINITY;
        for e in 0..m {
            let l = table.of(ue, e);
            if l.is_finite() && l < best {
                best = l;
            }
        }
        if !best.is_finite() {
            return Err(format!("infeasible: UE {ue} has no finite link latency"));
        }
        if best > lb_best {
            lb_best = best;
        }
    }

    // ub: greedy witness — each UE takes its cheapest edge with spare
    // capacity (UE id order). If a UE only finds non-finite spare links
    // the witness degrades to +inf and the window covers every finite
    // candidate at or above lb_best.
    let mut load = vec![0usize; m];
    let mut ub = f64::NEG_INFINITY;
    for ue in 0..n {
        let (mut pick, mut pick_lat) = (usize::MAX, f64::INFINITY);
        for e in 0..m {
            if load[e] >= cap {
                continue;
            }
            let l = table.of(ue, e);
            if l.is_finite() && l < pick_lat {
                (pick, pick_lat) = (e, l);
            }
        }
        if pick == usize::MAX {
            // n <= m·cap guarantees a spare slot exists somewhere.
            pick = (0..m).find(|&e| load[e] < cap).expect("spare capacity");
        }
        load[pick] += 1;
        if pick_lat > ub {
            ub = pick_lat;
        }
    }

    let mut cands: Vec<f64> = table
        .latency_s
        .iter()
        .copied()
        .filter(|l| l.is_finite() && *l >= lb_best && *l <= ub)
        .collect();
    cands.sort_unstable_by(|a, b| a.total_cmp(b));
    cands.dedup(); // all finite: PartialEq dedup is total here
    if cands.is_empty() {
        return Err("infeasible: no finite candidate threshold".to_string());
    }

    let mut hi = if ub.is_finite() {
        // ub is itself a table entry inside the window: a known-feasible
        // anchor, no probe needed.
        cands.partition_point(|x| *x < ub)
    } else {
        let last = cands.len() - 1;
        if !feasible_at(table, cap, cands[last]) {
            return Err("no feasible assignment within finite latencies".to_string());
        }
        last
    };
    let mut lo = 0usize;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible_at(table, cap, cands[mid]) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Ok(cands[lo])
}

/// Can every UE be placed on an edge with link latency ≤ z without any
/// edge exceeding `cap`? Exact, via max-flow on the aggregated network
/// source → mask-group(|group|) → admissible edges → sink(cap): UEs with
/// the same admissible set are exchangeable, so collapsing them preserves
/// the max-flow value, and total unimodularity makes the integral answer
/// equal the fractional (LP) one.
fn feasible_at(table: &LatencyTable, cap: usize, z: f64) -> bool {
    let (n, m) = (table.num_ues, table.num_edges);
    let words = m.div_ceil(64);
    let mut masks = vec![0u64; n * words];
    for ue in 0..n {
        let base = ue * words;
        let mut any = false;
        for e in 0..m {
            // NaN/+inf entries (degenerate or down-edge-poisoned links)
            // fail `<= z` for every finite z and never become admissible.
            if table.of(ue, e) <= z {
                masks[base + e / 64] |= 1u64 << (e % 64);
                any = true;
            }
        }
        if !any {
            return false;
        }
    }

    // Group UEs by admissible mask: an index sort on the mask words (R1:
    // no hash maps; ties need no ordering — only group sizes matter).
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.sort_unstable_by(|&a, &b| {
        let (a, b) = (a as usize * words, b as usize * words);
        masks[a..a + words].cmp(&masks[b..b + words])
    });

    let mask_of = |ue: usize| &masks[ue * words..ue * words + words];
    let mut groups: Vec<(usize, usize)> = Vec::new(); // (representative ue, count)
    for &ue in &idx {
        let ue = ue as usize;
        match groups.last_mut() {
            Some((rep, count)) if mask_of(*rep) == mask_of(ue) => *count += 1,
            _ => groups.push((ue, 1)),
        }
    }

    let g = groups.len();
    let (src, snk) = (g + m, g + m + 1);
    let mut flow = Dinic::new(g + m + 2);
    for (gi, &(rep, count)) in groups.iter().enumerate() {
        flow.add_edge(src, gi, count as i64);
        let base = rep * words;
        for e in 0..m {
            if masks[base + e / 64] & (1u64 << (e % 64)) != 0 {
                flow.add_edge(gi, g + e, count.min(cap) as i64);
            }
        }
    }
    for e in 0..m {
        flow.add_edge(g + e, snk, cap as i64);
    }
    flow.max_flow(src, snk) == n as i64
}

/// Min-cost-flow association: restrict arcs to the optimal min-max
/// threshold `z*` from [`flow_lower_bound`], then run successive shortest
/// paths — the result achieves the exact bottleneck optimum and, among
/// all such assignments, the minimum total latency. O(n · nm log nm):
/// practical to a few thousand UEs.
pub fn solve_flow(table: &LatencyTable, cap: usize) -> Result<Association, String> {
    let (n, m) = (table.num_ues, table.num_edges);
    let z = flow_lower_bound(table, cap)?;
    if n == 0 {
        return Ok(Association::new(Vec::new(), m));
    }

    let (src, snk) = (n + m, n + m + 1);
    let mut mcmf = MinCostFlow::new(n + m + 2);
    let mut ue_arcs: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    for ue in 0..n {
        mcmf.add_edge(src, ue, 1, 0.0);
        for e in 0..m {
            let l = table.of(ue, e);
            if l <= z {
                let arc = mcmf.add_edge(ue, n + e, 1, l);
                ue_arcs[ue].push((arc, e));
            }
        }
    }
    for e in 0..m {
        mcmf.add_edge(n + e, snk, cap as i64, 0.0);
    }
    if mcmf.solve(src, snk) != n as i64 {
        // flow_lower_bound proved z feasible; only a capacity/threshold
        // inconsistency could land here.
        return Err("min-cost flow could not place every UE".to_string());
    }

    let mut edge_of = vec![usize::MAX; n];
    for ue in 0..n {
        for &(arc, e) in &ue_arcs[ue] {
            if mcmf.arc_flow(arc) > 0 {
                edge_of[ue] = e;
            }
        }
    }
    let assoc = Association::new(edge_of, m);
    assoc.validate(cap)?;
    Ok(assoc)
}

// ---------------------------------------------------------------------
// Min-cost max-flow: successive shortest paths, Dijkstra with Johnson
// potentials. Deterministic: fixed arc order, heap ties broken by node.
// ---------------------------------------------------------------------

struct MinCostFlow {
    to: Vec<usize>,
    cap: Vec<i64>,
    cost: Vec<f64>,
    head: Vec<Vec<usize>>,
    initial_cap: Vec<i64>,
}

#[derive(Clone, Copy)]
struct HeapEntry {
    dist: f64,
    node: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapEntry {}
impl Ord for HeapEntry {
    // Reversed: BinaryHeap is a max-heap, we pop the smallest distance;
    // equal distances pop in ascending node order.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .dist
            .total_cmp(&self.dist)
            .then_with(|| other.node.cmp(&self.node))
    }
}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl MinCostFlow {
    fn new(nodes: usize) -> MinCostFlow {
        MinCostFlow {
            to: Vec::new(),
            cap: Vec::new(),
            cost: Vec::new(),
            head: vec![Vec::new(); nodes],
            initial_cap: Vec::new(),
        }
    }

    /// Returns the arc index of the forward edge (reverse lives at ^ 1).
    fn add_edge(&mut self, from: usize, to: usize, cap: i64, cost: f64) -> usize {
        let idx = self.to.len();
        self.to.push(to);
        self.cap.push(cap);
        self.cost.push(cost);
        self.initial_cap.push(cap);
        self.head[from].push(idx);
        self.to.push(from);
        self.cap.push(0);
        self.cost.push(-cost);
        self.initial_cap.push(0);
        self.head[to].push(idx + 1);
        idx
    }

    fn arc_flow(&self, arc: usize) -> i64 {
        self.initial_cap[arc] - self.cap[arc]
    }

    /// Push flow until the sink is unreachable; returns the total flow.
    fn solve(&mut self, src: usize, snk: usize) -> i64 {
        let nodes = self.head.len();
        let mut potential = vec![0.0f64; nodes];
        let mut dist = vec![f64::INFINITY; nodes];
        let mut prev_arc = vec![usize::MAX; nodes];
        let mut total = 0i64;
        loop {
            dist.fill(f64::INFINITY);
            prev_arc.fill(usize::MAX);
            dist[src] = 0.0;
            let mut heap = BinaryHeap::new();
            heap.push(HeapEntry { dist: 0.0, node: src });
            while let Some(HeapEntry { dist: d, node: v }) = heap.pop() {
                if d > dist[v] {
                    continue;
                }
                for &arc in &self.head[v] {
                    if self.cap[arc] <= 0 {
                        continue;
                    }
                    let u = self.to[arc];
                    let nd = d + self.cost[arc] + potential[v] - potential[u];
                    if nd < dist[u] {
                        dist[u] = nd;
                        prev_arc[u] = arc;
                        heap.push(HeapEntry { dist: nd, node: u });
                    }
                }
            }
            if !dist[snk].is_finite() {
                return total;
            }
            // Cap potentials at dist[snk] so nodes the search did not
            // settle this round keep non-negative reduced costs.
            let cut = dist[snk];
            for (p, d) in potential.iter_mut().zip(&dist) {
                *p += d.min(cut);
            }
            let mut push = i64::MAX;
            let mut v = snk;
            while v != src {
                let arc = prev_arc[v];
                push = push.min(self.cap[arc]);
                v = self.to[arc ^ 1];
            }
            let mut v = snk;
            while v != src {
                let arc = prev_arc[v];
                self.cap[arc] -= push;
                self.cap[arc ^ 1] += push;
                v = self.to[arc ^ 1];
            }
            total += push;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::{greedy, solve_exact_matching, time_minimized};
    use crate::net::{Channel, SystemParams, Topology};

    fn table(edges: usize, ues: usize, seed: u64) -> (Topology, Channel, LatencyTable) {
        let t = Topology::sample(&SystemParams::default(), edges, ues, seed);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        let lt = LatencyTable::build(&t, &ch, 20.0);
        (t, ch, lt)
    }

    #[test]
    fn bound_equals_exact_matching_objective() {
        for seed in 0..8 {
            let (_t, _ch, lt) = table(4, 24, seed);
            let exact = solve_exact_matching(&lt, 8).unwrap();
            let bound = flow_lower_bound(&lt, 8).unwrap();
            // Both are the same table entry: exact equality, no tolerance.
            assert_eq!(
                bound.to_bits(),
                lt.max_latency(&exact).to_bits(),
                "seed {seed}: bound {bound} vs exact {}",
                lt.max_latency(&exact)
            );
        }
    }

    #[test]
    fn bound_is_below_every_heuristic() {
        for seed in 0..8 {
            let (_t, ch, lt) = table(5, 40, 100 + seed);
            let bound = flow_lower_bound(&lt, 10).unwrap();
            for assoc in [greedy(&ch, 10).unwrap(), time_minimized(&ch, 10).unwrap()] {
                let cert = Certificate::new(bound, lt.max_latency(&assoc));
                assert!(cert.holds(), "seed {seed}: {cert:?}");
                assert!(cert.gap >= 0.0);
            }
        }
    }

    #[test]
    fn solve_flow_achieves_the_bound() {
        for seed in 0..5 {
            let (_t, _ch, lt) = table(4, 20, 200 + seed);
            let a = solve_flow(&lt, 6).unwrap();
            a.validate(6).unwrap();
            let cert = certify(&lt, 6, &a).unwrap();
            assert_eq!(
                cert.gap.to_bits(),
                0.0f64.to_bits(),
                "seed {seed}: flow assignment must meet its own bound, got {cert:?}"
            );
        }
    }

    #[test]
    fn solve_flow_minimizes_total_latency_among_optima() {
        // On a cap-slack instance the min-cost refinement must not exceed
        // the total latency of the exact matching solution.
        for seed in 0..5 {
            let (_t, _ch, lt) = table(3, 12, 300 + seed);
            let flow = solve_flow(&lt, 6).unwrap();
            let exact = solve_exact_matching(&lt, 6).unwrap();
            let sum = |a: &Association| -> f64 {
                a.edge_of
                    .iter()
                    .enumerate()
                    .map(|(ue, &e)| lt.of(ue, e))
                    .sum()
            };
            assert!(
                sum(&flow) <= sum(&exact) + 1e-9,
                "seed {seed}: flow total {} > exact total {}",
                sum(&flow),
                sum(&exact)
            );
        }
    }

    #[test]
    fn bound_ignores_poisoned_columns() {
        let (_t, _ch, mut lt) = table(3, 9, 41);
        let baseline = {
            let mut clean = lt.clone();
            let m = clean.num_edges;
            for ue in 0..clean.num_ues {
                clean.latency_s[ue * m] = f64::INFINITY;
            }
            flow_lower_bound(&clean, 5).unwrap()
        };
        let m = lt.num_edges;
        for ue in 0..lt.num_ues {
            lt.latency_s[ue * m] = f64::INFINITY;
        }
        let bound = flow_lower_bound(&lt, 5).unwrap();
        assert!(bound.is_finite());
        assert_eq!(bound.to_bits(), baseline.to_bits());
        // Cross-check against the fixed exact matching on the same table.
        let exact = solve_exact_matching(&lt, 5).unwrap();
        assert_eq!(bound.to_bits(), lt.max_latency(&exact).to_bits());
    }

    #[test]
    fn degenerate_and_infeasible_tables_err() {
        let (_t, _ch, mut lt) = table(2, 6, 43);
        for z in lt.latency_s.iter_mut() {
            *z = f64::NAN;
        }
        assert!(flow_lower_bound(&lt, 4).is_err());
        let (_t, _ch, lt) = table(2, 10, 17);
        assert!(flow_lower_bound(&lt, 4).is_err()); // 10 UEs > 2 x 4
    }

    #[test]
    fn empty_world_is_a_zero_bound() {
        let lt = LatencyTable {
            num_ues: 0,
            num_edges: 3,
            latency_s: Vec::new(),
        };
        assert_eq!(flow_lower_bound(&lt, 2).unwrap(), 0.0);
        let a = solve_flow(&lt, 2).unwrap();
        assert_eq!(a.num_ues(), 0);
    }

    #[test]
    fn bound_scales_past_the_matching_test_sizes() {
        // Not a perf assertion (that lives in benches/assoc_gap.rs), just
        // the aggregated path exercised well past the raw-Dinic shapes.
        let (_t, _ch, lt) = table(8, 2000, 71);
        let bound = flow_lower_bound(&lt, 300).unwrap();
        assert!(bound.is_finite() && bound > 0.0);
        let exact = solve_exact_matching(&lt, 300).unwrap();
        assert_eq!(bound.to_bits(), lt.max_latency(&exact).to_bits());
    }

    #[test]
    fn masks_span_multiple_words() {
        // 70 edges forces two mask words; the bound must still agree with
        // the exact matching solver.
        let (_t, _ch, lt) = table(70, 140, 91);
        let bound = flow_lower_bound(&lt, 2).unwrap();
        let exact = solve_exact_matching(&lt, 2).unwrap();
        assert_eq!(bound.to_bits(), lt.max_latency(&exact).to_bits());
    }
}
