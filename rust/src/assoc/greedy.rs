//! Greedy baseline (paper §V-C): each edge server in turn takes the
//! still-available UEs with maximum SNR, up to the bandwidth cap.
//!
//! Thin wrapper over [`GreedyPolicy`]'s cold path (per-edge rankings +
//! the shared `edgewise_take` walk, same machinery the warm engine
//! maintains incrementally). One deliberate behavior change vs the seed:
//! exact SNR ties now break by lower UE id on *every* edge — the seed's
//! stable re-sort of the shrinking `available` list made tie order
//! path-dependent past edge 0.

use super::incremental::{AssocCtx, AssocPolicy, GreedyPolicy};
use super::Association;
use crate::net::Channel;

pub fn greedy(channel: &Channel, cap: usize) -> Result<Association, String> {
    let ids: Vec<usize> = (0..channel.num_ues).collect();
    let ctx = AssocCtx {
        channel,
        topo: None,
        edge_up: None,
    };
    let edge_of = GreedyPolicy.assign_cold(&ctx, &ids, cap)?;
    let assoc = Association::new(edge_of, channel.num_edges);
    assoc.validate(cap)?;
    Ok(assoc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Channel, SystemParams, Topology};

    #[test]
    fn feasible_and_complete() {
        let t = Topology::sample(&SystemParams::default(), 5, 100, 2);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        let a = greedy(&ch, 20).unwrap();
        a.validate(20).unwrap();
        assert!(a.edge_of.iter().all(|&m| m < 5));
    }

    #[test]
    fn first_edge_gets_its_best_ues() {
        let t = Topology::sample(&SystemParams::default(), 3, 30, 7);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        let a = greedy(&ch, 20).unwrap();
        // Every UE on edge 0 has SNR toward edge 0 at least as large as
        // every UE NOT on edge 0 (they were taken first).
        let on0: Vec<usize> = (0..30).filter(|&n| a.edge_of[n] == 0).collect();
        let off0: Vec<usize> = (0..30).filter(|&n| a.edge_of[n] != 0).collect();
        let min_on = on0
            .iter()
            .map(|&n| ch.snr_of(n, 0))
            .fold(f64::INFINITY, f64::min);
        let max_off = off0
            .iter()
            .map(|&n| ch.snr_of(n, 0))
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(min_on >= max_off);
    }

    #[test]
    fn infeasible_detected() {
        let t = Topology::sample(&SystemParams::default(), 1, 30, 9);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        assert!(greedy(&ch, 20).is_err());
    }
}
