//! [`ScenarioRun`] — the one builder-style entry point for executing
//! scenarios, single-instance or batched.
//!
//! Historically the API sprawled across five free functions
//! (`run_instance`, `run_instance_traced`, `run_batch`,
//! `run_batch_with`, `run_batch_traced`) whose names encoded their
//! option combinations. `ScenarioRun` replaces the combinatorics with a
//! builder:
//!
//! ```ignore
//! // One instance, custom seed, observed by a sink:
//! let out = ScenarioRun::new(&spec).seed(7).sink(&mut sink).run()?;
//! // A batch with a progress callback:
//! let batch = ScenarioRun::new(&spec).on_outcome(|i, _| done(i)).run_batch()?;
//! // A traced batch (one JSONL sink per instance, slotted by index):
//! let (batch, sinks) = ScenarioRun::new(&spec).run_batch_traced()?;
//! // A batch streaming through custom per-instance sinks (serve path):
//! let (batch, _) = ScenarioRun::new(&spec).run_batch_with_sinks(mk_sink)?;
//! ```
//!
//! The old free functions survive as thin delegating shims so callers
//! migrate incrementally; they add no behavior.

use super::dynamics::{run_instance_traced, ScenarioOutcome};
use super::runner::{run_batch_sinked, BatchResult};
use super::spec::ScenarioSpec;
use crate::trace::{JsonlSink, NullSink, TraceSink};

/// Builder for a scenario execution. See the module docs for the
/// grammar; every terminal (`run`, `run_batch`, `run_batch_traced`,
/// `run_batch_with_sinks`) consumes the builder.
pub struct ScenarioRun<'a> {
    spec: &'a ScenarioSpec,
    seed: Option<u64>,
    sink: Option<&'a mut dyn TraceSink>,
    on_outcome: Option<Box<dyn FnMut(usize, &ScenarioOutcome) + 'a>>,
}

impl<'a> ScenarioRun<'a> {
    pub fn new(spec: &'a ScenarioSpec) -> Self {
        ScenarioRun {
            spec,
            seed: None,
            sink: None,
            on_outcome: None,
        }
    }

    /// Override the seed. For [`run`](Self::run) this is the instance
    /// seed itself; for the batch terminals it replaces
    /// `spec.base.seed` as the root of the per-instance seed stream.
    /// Default: `spec.base.seed` either way.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Observe the run through a [`TraceSink`]. Only meaningful for
    /// [`run`](Self::run): a batch needs one sink *per instance* (use
    /// [`run_batch_traced`](Self::run_batch_traced) or
    /// [`run_batch_with_sinks`](Self::run_batch_with_sinks)), so the
    /// batch terminals reject a builder-level sink instead of silently
    /// dropping it.
    pub fn sink(mut self, sink: &'a mut dyn TraceSink) -> Self {
        self.sink = Some(sink);
        self
    }

    /// Completion callback, invoked on the calling thread as each
    /// instance finishes (completion order — use it for progress, not
    /// for ordering-sensitive logic). [`run`](Self::run) invokes it once
    /// with index 0.
    pub fn on_outcome<F: FnMut(usize, &ScenarioOutcome) + 'a>(mut self, f: F) -> Self {
        self.on_outcome = Some(Box::new(f));
        self
    }

    /// Run one instance end to end. Pure function of `(spec, seed)`.
    pub fn run(self) -> Result<ScenarioOutcome, String> {
        let seed = self.seed.unwrap_or(self.spec.base.seed);
        let mut null = NullSink;
        let sink: &mut dyn TraceSink = match self.sink {
            Some(s) => s,
            None => &mut null,
        };
        let out = run_instance_traced(self.spec, seed, sink)?;
        if let Some(mut f) = self.on_outcome {
            f(0, &out);
        }
        Ok(out)
    }

    /// Run the spec's batch on the sharded runner (no per-instance
    /// tracing). Bit-for-bit identical outcomes for any shard count.
    pub fn run_batch(self) -> Result<BatchResult, String> {
        self.run_batch_with_sinks(|_| NullSink)
            .map(|(batch, _)| batch)
    }

    /// [`run_batch`](Self::run_batch) with one [`JsonlSink`] per
    /// instance, returned in instance order (ready to concatenate into
    /// one `--trace` file; content is shard-count independent).
    pub fn run_batch_traced(self) -> Result<(BatchResult, Vec<JsonlSink>), String> {
        self.run_batch_with_sinks(JsonlSink::for_instance)
    }

    /// The generic batch terminal: each instance runs through its own
    /// sink built by `mk_sink(index)`; sinks come back slotted by
    /// instance index exactly like outcomes. This is how `hfl serve`
    /// streams per-epoch events to clients while a job runs.
    pub fn run_batch_with_sinks<S, G>(self, mk_sink: G) -> Result<(BatchResult, Vec<S>), String>
    where
        S: TraceSink + Send,
        G: Fn(usize) -> S + Sync,
    {
        if self.sink.is_some() {
            return Err(
                "ScenarioRun::sink observes a single run(); a batch needs one sink per \
                 instance — use run_batch_traced() or run_batch_with_sinks(mk_sink)"
                    .into(),
            );
        }
        let reseeded;
        let spec = match self.seed {
            Some(s) if s != self.spec.base.seed => {
                reseeded = self.spec.clone().seed(s);
                &reseeded
            }
            _ => self.spec,
        };
        let mut on_outcome = self.on_outcome;
        run_batch_sinked(spec, mk_sink, move |i, o| {
            if let Some(f) = on_outcome.as_mut() {
                f(i, o);
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{run_batch, run_instance};
    use crate::trace::StatsSink;

    fn spec() -> ScenarioSpec {
        ScenarioSpec::new().edges(2).ues(8).instances(3).shards(2)
    }

    #[test]
    fn run_matches_free_function() {
        let spec = spec();
        let a = ScenarioRun::new(&spec).seed(77).run().unwrap();
        let b = run_instance(&spec, 77).unwrap();
        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    }

    #[test]
    fn run_invokes_sink_and_callback() {
        let spec = spec();
        let mut sink = StatsSink::default();
        let mut called = 0usize;
        let out = ScenarioRun::new(&spec)
            .sink(&mut sink)
            .on_outcome(|i, _| {
                assert_eq!(i, 0);
                called += 1;
            })
            .run()
            .unwrap();
        assert_eq!(called, 1);
        assert_eq!(sink.epochs, out.epochs + 1, "final partial epoch counts");
    }

    #[test]
    fn batch_matches_free_function_and_reseeds() {
        let spec = spec();
        let a = ScenarioRun::new(&spec).run_batch().unwrap();
        let b = run_batch(&spec).unwrap();
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits());
        }
        // .seed(s) on a batch re-roots the instance seed stream.
        let c = ScenarioRun::new(&spec).seed(spec.base.seed ^ 1).run_batch().unwrap();
        assert_ne!(
            a.outcomes[0].makespan_s.to_bits(),
            c.outcomes[0].makespan_s.to_bits()
        );
    }

    #[test]
    fn batch_rejects_builder_level_sink() {
        let spec = spec();
        let mut sink = StatsSink::default();
        let err = ScenarioRun::new(&spec).sink(&mut sink).run_batch().unwrap_err();
        assert!(err.contains("one sink per"), "got '{err}'");
    }
}
