//! Sharded parallel batch executor.
//!
//! Runs hundreds of scenario instances concurrently over a work-stealing
//! index queue: `shards` worker threads (std threads, scoped borrows — no
//! per-instance allocation of world state crosses threads) claim the next
//! instance index from a shared atomic counter, run it, and stream the
//! outcome back over a channel to the caller's thread.
//!
//! **Determinism.** Every instance seed is derived up front from the batch
//! base seed — never from the shard that happens to execute it — and
//! outcomes are slotted by instance index. The batch output is therefore
//! bit-for-bit identical for any shard count (property-tested in
//! `tests/scenario.rs` for 1 vs 8 shards).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

use super::dynamics::{run_instance_traced, ScenarioOutcome};
use super::spec::ScenarioSpec;
use crate::trace::{JsonlSink, TraceSink};
use crate::util::Rng;

/// Output of a batch run.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// One outcome per instance, in instance order.
    pub outcomes: Vec<ScenarioOutcome>,
    /// Wall-clock of the whole batch (seconds).
    pub wall_s: f64,
    /// Shards actually used.
    pub shards: usize,
}

impl BatchResult {
    /// Batch throughput in instances per second.
    pub fn instances_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.outcomes.len() as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Resolve a requested shard count (0 = one per available core).
pub fn shard_count(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Per-instance seeds, derived from the batch base seed only (shard- and
/// schedule-independent by construction).
pub fn instance_seeds(base_seed: u64, instances: usize) -> Vec<u64> {
    // hfl-lint: allow(R4, this is the batch's seed-stream root; every instance RNG forks from it)
    let mut rng = Rng::new(base_seed ^ 0xBA7C_5EED_0F1E_E75A);
    (0..instances).map(|_| rng.next_u64()).collect()
}

/// Shared executor: each worker builds its instance's sink via
/// `mk_sink(index)`, runs the instance through it, and ships both back.
/// Sinks are slotted by instance index exactly like outcomes, so traced
/// batches inherit the shard-count independence of the runner (the
/// concatenated per-instance streams never depend on scheduling).
/// Crate-internal primitive behind [`crate::scenario::ScenarioRun`].
pub(crate) fn run_batch_sinked<S, G, F>(
    spec: &ScenarioSpec,
    mk_sink: G,
    on_done: F,
) -> Result<(BatchResult, Vec<S>), String>
where
    S: TraceSink + Send,
    G: Fn(usize) -> S + Sync,
    F: FnMut(usize, &ScenarioOutcome),
{
    run_batch_core(spec, mk_sink, on_done, |_, seed, sink| {
        run_instance_traced(spec, seed, sink)
    })
}

/// The executor behind [`run_batch_sinked`], generic over the per-instance
/// run function so the failure-reporting contract is directly testable.
///
/// **Error reporting is schedule-independent.** On failure the batch
/// reports the *lowest-index* failing instance, for any shard count. The
/// old code returned the first error *received* — completion order, so
/// which error surfaced depended on shard scheduling. The argument for the
/// fix: workers claim indices from one atomic counter, so claims are
/// handed out in increasing order; the abort flag is only set *after* an
/// error for some claimed index `j` arrives, by which point every index
/// `< j` — in particular the globally lowest failing index — was already
/// claimed; claimed instances always run to completion (the flag is
/// checked before claiming, never mid-run) and the receiver drains the
/// channel until every worker is done. The minimum over received errors is
/// therefore the minimum over all errors the serial run would hit.
fn run_batch_core<S, G, F, R>(
    spec: &ScenarioSpec,
    mk_sink: G,
    mut on_done: F,
    run_one: R,
) -> Result<(BatchResult, Vec<S>), String>
where
    S: TraceSink + Send,
    G: Fn(usize) -> S + Sync,
    F: FnMut(usize, &ScenarioOutcome),
    R: Fn(usize, u64, &mut S) -> Result<ScenarioOutcome, String> + Sync,
{
    spec.validate()?;
    let instances = spec.batch.instances;
    let shards = shard_count(spec.batch.shards).min(instances.max(1));
    let seeds = instance_seeds(spec.base.seed, instances);
    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    // hfl-lint: allow(R3, batch wall-time report only; no simulated quantity derives from it)
    let t0 = std::time::Instant::now();

    type Slot<S> = (usize, Result<ScenarioOutcome, String>, S);
    let (outcomes, sinks) =
        std::thread::scope(|scope| -> Result<(Vec<ScenarioOutcome>, Vec<S>), String> {
            let (tx, rx) = mpsc::channel::<Slot<S>>();
            for _ in 0..shards {
                let tx = tx.clone();
                let next = &next;
                let abort = &abort;
                let seeds = &seeds;
                let mk_sink = &mk_sink;
                let run_one = &run_one;
                scope.spawn(move || loop {
                    // Checked before claiming only: once an index is
                    // claimed it always runs and reports (the lowest-index
                    // failure argument above depends on this). Relaxed is
                    // enough — the flag is a stop-claiming hint, the
                    // channel carries all the data.
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= instances {
                        break;
                    }
                    let mut sink = mk_sink(i);
                    let result = run_one(i, seeds[i], &mut sink).map(|mut o| {
                        o.instance = i;
                        o
                    });
                    // Receiver gone — stop claiming work.
                    if tx.send((i, result, sink)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);

            let mut slots: Vec<Option<ScenarioOutcome>> = (0..instances).map(|_| None).collect();
            let mut sink_slots: Vec<Option<S>> = (0..instances).map(|_| None).collect();
            let mut first_err: Option<(usize, String)> = None;
            // hfl-lint: allow(R6, results land in index slots; the lowest-index error wins)
            for (i, result, sink) in rx {
                match result {
                    Ok(outcome) => {
                        if first_err.is_none() {
                            on_done(i, &outcome);
                        }
                        slots[i] = Some(outcome);
                        sink_slots[i] = Some(sink);
                    }
                    Err(e) => {
                        abort.store(true, Ordering::Relaxed);
                        if first_err.as_ref().map_or(true, |(j, _)| i < *j) {
                            first_err = Some((i, e));
                        }
                    }
                }
            }
            if let Some((i, e)) = first_err {
                return Err(format!("scenario instance {i}: {e}"));
            }
            Ok((
                slots
                    .into_iter()
                    .map(|slot| slot.expect("runner: instance never reported"))
                    .collect(),
                sink_slots
                    .into_iter()
                    .map(|slot| slot.expect("runner: instance sink never reported"))
                    .collect(),
            ))
        })?;

    Ok((
        BatchResult {
            outcomes,
            wall_s: t0.elapsed().as_secs_f64(),
            shards,
        },
        sinks,
    ))
}

/// Run the spec's batch, invoking `on_done(index, outcome)` on the calling
/// thread as each instance completes (completion order — use it for
/// progress, not for ordering-sensitive logic).
///
/// Thin shim over [`crate::scenario::ScenarioRun`] (the unified entry).
pub fn run_batch_with<F: FnMut(usize, &ScenarioOutcome)>(
    spec: &ScenarioSpec,
    on_done: F,
) -> Result<BatchResult, String> {
    crate::scenario::ScenarioRun::new(spec)
        .on_outcome(on_done)
        .run_batch()
}

/// [`run_batch_with`] with a [`JsonlSink`] per instance: returns the
/// batch plus the per-instance event streams, in instance order (ready
/// to concatenate into one `--trace` file — the content is identical for
/// every shard count).
///
/// Thin shim over [`crate::scenario::ScenarioRun`] (the unified entry).
pub fn run_batch_traced<F: FnMut(usize, &ScenarioOutcome)>(
    spec: &ScenarioSpec,
    on_done: F,
) -> Result<(BatchResult, Vec<JsonlSink>), String> {
    crate::scenario::ScenarioRun::new(spec)
        .on_outcome(on_done)
        .run_batch_traced()
}

/// [`run_batch_with`] without a progress callback.
///
/// Thin shim over [`crate::scenario::ScenarioRun`] (the unified entry).
pub fn run_batch(spec: &ScenarioSpec) -> Result<BatchResult, String> {
    crate::scenario::ScenarioRun::new(spec).run_batch()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn seeds_are_schedule_independent_and_distinct() {
        let a = instance_seeds(42, 32);
        let b = instance_seeds(42, 32);
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), a.len(), "instance seeds must be distinct");
        // A longer batch extends, not reshuffles, the seed sequence.
        let longer = instance_seeds(42, 64);
        assert_eq!(&longer[..32], &a[..]);
    }

    #[test]
    fn shard_count_resolution() {
        assert_eq!(shard_count(3), 3);
        assert!(shard_count(0) >= 1);
    }

    #[test]
    fn small_batch_runs_and_slots_in_order() {
        let spec = crate::scenario::ScenarioSpec::new()
            .edges(2)
            .ues(8)
            .instances(5)
            .shards(2);
        let batch = run_batch(&spec).unwrap();
        assert_eq!(batch.outcomes.len(), 5);
        for (i, o) in batch.outcomes.iter().enumerate() {
            assert_eq!(o.instance, i);
            assert!(o.makespan_s > 0.0);
            assert!(o.converged);
        }
        assert!(batch.instances_per_s() > 0.0);
    }

    #[test]
    fn failing_batch_reports_lowest_index_for_any_shard_count() {
        // Regression: the runner used to surface the first error *received*
        // (completion order), so the reported instance depended on shard
        // scheduling. With injected failures at indices 3 and 5, every
        // shard count must report instance 3.
        let spec = crate::scenario::ScenarioSpec::new()
            .edges(2)
            .ues(6)
            .instances(8);
        for shards in [1usize, 8] {
            let spec = spec.clone().shards(shards);
            let err = run_batch_core(
                &spec,
                |_| NullSink,
                |_, _| {},
                |i, seed, sink| {
                    if i == 3 || i == 5 {
                        Err("injected failure".to_string())
                    } else {
                        run_instance_traced(&spec, seed, sink)
                    }
                },
            )
            .unwrap_err();
            assert!(
                err.starts_with("scenario instance 3:"),
                "shards={shards}: reported '{err}', want instance 3"
            );
        }
    }

    #[test]
    fn callback_sees_every_instance() {
        let spec = crate::scenario::ScenarioSpec::new()
            .edges(2)
            .ues(6)
            .instances(7)
            .shards(3);
        let mut seen = vec![false; 7];
        run_batch_with(&spec, |i, _| seen[i] = true).unwrap();
        assert!(seen.iter().all(|&s| s));
    }
}
