//! Aggregate reporting for scenario batches: percentile / CI summaries
//! over the fleet's outcomes (via `util/stats.rs`), per-instance series
//! for `metrics::Recorder`, and machine-readable JSON emission.

use std::io::Write;
use std::path::Path;

use super::dynamics::ScenarioOutcome;
use super::spec::ScenarioSpec;
use crate::metrics::Recorder;
use crate::trace::{Counter, Phase};
use crate::util::json::Json;
use crate::util::stats::{mean, percentile, std};

/// Distribution summary of one metric across a batch.
#[derive(Debug, Clone, Copy)]
pub struct SummaryStat {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
    /// Normal-approximation 95% confidence interval on the mean.
    pub ci95: (f64, f64),
}

impl SummaryStat {
    pub fn from_samples(xs: &[f64]) -> SummaryStat {
        if xs.is_empty() {
            return SummaryStat {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
                ci95: (0.0, 0.0),
            };
        }
        let m = mean(xs);
        let s = std(xs);
        let half = 1.96 * s / (xs.len() as f64).sqrt();
        SummaryStat {
            count: xs.len(),
            mean: m,
            std: s,
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p90: percentile(xs, 90.0),
            p99: percentile(xs, 99.0),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            ci95: (m - half, m + half),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean)),
            ("std", Json::num(self.std)),
            ("min", Json::num(self.min)),
            ("p50", Json::num(self.p50)),
            ("p90", Json::num(self.p90)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
            ("ci95_lo", Json::num(self.ci95.0)),
            ("ci95_hi", Json::num(self.ci95.1)),
        ])
    }
}

/// Aggregated view of one batch.
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub instances: usize,
    pub converged_frac: f64,
    pub makespan_s: SummaryStat,
    pub closed_form_s: SummaryStat,
    pub rounds: SummaryStat,
    pub epochs: SummaryStat,
    pub handovers: SummaryStat,
    pub arrivals: SummaryStat,
    pub departures: SummaryStat,
    pub dropped_uploads: SummaryStat,
    pub tau_max_s: SummaryStat,
    pub ue_barrier_wait_s: SummaryStat,
    /// Per-instance cumulative (a, b) re-solve wall time (seconds).
    pub resolve_time_s: SummaryStat,
    /// Per-instance cumulative association wall time (seconds).
    pub assoc_time_s: SummaryStat,
    /// Per-instance cumulative reprocessed-UE counts (the incremental
    /// association engine's work metric).
    pub reassociations: SummaryStat,
    /// Per-instance participation rate: fraction of scheduled uploads
    /// that made their barrier (dropout + deadline losses excluded).
    pub participation_rate: SummaryStat,
    /// Per-instance deadline-dropped uploads.
    pub late_uploads: SummaryStat,
    /// Per-instance edge up→down transitions (outage process).
    pub outages: SummaryStat,
    /// Per-instance Σ over epochs of down-edge counts (outage exposure).
    pub down_edge_epochs: SummaryStat,
    /// Last-epoch flow lower bound on the min-max association latency
    /// (problem (39)); all-zero unless the spec ran with `certify = true`.
    pub assoc_lower_bound: SummaryStat,
    /// Last-epoch certificate gap `achieved − lower_bound`; all-zero
    /// unless `certify = true`.
    pub assoc_gap: SummaryStat,
    /// Per-phase cumulative wall time (seconds), one entry per
    /// [`Phase`] in `Phase::ALL` order (name, distribution).
    pub phase_wall: Vec<(&'static str, SummaryStat)>,
    /// Per-counter totals, one entry per [`Counter`] in `Counter::ALL`
    /// order (name, distribution across instances).
    pub phase_counters: Vec<(&'static str, SummaryStat)>,
}

fn column<F: Fn(&ScenarioOutcome) -> f64>(outcomes: &[ScenarioOutcome], f: F) -> SummaryStat {
    let xs: Vec<f64> = outcomes.iter().map(f).collect();
    SummaryStat::from_samples(&xs)
}

impl BatchReport {
    pub fn from_outcomes(outcomes: &[ScenarioOutcome]) -> BatchReport {
        let converged = outcomes.iter().filter(|o| o.converged).count();
        BatchReport {
            instances: outcomes.len(),
            converged_frac: if outcomes.is_empty() {
                0.0
            } else {
                converged as f64 / outcomes.len() as f64
            },
            makespan_s: column(outcomes, |o| o.makespan_s),
            closed_form_s: column(outcomes, |o| o.closed_form_s),
            rounds: column(outcomes, |o| o.rounds as f64),
            epochs: column(outcomes, |o| o.epochs as f64),
            handovers: column(outcomes, |o| o.handovers as f64),
            arrivals: column(outcomes, |o| o.arrivals as f64),
            departures: column(outcomes, |o| o.departures as f64),
            dropped_uploads: column(outcomes, |o| o.dropped_uploads as f64),
            tau_max_s: column(outcomes, |o| o.tau_max_s),
            ue_barrier_wait_s: column(outcomes, |o| o.ue_barrier_wait_s),
            resolve_time_s: column(outcomes, |o| o.resolve_time_s),
            assoc_time_s: column(outcomes, |o| o.assoc_time_s),
            reassociations: column(outcomes, |o| o.reassociations as f64),
            participation_rate: column(outcomes, |o| o.participation_rate),
            late_uploads: column(outcomes, |o| o.late_uploads as f64),
            outages: column(outcomes, |o| o.outages as f64),
            down_edge_epochs: column(outcomes, |o| o.down_edge_epochs as f64),
            assoc_lower_bound: column(outcomes, |o| o.assoc_lower_bound),
            assoc_gap: column(outcomes, |o| o.assoc_gap),
            phase_wall: Phase::ALL
                .iter()
                .map(|&p| (p.name(), column(outcomes, |o| o.phase.wall(p))))
                .collect(),
            phase_counters: Counter::ALL
                .iter()
                .map(|&c| (c.name(), column(outcomes, |o| o.phase.count(c) as f64)))
                .collect(),
        }
    }

    /// JSON document, with the spec summary attached for provenance.
    pub fn to_json(&self, spec: Option<&ScenarioSpec>) -> Json {
        let mut fields = vec![
            ("instances", Json::num(self.instances as f64)),
            ("converged_frac", Json::num(self.converged_frac)),
            ("makespan_s", self.makespan_s.to_json()),
            ("closed_form_s", self.closed_form_s.to_json()),
            ("rounds", self.rounds.to_json()),
            ("epochs", self.epochs.to_json()),
            ("handovers", self.handovers.to_json()),
            ("arrivals", self.arrivals.to_json()),
            ("departures", self.departures.to_json()),
            ("dropped_uploads", self.dropped_uploads.to_json()),
            ("tau_max_s", self.tau_max_s.to_json()),
            ("ue_barrier_wait_s", self.ue_barrier_wait_s.to_json()),
            ("resolve_time_s", self.resolve_time_s.to_json()),
            ("assoc_time_s", self.assoc_time_s.to_json()),
            ("reassociations", self.reassociations.to_json()),
            ("participation_rate", self.participation_rate.to_json()),
            ("late_uploads", self.late_uploads.to_json()),
            ("outages", self.outages.to_json()),
            ("down_edge_epochs", self.down_edge_epochs.to_json()),
            ("assoc_lower_bound", self.assoc_lower_bound.to_json()),
            ("assoc_gap", self.assoc_gap.to_json()),
        ];
        fields.push((
            "phases",
            Json::obj(
                self.phase_wall
                    .iter()
                    .map(|(name, s)| (*name, s.to_json()))
                    .collect(),
            ),
        ));
        fields.push((
            "phase_counters",
            Json::obj(
                self.phase_counters
                    .iter()
                    .map(|(name, s)| (*name, s.to_json()))
                    .collect(),
            ),
        ));
        if let Some(spec) = spec {
            fields.insert(0, ("spec", Json::str(&spec.summary())));
        }
        Json::obj(fields)
    }

    /// Write the JSON report to `path` (creating parent dirs).
    pub fn write(&self, path: &Path, spec: Option<&ScenarioSpec>) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json(spec).to_string().as_bytes())
    }

    /// Human summary on stdout (the CLI's user-facing report — the
    /// `stdout-ok` markers exempt these lines from the CI print gate).
    pub fn print(&self) {
        let head = format!(
            "batch: {} instances, {:.1}% converged",
            self.instances,
            self.converged_frac * 100.0
        );
        println!("{head}"); // stdout-ok: display API
        let row = |name: &str, s: &SummaryStat| {
            let line = format!(
                "  {name:<18} mean {:>10.4}  ±{:>9.4}  p50 {:>10.4}  p90 {:>10.4}  p99 {:>10.4}  max {:>10.4}",
                s.mean, s.std, s.p50, s.p90, s.p99, s.max
            );
            println!("{line}"); // stdout-ok: display API
        };
        row("makespan_s", &self.makespan_s);
        row("rounds", &self.rounds);
        row("epochs", &self.epochs);
        row("handovers", &self.handovers);
        row("dropped_uploads", &self.dropped_uploads);
        row("late_uploads", &self.late_uploads);
        row("participation", &self.participation_rate);
        row("outages", &self.outages);
        row("ue_wait_s", &self.ue_barrier_wait_s);
        row("resolve_s", &self.resolve_time_s);
        row("assoc_s", &self.assoc_time_s);
        row("reassociations", &self.reassociations);
        for (name, s) in &self.phase_wall {
            if s.max > 0.0 {
                row(&format!("phase_{name}_s"), s);
            }
        }
    }
}

/// Stream per-instance rows into a [`Recorder`] series named
/// `scenario_instances` (one row per instance, instance order).
pub fn record_batch(outcomes: &[ScenarioOutcome], rec: &mut Recorder) {
    // Existing 24 columns first (byte-compatible with earlier CSVs),
    // then the per-phase wall and counter columns appended at the end.
    let mut columns: Vec<&str> = vec![
        "instance",
        "makespan_s",
        "closed_form_s",
        "rounds",
        "epochs",
        "a",
        "b",
        "handovers",
        "arrivals",
        "departures",
        "dropped_uploads",
        "late_uploads",
        "scheduled_uploads",
        "participation_rate",
        "outages",
        "recoveries",
        "down_edge_epochs",
        "events",
        "converged",
        "resolve_time_s",
        "resolves",
        "cold_resolves",
        "assoc_time_s",
        "reassociations",
    ];
    columns.extend(Phase::ALL.iter().map(|p| p.col()));
    columns.extend(Counter::ALL.iter().map(|c| c.col()));
    // Certificate columns last so every earlier column keeps its
    // position from pre-certificate CSVs.
    columns.push("assoc_lower_bound");
    columns.push("assoc_gap");
    let series = rec.series("scenario_instances", &columns);
    for o in outcomes {
        let mut row = vec![
            o.instance as f64,
            o.makespan_s,
            o.closed_form_s,
            o.rounds as f64,
            o.epochs as f64,
            o.a as f64,
            o.b as f64,
            o.handovers as f64,
            o.arrivals as f64,
            o.departures as f64,
            o.dropped_uploads as f64,
            o.late_uploads as f64,
            o.scheduled_uploads as f64,
            o.participation_rate,
            o.outages as f64,
            o.recoveries as f64,
            o.down_edge_epochs as f64,
            o.events as f64,
            if o.converged { 1.0 } else { 0.0 },
            o.resolve_time_s,
            o.resolves as f64,
            o.cold_resolves as f64,
            o.assoc_time_s,
            o.reassociations as f64,
        ];
        row.extend(Phase::ALL.iter().map(|&p| o.phase.wall(p)));
        row.extend(Counter::ALL.iter().map(|&c| o.phase.count(c) as f64));
        row.push(o.assoc_lower_bound);
        row.push(o.assoc_gap);
        series.push(row);
    }
}

/// Remove every *measured* (wall-clock-derived) field from a JSON
/// document, recursively, and re-serialize canonically: the top-level
/// `resolve_time_s` / `assoc_time_s` aggregates, any `phases` wall-time
/// object, bare `wall_s` fields, and `phase_<name>_s` columns. What
/// remains is the deterministic content — two runs of the same spec and
/// seed must agree *byte for byte* after this strip, which is exactly
/// the wire-vs-batch contract `hfl serve` is tested against (the trace
/// counterpart is [`crate::trace::strip_walls`]).
pub fn strip_measured(json_text: &str) -> Result<String, String> {
    fn measured(key: &str) -> bool {
        key == "resolve_time_s"
            || key == "assoc_time_s"
            || key == "phases"
            || key == "wall_s"
            || (key.starts_with("phase_") && key.ends_with("_s"))
    }
    fn strip(v: Json) -> Json {
        match v {
            Json::Obj(m) => Json::Obj(
                m.into_iter()
                    .filter(|(k, _)| !measured(k))
                    .map(|(k, v)| (k, strip(v)))
                    .collect(),
            ),
            Json::Arr(items) => Json::Arr(items.into_iter().map(strip).collect()),
            other => other,
        }
    }
    let v = Json::parse(json_text).map_err(|e| e.to_string())?;
    Ok(strip(v).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(makespan: f64, rounds: u64, converged: bool) -> ScenarioOutcome {
        ScenarioOutcome {
            instance: 0,
            seed: 0,
            makespan_s: makespan,
            closed_form_s: makespan,
            rounds,
            epochs: 1,
            converged,
            a: 10,
            b: 3,
            round_time_s: makespan / rounds.max(1) as f64,
            tau_max_s: 0.1,
            assoc_lower_bound: 0.0,
            assoc_gap: 0.0,
            handovers: 0,
            arrivals: 0,
            departures: 0,
            dropped_uploads: 0,
            late_uploads: 0,
            scheduled_uploads: rounds * 10,
            participation_rate: 1.0,
            outages: 0,
            recoveries: 0,
            down_edge_epochs: 0,
            events: rounds * 10,
            ue_barrier_wait_s: 0.0,
            edge_barrier_wait_s: 0.0,
            resolve_time_s: 0.0,
            resolves: 1,
            cold_resolves: 1,
            ab_per_epoch: vec![(10, 3)],
            assoc_time_s: 0.0,
            reassociations: 1,
            phase: crate::trace::PhaseStats::default(),
        }
    }

    #[test]
    fn summary_stat_matches_hand_computation() {
        let s = SummaryStat::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
        assert!(s.ci95.0 < s.mean && s.mean < s.ci95.1);
        let empty = SummaryStat::from_samples(&[]);
        assert_eq!(empty.count, 0);
        assert_eq!(empty.mean, 0.0);
    }

    #[test]
    fn summary_stat_single_sample() {
        // n = 1: no spread information — zero-width CI, all percentiles
        // collapse onto the sample.
        let s = SummaryStat::from_samples(&[2.5]);
        assert_eq!(s.count, 1);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.ci95, (2.5, 2.5));
        assert_eq!((s.p50, s.p90, s.p99), (2.5, 2.5, 2.5));
        assert_eq!((s.min, s.max), (2.5, 2.5));
    }

    #[test]
    fn summary_stat_accepts_unsorted_samples() {
        let s = SummaryStat::from_samples(&[9.0, 1.0, 5.0]);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn report_aggregates_and_serializes() {
        let outcomes = vec![
            outcome(10.0, 5, true),
            outcome(12.0, 5, true),
            outcome(20.0, 6, false),
        ];
        let report = BatchReport::from_outcomes(&outcomes);
        assert_eq!(report.instances, 3);
        assert!((report.converged_frac - 2.0 / 3.0).abs() < 1e-12);
        assert!((report.makespan_s.mean - 14.0).abs() < 1e-12);
        let json = report.to_json(None).to_string();
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(
            parsed.get("instances").and_then(Json::as_f64),
            Some(3.0)
        );
        assert!(parsed.get("makespan_s").and_then(|m| m.get("p90")).is_some());
        assert!(parsed
            .get("participation_rate")
            .and_then(|m| m.get("mean"))
            .is_some());
        assert!(parsed.get("outages").and_then(|m| m.get("max")).is_some());
        assert!(parsed.get("late_uploads").is_some());
    }

    #[test]
    fn strip_measured_removes_only_wall_derived_fields() {
        let report = BatchReport::from_outcomes(&[outcome(10.0, 5, true)]);
        let json = report.to_json(None).to_string();
        let stripped = strip_measured(&json).unwrap();
        for gone in ["resolve_time_s", "assoc_time_s", "\"phases\""] {
            assert!(json.contains(gone));
            assert!(!stripped.contains(gone), "{gone} must be stripped");
        }
        for kept in ["makespan_s", "participation_rate", "phase_counters"] {
            assert!(stripped.contains(kept), "{kept} must survive");
        }
        // Nested objects are stripped too.
        let nested = "{\"outer\":{\"wall_s\":1.5,\"epoch\":3},\"phase_sim_s\":0.2}";
        assert_eq!(strip_measured(nested).unwrap(), "{\"outer\":{\"epoch\":3}}");
    }

    #[test]
    fn recorder_rows_match_instances() {
        let outcomes = vec![outcome(1.0, 1, true), outcome(2.0, 2, true)];
        let mut rec = Recorder::new();
        record_batch(&outcomes, &mut rec);
        let series = &rec.series["scenario_instances"];
        assert_eq!(series.rows.len(), 2);
        assert_eq!(series.columns.len(), series.rows[0].len());
    }
}
