//! Declarative scenario specification.
//!
//! A [`ScenarioSpec`] fully describes a *batch* of scenario instances —
//! topology sampling + channel model (via the base [`Scenario`]),
//! association policy, optimizer mode, the failure model
//! (jitter/dropout), the time-varying **dynamics** block (random-waypoint
//! mobility + Poisson churn) and the batch shape (instances × shards).
//! Specs load from TOML (`util/toml.rs` subset) with CLI overrides, or
//! build fluently in code:
//!
//! ```no_run
//! use hfl::scenario::ScenarioSpec;
//! let spec = ScenarioSpec::new()
//!     .edges(5)
//!     .ues(100)
//!     .eps(0.25)
//!     .mobility(0.5, 2.0)
//!     .churn(0.5, 0.01)
//!     .jitter(0.1)
//!     .instances(256)
//!     .shards(8);
//! # let _ = spec;
//! ```

use crate::config::cli::CliError;
use crate::config::{Args, AssocStrategy, Scenario};
use crate::net::DeviceClassSpec;
use crate::util::toml::TomlDoc;

/// Which sub-problem-I solver the engine (re-)runs every epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptimizerMode {
    /// Exhaustive integer scan under ⌈R⌉ (the production path).
    #[default]
    Integer,
    /// Continuous relaxation (golden-section), rounded to the grid.
    Continuous,
    /// The paper's Algorithm 2 (subgradient on the Lagrange dual).
    Subgradient,
}

impl OptimizerMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "integer" | "exact" => Ok(OptimizerMode::Integer),
            "continuous" | "relaxed" => Ok(OptimizerMode::Continuous),
            "subgradient" | "alg2" => Ok(OptimizerMode::Subgradient),
            other => Err(format!(
                "unknown optimizer mode '{other}' (integer|continuous|subgradient)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerMode::Integer => "integer",
            OptimizerMode::Continuous => "continuous",
            OptimizerMode::Subgradient => "subgradient",
        }
    }
}

/// Per-epoch (a, b) re-solve strategy for dynamic scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResolveMode {
    /// Seed each epoch's solve from the previous epoch's optimum
    /// (exactness-preserving for the integer solver, tolerance-bounded
    /// for the continuous one). The default: dynamic worlds drift slowly,
    /// so the incumbent prunes most of the search.
    #[default]
    Warm,
    /// Solve every epoch from scratch — the pre-warm-start baseline the
    /// `resolve_warm` bench compares against.
    Cold,
}

impl ResolveMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "warm" | "incremental" => Ok(ResolveMode::Warm),
            "cold" | "scratch" => Ok(ResolveMode::Cold),
            other => Err(format!("unknown resolve mode '{other}' (warm|cold)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ResolveMode::Warm => "warm",
            ResolveMode::Cold => "cold",
        }
    }
}

/// Failure injection applied to every simulated epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureSpec {
    /// Lognormal jitter σ on every compute/upload duration (0 = none).
    pub jitter_sigma: f64,
    /// Per-round UE dropout probability (0 = none).
    pub dropout_prob: f64,
    /// Per-edge-round aggregation deadline τ_dl (seconds): uploads
    /// arriving later are dropped at the barrier, which closes exactly
    /// at the deadline (partial participation). `INFINITY` (default) =
    /// wait for the slowest scheduled member, the paper's semantics.
    pub deadline_s: f64,
}

impl Default for FailureSpec {
    fn default() -> Self {
        FailureSpec {
            jitter_sigma: 0.0,
            dropout_prob: 0.0,
            deadline_s: f64::INFINITY,
        }
    }
}

/// Per-epoch Markov edge outage/recovery process. Between epochs each up
/// edge fails with `fail_prob` (its members are displaced and
/// re-associate incrementally) and each down edge recovers with
/// `recover_prob`. A failure that would push the serving capacity below
/// the active fleet is vetoed, so runs stay feasible by construction.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OutageSpec {
    /// Per-epoch up→down probability per edge (0 = no outages).
    pub fail_prob: f64,
    /// Per-epoch down→up probability per edge.
    pub recover_prob: f64,
}

impl OutageSpec {
    pub fn enabled(&self) -> bool {
        self.fail_prob > 0.0
    }
}

/// Time-varying dynamics: epoch-based mobility and churn.
///
/// An *epoch* is a chunk of cloud rounds simulated under frozen world
/// state; between epochs the engine moves UEs (random waypoint), applies
/// churn (Poisson arrivals, Bernoulli departures), recomputes the affected
/// channel rows, re-associates (counting handovers) and re-solves (a, b).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DynamicsSpec {
    /// Cloud rounds simulated per epoch. `None` = auto: all remaining
    /// rounds in one epoch when the world is static, one round per epoch
    /// when mobility or churn is on.
    pub epoch_rounds: Option<u64>,
    /// Hard cap on epochs (guards non-convergence under heavy churn).
    pub max_epochs: usize,
    /// Random-waypoint speed range (m/s); `(0, 0)` disables mobility.
    pub speed_mps: (f64, f64),
    /// Poisson mean of UE arrivals per epoch (from the departed pool).
    pub arrival_rate: f64,
    /// Per-active-UE departure probability per epoch.
    pub departure_prob: f64,
}

impl Default for DynamicsSpec {
    fn default() -> Self {
        DynamicsSpec {
            epoch_rounds: None,
            max_epochs: 256,
            speed_mps: (0.0, 0.0),
            arrival_rate: 0.0,
            departure_prob: 0.0,
        }
    }
}

impl DynamicsSpec {
    pub fn mobility_enabled(&self) -> bool {
        self.speed_mps.1 > 0.0
    }

    pub fn churn_enabled(&self) -> bool {
        self.arrival_rate > 0.0 || self.departure_prob > 0.0
    }

    pub fn any_dynamics(&self) -> bool {
        self.mobility_enabled() || self.churn_enabled()
    }

    /// Rounds to simulate this epoch, given how many the accuracy model
    /// still requires.
    pub fn chunk(&self, remaining: u64) -> u64 {
        self.chunk_with(remaining, false)
    }

    /// [`Self::chunk`] with an extra world dynamic this block cannot see
    /// (the outage process lives in its own spec table): *any* dynamic
    /// forces one-round epochs when `epoch_rounds` is unset, and the
    /// policy lives here, in one place, rather than at call sites.
    pub fn chunk_with(&self, remaining: u64, extra_dynamics: bool) -> u64 {
        match self.epoch_rounds {
            Some(k) => k.max(1).min(remaining),
            None if self.any_dynamics() || extra_dynamics => remaining.min(1),
            None => remaining,
        }
    }
}

/// Trace capture: where (if anywhere) to write the per-epoch JSONL
/// event stream (`[trace]` TOML table / `--trace FILE` CLI). The
/// stream's content is seed-deterministic except the measured `wall_s`
/// fields; inspect it with `hfl trace <file>`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TraceSpec {
    /// JSONL output path (`None` = tracing off — the zero-cost default).
    pub file: Option<String>,
}

impl TraceSpec {
    pub fn enabled(&self) -> bool {
        self.file.is_some()
    }
}

/// Batch shape for the parallel fleet runner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchSpec {
    /// Scenario instances to run (each gets an independent derived seed).
    pub instances: usize,
    /// Worker shards; 0 = one per available core.
    pub shards: usize,
}

impl Default for BatchSpec {
    fn default() -> Self {
        BatchSpec {
            instances: 1,
            shards: 0,
        }
    }
}

/// A complete declarative scenario: what to run, how it evolves over
/// time, what can fail, and how wide to fan out.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Topology/channel/learning constants + association + eps + seed
    /// (the batch *base* seed; instances derive their own).
    pub base: Scenario,
    pub optimizer: OptimizerMode,
    /// Per-epoch (a, b) re-solve strategy (warm-started vs from-scratch).
    pub resolve: ResolveMode,
    /// Per-epoch re-association strategy: `Warm` maintains the
    /// association incrementally (`assoc::MaintainedAssociation`,
    /// dirty-set reprocessing, bitwise-equal maps), `Cold` re-runs the
    /// policy from scratch every epoch (the pre-incremental baseline).
    pub assoc_resolve: ResolveMode,
    /// Load-drift fraction of the edge capacity beyond which the warm
    /// association engine re-scores an edge's members (output-neutral
    /// under the paper's load-independent metric; bounds cache staleness
    /// for load-coupled scoring extensions).
    pub assoc_hysteresis: f64,
    /// Intra-instance maintenance threads: the deterministic shard count
    /// for the SoA-sharded engines (`assoc::MaintainedAssociation`,
    /// `delay::MaintainedInstance`). `0` = one shard per available core;
    /// any value yields bitwise-identical results (a speed knob, not a
    /// semantics knob — property-tested in `tests/parallel.rs`).
    pub intra_threads: usize,
    /// Emit per-epoch association optimality certificates: the flow-based
    /// LP lower bound and gap next to the achieved max latency
    /// (`assoc_lower_bound` / `assoc_gap` report columns). A reporting
    /// knob, never a semantics knob — trajectories are bitwise-identical
    /// either way and no RNG is consumed (off by default: the bound costs
    /// a re-solve-scale pass per epoch).
    pub certify: bool,
    pub failure: FailureSpec,
    /// Heterogeneous device classes (empty = the paper's uniform fleet).
    pub devices: DeviceClassSpec,
    /// Edge outage/recovery process (disabled by default).
    pub outage: OutageSpec,
    pub dynamics: DynamicsSpec,
    pub batch: BatchSpec,
    /// Trace capture (off by default; `--trace FILE` / `[trace] file`).
    pub trace: TraceSpec,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            base: Scenario::default(),
            optimizer: OptimizerMode::default(),
            resolve: ResolveMode::default(),
            assoc_resolve: ResolveMode::default(),
            assoc_hysteresis: 0.25,
            intra_threads: 1,
            certify: false,
            failure: FailureSpec::default(),
            devices: DeviceClassSpec::default(),
            outage: OutageSpec::default(),
            dynamics: DynamicsSpec::default(),
            batch: BatchSpec::default(),
            trace: TraceSpec::default(),
        }
    }
}

impl ScenarioSpec {
    pub fn new() -> ScenarioSpec {
        ScenarioSpec::default()
    }

    // -- builder -----------------------------------------------------------

    pub fn edges(mut self, n: usize) -> Self {
        self.base.num_edges = n;
        self
    }

    pub fn ues(mut self, n: usize) -> Self {
        self.base.num_ues = n;
        self
    }

    pub fn eps(mut self, eps: f64) -> Self {
        self.base.eps = eps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.base.seed = seed;
        self
    }

    pub fn assoc(mut self, strategy: AssocStrategy) -> Self {
        self.base.assoc = strategy;
        self
    }

    pub fn optimizer(mut self, mode: OptimizerMode) -> Self {
        self.optimizer = mode;
        self
    }

    /// Per-epoch re-solve strategy (warm = seed from previous optimum).
    pub fn resolve(mut self, mode: ResolveMode) -> Self {
        self.resolve = mode;
        self
    }

    /// Per-epoch re-association strategy (warm = maintained incremental
    /// engine, cold = from-scratch policy run; identical maps).
    pub fn assoc_resolve(mut self, mode: ResolveMode) -> Self {
        self.assoc_resolve = mode;
        self
    }

    /// Warm-association hysteresis: load-drift fraction of the capacity
    /// that triggers member re-scoring.
    pub fn assoc_hysteresis(mut self, h: f64) -> Self {
        self.assoc_hysteresis = h;
        self
    }

    /// Intra-instance maintenance threads / engine shard count
    /// (0 = one per core; bitwise-identical results for any value).
    pub fn intra_threads(mut self, threads: usize) -> Self {
        self.intra_threads = threads;
        self
    }

    /// Per-epoch association optimality certificates (reporting only;
    /// off by default).
    pub fn certify(mut self, on: bool) -> Self {
        self.certify = on;
        self
    }

    /// Fix (a, b) instead of re-solving each epoch.
    pub fn fixed_iters(mut self, a: u64, b: u64) -> Self {
        self.base.train.a = Some(a);
        self.base.train.b = Some(b);
        self
    }

    pub fn jitter(mut self, sigma: f64) -> Self {
        self.failure.jitter_sigma = sigma;
        self
    }

    pub fn dropout(mut self, prob: f64) -> Self {
        self.failure.dropout_prob = prob;
        self
    }

    /// Per-edge-round aggregation deadline τ_dl (seconds; ∞ = off).
    pub fn deadline(mut self, deadline_s: f64) -> Self {
        self.failure.deadline_s = deadline_s;
        self
    }

    /// Replace the device-class distribution wholesale.
    pub fn devices(mut self, spec: DeviceClassSpec) -> Self {
        self.devices = spec;
        self
    }

    /// Append one device class (see [`DeviceClassSpec::class`]).
    pub fn device_class(
        mut self,
        name: &str,
        weight: f64,
        f_cpu_scale: f64,
        power_scale: f64,
        cycles_scale: f64,
    ) -> Self {
        self.devices = self
            .devices
            .class(name, weight, f_cpu_scale, power_scale, cycles_scale);
        self
    }

    /// Markov edge outages: per-epoch fail / recover probabilities.
    pub fn outage(mut self, fail_prob: f64, recover_prob: f64) -> Self {
        self.outage.fail_prob = fail_prob;
        self.outage.recover_prob = recover_prob;
        self
    }

    /// Random-waypoint mobility with speeds uniform in `[lo, hi]` m/s.
    pub fn mobility(mut self, lo_mps: f64, hi_mps: f64) -> Self {
        self.dynamics.speed_mps = (lo_mps, hi_mps);
        self
    }

    /// Poisson churn: `arrival_rate` arrivals/epoch, per-UE
    /// `departure_prob` per epoch.
    pub fn churn(mut self, arrival_rate: f64, departure_prob: f64) -> Self {
        self.dynamics.arrival_rate = arrival_rate;
        self.dynamics.departure_prob = departure_prob;
        self
    }

    pub fn epoch_rounds(mut self, rounds: u64) -> Self {
        self.dynamics.epoch_rounds = Some(rounds);
        self
    }

    pub fn max_epochs(mut self, cap: usize) -> Self {
        self.dynamics.max_epochs = cap;
        self
    }

    pub fn instances(mut self, n: usize) -> Self {
        self.batch.instances = n;
        self
    }

    pub fn shards(mut self, n: usize) -> Self {
        self.batch.shards = n;
        self
    }

    /// Write the per-epoch JSONL event stream to `path`.
    pub fn trace_file(mut self, path: &str) -> Self {
        self.trace.file = Some(path.to_string());
        self
    }

    // -- loading -----------------------------------------------------------

    /// The environment-variable prefix of the spec override layer:
    /// `HFL_SPEED_MAX=12` is `--speed-max 12` at env precedence.
    pub const ENV_PREFIX: &'static str = "HFL_";

    /// Load from a TOML file (if given), then apply `HFL_*` environment
    /// overrides, then CLI overrides. Precedence (highest first):
    /// CLI > env > TOML > built-in defaults.
    pub fn load(path: Option<&str>, args: &Args) -> Result<ScenarioSpec, String> {
        let env = Args::from_prefixed_vars(Self::ENV_PREFIX, std::env::vars());
        Self::load_layered(path.map(|p| (p, None)), &env, args)
    }

    /// The explicit layering entry behind [`ScenarioSpec::load`]: `source`
    /// is the spec path plus (optionally) its already-read text — the
    /// serve path ships TOML text over the wire, the CLI path reads a
    /// file — and `env` is the `HFL_*` layer as an [`Args`] value.
    /// Applying layers in defaults → TOML → env → CLI order makes later
    /// (higher-precedence) layers overwrite earlier ones field by field.
    /// Every layer is checked for unknown keys, so a typo'd `HFL_*` var
    /// fails fast exactly like a typo'd flag.
    pub fn load_layered(
        source: Option<(&str, Option<&str>)>,
        env: &Args,
        args: &Args,
    ) -> Result<ScenarioSpec, String> {
        let mut spec = ScenarioSpec::default();
        if let Some((name, text)) = source {
            let owned;
            let text = match text {
                Some(t) => t,
                None => {
                    owned = std::fs::read_to_string(name)
                        .map_err(|e| format!("read {name}: {e}"))?;
                    &owned
                }
            };
            let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
            spec.apply_toml(&doc)?;
        }
        spec.apply_args(env).map_err(|e| e.to_string())?;
        env.reject_unknown()
            .map_err(|e| format!("environment overrides ({}*): {e}", Self::ENV_PREFIX))?;
        spec.apply_args(args).map_err(|e| e.to_string())?;
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a TOML document (no file, no CLI) — the programmatic entry.
    pub fn parse_toml(text: &str) -> Result<ScenarioSpec, String> {
        let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
        let mut spec = ScenarioSpec::default();
        spec.apply_toml(&doc)?;
        spec.validate()?;
        Ok(spec)
    }

    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        // [scenario] / [system] / [train] / [paths] — the base schema.
        self.base.apply_toml(doc)?;
        // [failure]
        if let Some(v) = doc.f64("failure", "jitter_sigma") {
            self.failure.jitter_sigma = v;
        }
        if let Some(v) = doc.f64("failure", "dropout_prob") {
            self.failure.dropout_prob = v;
        }
        if let Some(v) = doc.f64("failure", "deadline_s") {
            self.failure.deadline_s = v;
        }
        // [devices]
        if let Some(s) = doc.str("devices", "classes") {
            self.devices = DeviceClassSpec::parse(s)?;
        }
        // [outage]
        if let Some(v) = doc.f64("outage", "fail_prob") {
            self.outage.fail_prob = v;
        }
        if let Some(v) = doc.f64("outage", "recover_prob") {
            self.outage.recover_prob = v;
        }
        // [dynamics]
        if let Some(v) = doc.i64("dynamics", "epoch_rounds") {
            self.dynamics.epoch_rounds = Some(v.max(1) as u64);
        }
        if let Some(v) = doc.i64("dynamics", "max_epochs") {
            self.dynamics.max_epochs = v.max(1) as usize;
        }
        let lo = doc.f64("dynamics", "speed_min_mps");
        let hi = doc.f64("dynamics", "speed_max_mps");
        if lo.is_some() || hi.is_some() {
            let hi = hi.or(lo).unwrap_or(0.0);
            self.dynamics.speed_mps = (lo.unwrap_or(0.0), hi);
        }
        if let Some(v) = doc.f64("dynamics", "arrival_rate") {
            self.dynamics.arrival_rate = v;
        }
        if let Some(v) = doc.f64("dynamics", "departure_prob") {
            self.dynamics.departure_prob = v;
        }
        // [optimizer]
        if let Some(s) = doc.str("optimizer", "mode") {
            self.optimizer = OptimizerMode::parse(s)?;
        }
        if let Some(s) = doc.str("optimizer", "resolve") {
            self.resolve = ResolveMode::parse(s)?;
        }
        if let Some(s) = doc.str("optimizer", "assoc_resolve") {
            self.assoc_resolve = ResolveMode::parse(s)?;
        }
        if let Some(v) = doc.f64("optimizer", "assoc_hysteresis") {
            self.assoc_hysteresis = v;
        }
        if let Some(v) = doc.i64("optimizer", "intra_threads") {
            self.intra_threads = v.max(0) as usize;
        }
        if let Some(v) = doc.bool("optimizer", "certify") {
            self.certify = v;
        }
        // [batch]
        if let Some(v) = doc.i64("batch", "instances") {
            self.batch.instances = v.max(1) as usize;
        }
        if let Some(v) = doc.i64("batch", "shards") {
            self.batch.shards = v.max(0) as usize;
        }
        // [trace]
        if let Some(s) = doc.str("trace", "file") {
            self.trace.file = Some(s.to_string());
        }
        Ok(())
    }

    pub fn apply_args(&mut self, args: &Args) -> Result<(), CliError> {
        self.base.apply_args(args)?;
        if let Some(v) = args.get::<f64>("jitter")? {
            self.failure.jitter_sigma = v;
        }
        if let Some(v) = args.get::<f64>("dropout")? {
            self.failure.dropout_prob = v;
        }
        if let Some(v) = args.get::<f64>("deadline")? {
            self.failure.deadline_s = v;
        }
        if let Some(s) = args.str("device-classes") {
            self.devices = DeviceClassSpec::parse(&s).map_err(CliError)?;
        }
        if let Some(v) = args.get::<f64>("outage-fail")? {
            self.outage.fail_prob = v;
        }
        if let Some(v) = args.get::<f64>("outage-recover")? {
            self.outage.recover_prob = v;
        }
        if let Some(v) = args.get::<u64>("epoch-rounds")? {
            self.dynamics.epoch_rounds = Some(v.max(1));
        }
        if let Some(v) = args.get::<usize>("max-epochs")? {
            self.dynamics.max_epochs = v.max(1);
        }
        if let Some(v) = args.get::<f64>("speed-min")? {
            self.dynamics.speed_mps.0 = v;
        }
        if let Some(v) = args.get::<f64>("speed-max")? {
            self.dynamics.speed_mps.1 = v;
        }
        if let Some(v) = args.get::<f64>("arrival-rate")? {
            self.dynamics.arrival_rate = v;
        }
        if let Some(v) = args.get::<f64>("departure-prob")? {
            self.dynamics.departure_prob = v;
        }
        if let Some(s) = args.str("mode") {
            self.optimizer = OptimizerMode::parse(&s).map_err(CliError)?;
        }
        if let Some(s) = args.str("resolve") {
            self.resolve = ResolveMode::parse(&s).map_err(CliError)?;
        }
        if let Some(s) = args.str("assoc-resolve") {
            self.assoc_resolve = ResolveMode::parse(&s).map_err(CliError)?;
        }
        if let Some(v) = args.get::<f64>("assoc-hysteresis")? {
            self.assoc_hysteresis = v;
        }
        if let Some(v) = args.get::<usize>("intra-threads")? {
            self.intra_threads = v;
        }
        // Bare `--certify` turns the knob on; valued forms (`--certify
        // false`, `HFL_CERTIFY=true` — env vars always carry a value)
        // take the parsed bool.
        if args.flag("certify") {
            self.certify = true;
        } else if let Some(v) = args.get::<bool>("certify")? {
            self.certify = v;
        }
        if let Some(v) = args.get::<usize>("instances")? {
            self.batch.instances = v.max(1);
        }
        if let Some(v) = args.get::<usize>("shards")? {
            self.batch.shards = v;
        }
        if let Some(s) = args.str("trace") {
            self.trace.file = Some(s);
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        self.base.validate()?;
        let d = &self.dynamics;
        // Rayleigh fading is a *static snapshot* draw; the dynamics
        // engine's incremental row recompute does not redraw it, so a
        // dynamic world would silently mix faded and unfaded links.
        if d.any_dynamics() {
            if let crate::net::topology::FadingModel::Rayleigh { .. } = self.base.system.fading {
                return Err(
                    "time-varying dynamics require fading = \"none\": mobility/churn \
                     recompute channel rows without redrawing Rayleigh fading"
                        .into(),
                );
            }
        }
        if d.speed_mps.0 < 0.0 || d.speed_mps.1 < d.speed_mps.0 {
            return Err(format!(
                "mobility speed range ({}, {}) must satisfy 0 <= lo <= hi",
                d.speed_mps.0, d.speed_mps.1
            ));
        }
        if d.arrival_rate < 0.0 {
            return Err(format!("arrival_rate must be >= 0, got {}", d.arrival_rate));
        }
        if !(0.0..=1.0).contains(&d.departure_prob) {
            return Err(format!(
                "departure_prob must be in [0,1], got {}",
                d.departure_prob
            ));
        }
        if d.max_epochs == 0 {
            return Err("max_epochs must be >= 1".into());
        }
        let f = &self.failure;
        if f.jitter_sigma < 0.0 {
            return Err(format!("jitter_sigma must be >= 0, got {}", f.jitter_sigma));
        }
        if !(0.0..=1.0).contains(&f.dropout_prob) {
            return Err(format!(
                "dropout_prob must be in [0,1], got {}",
                f.dropout_prob
            ));
        }
        if f.deadline_s.is_nan() || f.deadline_s <= 0.0 {
            return Err(format!(
                "deadline_s must be > 0 (INFINITY = off), got {}",
                f.deadline_s
            ));
        }
        self.devices.validate()?;
        let o = &self.outage;
        if !(0.0..=1.0).contains(&o.fail_prob) {
            return Err(format!("outage fail_prob must be in [0,1], got {}", o.fail_prob));
        }
        if !(0.0..=1.0).contains(&o.recover_prob) {
            return Err(format!(
                "outage recover_prob must be in [0,1], got {}",
                o.recover_prob
            ));
        }
        if o.recover_prob > 0.0 && !o.enabled() {
            return Err(
                "outage recover_prob without fail_prob would be a silent no-op; \
                 set fail_prob > 0 (or drop the [outage] table)"
                    .into(),
            );
        }
        if o.enabled() && self.base.num_edges < 2 {
            return Err("edge outages need at least 2 edges (the feasibility veto \
                        would pin a single edge up forever)"
                .into());
        }
        if self.batch.instances == 0 {
            return Err("batch.instances must be >= 1".into());
        }
        if self.assoc_hysteresis.is_nan() || self.assoc_hysteresis < 0.0 {
            return Err(format!(
                "assoc_hysteresis must be >= 0, got {}",
                self.assoc_hysteresis
            ));
        }
        if let Some(f) = &self.trace.file {
            if f.is_empty() {
                return Err("trace file path must be non-empty (omit [trace] to disable)".into());
            }
        }
        Ok(())
    }

    /// One-line human summary for CLI/report headers.
    pub fn summary(&self) -> String {
        let d = &self.dynamics;
        let dynamics = if d.any_dynamics() {
            format!(
                "mobility {:.1}-{:.1} m/s, churn +{:.2}/-{:.3}",
                d.speed_mps.0, d.speed_mps.1, d.arrival_rate, d.departure_prob
            )
        } else {
            "static".to_string()
        };
        let devices = if self.devices.is_empty() {
            "uniform".to_string()
        } else {
            format!("{} classes [{}]", self.devices.classes.len(), self.devices.to_compact())
        };
        let deadline = if self.failure.deadline_s.is_finite() {
            format!(", deadline={}s", self.failure.deadline_s)
        } else {
            String::new()
        };
        let outage = if self.outage.enabled() {
            format!(
                ", outage {:.3}/{:.3}",
                self.outage.fail_prob, self.outage.recover_prob
            )
        } else {
            String::new()
        };
        let intra = if self.intra_threads != 1 {
            format!(", intra_threads={}", self.intra_threads)
        } else {
            String::new()
        };
        let certify = if self.certify { ", certify" } else { "" };
        format!(
            "{} edges, {} UEs, eps={}, assoc={}, opt={}, resolve={}, assoc_resolve={}{intra}{certify}, \
             jitter={}, dropout={}{deadline}{outage}, devices={devices}, {}",
            self.base.num_edges,
            self.base.num_ues,
            self.base.eps,
            self.base.assoc.name(),
            self.optimizer.name(),
            self.resolve.name(),
            self.assoc_resolve.name(),
            self.failure.jitter_sigma,
            self.failure.dropout_prob,
            dynamics
        )
    }

    /// Multi-line dump of the fully resolved spec, one `key = value` per
    /// line — the `--validate-only` output. Every field that layered
    /// resolution (defaults → TOML → `HFL_*` env → CLI) can touch appears
    /// here, so two invocations resolve identically iff their dumps match.
    pub fn describe(&self) -> String {
        let mut s = String::new();
        let mut line = |k: &str, v: String| {
            s.push_str(&format!("  {k:<22} = {v}\n"));
        };
        line("edges", self.base.num_edges.to_string());
        line("ues", self.base.num_ues.to_string());
        line("eps", self.base.eps.to_string());
        line("seed", self.base.seed.to_string());
        line("assoc", self.base.assoc.name().to_string());
        line("gamma", self.base.system.gamma.to_string());
        line("zeta", self.base.system.zeta.to_string());
        line("optimizer.mode", self.optimizer.name().to_string());
        line("optimizer.resolve", self.resolve.name().to_string());
        line("optimizer.assoc_resolve", self.assoc_resolve.name().to_string());
        line("optimizer.assoc_hysteresis", self.assoc_hysteresis.to_string());
        line("optimizer.intra_threads", self.intra_threads.to_string());
        line("optimizer.certify", self.certify.to_string());
        line("failure.jitter_sigma", self.failure.jitter_sigma.to_string());
        line("failure.dropout_prob", self.failure.dropout_prob.to_string());
        line("failure.deadline_s", self.failure.deadline_s.to_string());
        line(
            "devices.classes",
            if self.devices.is_empty() {
                "uniform".to_string()
            } else {
                self.devices.to_compact()
            },
        );
        line("outage.fail_prob", self.outage.fail_prob.to_string());
        line("outage.recover_prob", self.outage.recover_prob.to_string());
        line(
            "dynamics.speed_mps",
            format!("({}, {})", self.dynamics.speed_mps.0, self.dynamics.speed_mps.1),
        );
        line("dynamics.arrival_rate", self.dynamics.arrival_rate.to_string());
        line("dynamics.departure_prob", self.dynamics.departure_prob.to_string());
        line(
            "dynamics.epoch_rounds",
            match self.dynamics.epoch_rounds {
                Some(r) => r.to_string(),
                None => "auto".to_string(),
            },
        );
        line("dynamics.max_epochs", self.dynamics.max_epochs.to_string());
        line("batch.instances", self.batch.instances.to_string());
        line("batch.shards", self.batch.shards.to_string());
        line(
            "trace.file",
            self.trace.file.clone().unwrap_or_else(|| "off".to_string()),
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn env_layer_sits_between_toml_and_cli() {
        let toml = "[dynamics]\nmax_epochs = 8\n[batch]\ninstances = 3\n";
        let env = args("--max-epochs 16 --instances 5");
        let cli = args("--instances 7");
        let spec = ScenarioSpec::load_layered(Some(("inline", Some(toml))), &env, &cli).unwrap();
        assert_eq!(spec.dynamics.max_epochs, 16, "env must override TOML");
        assert_eq!(spec.batch.instances, 7, "CLI must override env");
    }

    #[test]
    fn unknown_env_override_fails_fast() {
        let env = Args::from_prefixed_vars(
            "HFL_",
            [("HFL_MAX_EPOCS".to_string(), "9".to_string())],
        );
        let err = ScenarioSpec::load_layered(None, &env, &args("")).unwrap_err();
        assert!(
            err.contains("environment overrides") && err.contains("max-epocs"),
            "want a typo'd env var surfaced with its mapped key, got '{err}'"
        );
    }

    #[test]
    fn describe_lists_resolved_fields() {
        let spec = ScenarioSpec::new().edges(3).ues(30).max_epochs(12);
        let d = spec.describe();
        assert!(d.contains("edges") && d.contains("= 3"));
        assert!(d.contains("dynamics.max_epochs") && d.contains("= 12"));
        assert!(d.contains("trace.file"));
    }

    #[test]
    fn builder_chain_sets_everything() {
        let spec = ScenarioSpec::new()
            .edges(7)
            .ues(60)
            .eps(0.1)
            .seed(9)
            .assoc(AssocStrategy::Greedy)
            .optimizer(OptimizerMode::Subgradient)
            .resolve(ResolveMode::Cold)
            .jitter(0.2)
            .dropout(0.05)
            .mobility(1.0, 3.0)
            .churn(0.5, 0.02)
            .epoch_rounds(2)
            .max_epochs(32)
            .instances(10)
            .shards(4);
        assert_eq!(spec.base.num_edges, 7);
        assert_eq!(spec.base.num_ues, 60);
        assert_eq!(spec.base.assoc, AssocStrategy::Greedy);
        assert_eq!(spec.optimizer, OptimizerMode::Subgradient);
        assert_eq!(spec.resolve, ResolveMode::Cold);
        assert_eq!(spec.failure.jitter_sigma, 0.2);
        assert_eq!(spec.dynamics.speed_mps, (1.0, 3.0));
        assert_eq!(spec.dynamics.epoch_rounds, Some(2));
        assert_eq!(spec.batch.instances, 10);
        spec.validate().unwrap();
    }

    #[test]
    fn toml_all_sections() {
        let spec = ScenarioSpec::parse_toml(
            r#"
[scenario]
num_edges = 4
num_ues = 40
eps = 0.2
assoc = "greedy"
[failure]
jitter_sigma = 0.15
dropout_prob = 0.02
[dynamics]
epoch_rounds = 3
max_epochs = 12
speed_min_mps = 0.5
speed_max_mps = 2.5
arrival_rate = 1.5
departure_prob = 0.05
[optimizer]
mode = "subgradient"
resolve = "cold"
[batch]
instances = 64
shards = 8
"#,
        )
        .unwrap();
        assert_eq!(spec.base.num_edges, 4);
        assert_eq!(spec.base.assoc, AssocStrategy::Greedy);
        assert_eq!(spec.failure.jitter_sigma, 0.15);
        assert_eq!(spec.dynamics.epoch_rounds, Some(3));
        assert_eq!(spec.dynamics.max_epochs, 12);
        assert_eq!(spec.dynamics.speed_mps, (0.5, 2.5));
        assert_eq!(spec.dynamics.arrival_rate, 1.5);
        assert_eq!(spec.optimizer, OptimizerMode::Subgradient);
        assert_eq!(spec.resolve, ResolveMode::Cold);
        assert_eq!(spec.batch.instances, 64);
        assert_eq!(spec.batch.shards, 8);
        assert!(spec.dynamics.any_dynamics());
    }

    #[test]
    fn cli_overrides_spec() {
        let mut spec = ScenarioSpec::default();
        spec.apply_args(&args(
            "scenario --ues 50 --jitter 0.3 --speed-max 4.0 --instances 20 --mode continuous",
        ))
        .unwrap();
        assert_eq!(spec.base.num_ues, 50);
        assert_eq!(spec.failure.jitter_sigma, 0.3);
        assert_eq!(spec.dynamics.speed_mps.1, 4.0);
        assert_eq!(spec.batch.instances, 20);
        assert_eq!(spec.optimizer, OptimizerMode::Continuous);
    }

    #[test]
    fn validation_rejects_bad_dynamics() {
        assert!(ScenarioSpec::new().mobility(3.0, 1.0).validate().is_err());
        assert!(ScenarioSpec::new().churn(-1.0, 0.0).validate().is_err());
        assert!(ScenarioSpec::new().churn(0.0, 1.5).validate().is_err());
        assert!(ScenarioSpec::new().dropout(2.0).validate().is_err());
        let mut s = ScenarioSpec::new();
        s.dynamics.max_epochs = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn rayleigh_fading_incompatible_with_dynamics() {
        use crate::net::topology::FadingModel;
        // Dynamic world + static-snapshot fading: rejected (the row
        // recompute would silently drop the fading multiplier).
        let mut moving = ScenarioSpec::new().mobility(0.5, 1.0);
        moving.base.system.fading = FadingModel::Rayleigh { seed: 1 };
        assert!(moving.validate().is_err());
        let mut churning = ScenarioSpec::new().churn(0.5, 0.0);
        churning.base.system.fading = FadingModel::Rayleigh { seed: 1 };
        assert!(churning.validate().is_err());
        // A static Rayleigh snapshot remains valid.
        let mut still = ScenarioSpec::new();
        still.base.system.fading = FadingModel::Rayleigh { seed: 1 };
        assert!(still.validate().is_ok());
    }

    #[test]
    fn chunking_policy() {
        let stat = DynamicsSpec::default();
        assert_eq!(stat.chunk(17), 17);
        let dynamic = DynamicsSpec {
            speed_mps: (0.5, 1.0),
            ..Default::default()
        };
        assert_eq!(dynamic.chunk(17), 1);
        let explicit = DynamicsSpec {
            epoch_rounds: Some(4),
            ..Default::default()
        };
        assert_eq!(explicit.chunk(17), 4);
        assert_eq!(explicit.chunk(3), 3);
        assert_eq!(explicit.chunk(0), 0);
        // An extra dynamic (the outage process) forces one-round epochs
        // exactly like the block's own dynamics — unless epoch_rounds
        // pins the chunk explicitly.
        assert_eq!(stat.chunk_with(17, true), 1);
        assert_eq!(stat.chunk_with(17, false), 17);
        assert_eq!(explicit.chunk_with(17, true), 4);
    }

    #[test]
    fn optimizer_mode_parse() {
        assert_eq!(
            OptimizerMode::parse("alg2").unwrap(),
            OptimizerMode::Subgradient
        );
        assert_eq!(
            OptimizerMode::parse("integer").unwrap(),
            OptimizerMode::Integer
        );
        assert!(OptimizerMode::parse("magic").is_err());
    }

    #[test]
    fn assoc_resolve_knob_toml_cli_builder() {
        // Defaults: warm engine, 0.25 hysteresis.
        let d = ScenarioSpec::default();
        assert_eq!(d.assoc_resolve, ResolveMode::Warm);
        assert!((d.assoc_hysteresis - 0.25).abs() < 1e-12);
        // TOML.
        let spec = ScenarioSpec::parse_toml(
            r#"
[optimizer]
assoc_resolve = "cold"
assoc_hysteresis = 0.5
"#,
        )
        .unwrap();
        assert_eq!(spec.assoc_resolve, ResolveMode::Cold);
        assert!((spec.assoc_hysteresis - 0.5).abs() < 1e-12);
        // CLI overrides.
        let mut spec = ScenarioSpec::default();
        spec.apply_args(&args("scenario --assoc-resolve cold --assoc-hysteresis 1.5"))
            .unwrap();
        assert_eq!(spec.assoc_resolve, ResolveMode::Cold);
        assert!((spec.assoc_hysteresis - 1.5).abs() < 1e-12);
        assert!(spec.summary().contains("assoc_resolve=cold"));
        // Builder + validation.
        let spec = ScenarioSpec::new()
            .assoc_resolve(ResolveMode::Warm)
            .assoc_hysteresis(0.0);
        spec.validate().unwrap();
        assert!(ScenarioSpec::new().assoc_hysteresis(-1.0).validate().is_err());
        assert!(ScenarioSpec::new().assoc_hysteresis(f64::NAN).validate().is_err());
    }

    #[test]
    fn intra_threads_knob_toml_cli_builder() {
        // Default: serial maintenance (one shard).
        let d = ScenarioSpec::default();
        assert_eq!(d.intra_threads, 1);
        assert!(!d.summary().contains("intra_threads"), "default stays silent");
        // TOML (negative values clamp to auto).
        let spec = ScenarioSpec::parse_toml(
            r#"
[optimizer]
intra_threads = 4
"#,
        )
        .unwrap();
        assert_eq!(spec.intra_threads, 4);
        let spec = ScenarioSpec::parse_toml("[optimizer]\nintra_threads = -3\n").unwrap();
        assert_eq!(spec.intra_threads, 0, "negative clamps to 0 = auto");
        // CLI override.
        let mut spec = ScenarioSpec::default();
        spec.apply_args(&args("scenario --intra-threads 8")).unwrap();
        assert_eq!(spec.intra_threads, 8);
        assert!(spec.summary().contains("intra_threads=8"));
        // Builder + validation: any usize is valid (0 = auto).
        ScenarioSpec::new().intra_threads(0).validate().unwrap();
        ScenarioSpec::new().intra_threads(64).validate().unwrap();
    }

    #[test]
    fn certify_knob_toml_cli_builder() {
        // Default: off, and silent in the summary.
        let d = ScenarioSpec::default();
        assert!(!d.certify);
        assert!(!d.summary().contains("certify"), "default stays silent");
        let certify_line = d
            .describe()
            .lines()
            .find(|l| l.contains("optimizer.certify"))
            .expect("describe() must list the certify knob")
            .to_string();
        assert!(certify_line.ends_with("= false"));
        // TOML.
        let spec = ScenarioSpec::parse_toml(
            r#"
[optimizer]
certify = true
"#,
        )
        .unwrap();
        assert!(spec.certify);
        // CLI: bare flag turns it on, valued form can turn it back off
        // (the env layer always arrives valued: HFL_CERTIFY=true).
        let mut spec = ScenarioSpec::default();
        spec.apply_args(&args("scenario --certify")).unwrap();
        assert!(spec.certify);
        assert!(spec.summary().contains("certify"));
        let mut spec = ScenarioSpec::new().certify(true);
        spec.apply_args(&args("scenario --certify false")).unwrap();
        assert!(!spec.certify);
        let mut spec = ScenarioSpec::default();
        spec.apply_args(&args("scenario --certify true")).unwrap();
        assert!(spec.certify);
        // Builder + validation: a reporting knob, always valid.
        ScenarioSpec::new().certify(true).validate().unwrap();
    }

    #[test]
    fn devices_outage_deadline_toml_cli_builder() {
        // Defaults: uniform fleet, no outages, no deadline.
        let d = ScenarioSpec::default();
        assert!(d.devices.is_empty());
        assert!(!d.outage.enabled());
        assert!(d.failure.deadline_s.is_infinite());
        d.validate().unwrap();
        // TOML.
        let spec = ScenarioSpec::parse_toml(
            r#"
[scenario]
num_edges = 4
num_ues = 40
[failure]
deadline_s = 2.5
[devices]
classes = "flagship:0.2:1.0:1.0:1.0, iot:0.8:0.1:0.5:2.0"
[outage]
fail_prob = 0.1
recover_prob = 0.4
"#,
        )
        .unwrap();
        assert_eq!(spec.devices.classes.len(), 2);
        assert_eq!(spec.devices.classes[1].name, "iot");
        assert_eq!(spec.devices.classes[1].f_cpu_scale, 0.1);
        assert_eq!(spec.failure.deadline_s, 2.5);
        assert!(spec.outage.enabled());
        assert_eq!(spec.outage.recover_prob, 0.4);
        // CLI overrides.
        let mut spec = ScenarioSpec::default();
        spec.apply_args(&args(
            "scenario --deadline 1.5 --outage-fail 0.2 --outage-recover 0.5 \
             --device-classes fast:1:1:1:1,slow:1:0.5:1:1",
        ))
        .unwrap();
        assert_eq!(spec.failure.deadline_s, 1.5);
        assert_eq!(spec.outage.fail_prob, 0.2);
        assert_eq!(spec.devices.classes.len(), 2);
        spec.validate().unwrap();
        let s = spec.summary();
        assert!(s.contains("outage 0.200/0.500"), "{s}");
        assert!(s.contains("deadline=1.5s"), "{s}");
        assert!(s.contains("2 classes"), "{s}");
        // Builder + validation rejections.
        ScenarioSpec::new()
            .device_class("a", 1.0, 1.0, 1.0, 1.0)
            .outage(0.1, 0.5)
            .deadline(3.0)
            .validate()
            .unwrap();
        assert!(ScenarioSpec::new().deadline(0.0).validate().is_err());
        assert!(ScenarioSpec::new().deadline(f64::NAN).validate().is_err());
        assert!(ScenarioSpec::new().outage(1.5, 0.0).validate().is_err());
        assert!(ScenarioSpec::new().outage(0.1, -0.2).validate().is_err());
        // recover_prob alone would silently never fire: rejected.
        assert!(ScenarioSpec::new().outage(0.0, 0.5).validate().is_err());
        assert!(ScenarioSpec::new().outage(0.0, 0.0).validate().is_ok());
        // Outages on a single-edge world are rejected (the feasibility
        // veto would pin it up forever — a silent no-op spec).
        assert!(ScenarioSpec::new().edges(1).outage(0.5, 0.5).validate().is_err());
        assert!(ScenarioSpec::new()
            .device_class("x", -1.0, 1.0, 1.0, 1.0)
            .validate()
            .is_err());
        // A bad CLI device spec surfaces as a CLI error.
        let mut bad = ScenarioSpec::default();
        assert!(bad.apply_args(&args("scenario --device-classes nope:1:1")).is_err());
    }

    #[test]
    fn resolve_mode_parse_and_default() {
        assert_eq!(ResolveMode::default(), ResolveMode::Warm);
        assert_eq!(ResolveMode::parse("warm").unwrap(), ResolveMode::Warm);
        assert_eq!(ResolveMode::parse("cold").unwrap(), ResolveMode::Cold);
        assert!(ResolveMode::parse("lukewarm").is_err());
        // CLI override path.
        let mut spec = ScenarioSpec::default();
        spec.apply_args(&args("scenario --resolve cold")).unwrap();
        assert_eq!(spec.resolve, ResolveMode::Cold);
        assert!(spec.summary().contains("resolve=cold"));
    }
}
