//! Declarative scenario engine + parallel fleet runner.
//!
//! The paper evaluates one static snapshot: fixed UEs, fixed channels,
//! one association, one (a*, b*). This subsystem turns that snapshot into
//! a *workload substrate*:
//!
//! * [`spec`] — a declarative [`ScenarioSpec`] (TOML-loadable, fluent
//!   builder) composing topology sampling, channel model, association
//!   policy, optimizer mode, the jitter/dropout failure model and a
//!   **dynamics** block;
//! * [`dynamics`] — the epoch engine: random-waypoint mobility (position
//!   updates → incremental channel recompute), Poisson churn, per-epoch
//!   handover re-association — **incremental** via
//!   `assoc::MaintainedAssociation` (`assoc_resolve = "warm" | "cold"`,
//!   dirty-set reprocessing of only the UEs the epoch touched, bitwise-
//!   equal maps) — and an **incremental (a, b) re-solve** (the delay
//!   instance is maintained in place across epochs and the solver
//!   warm-starts from the previous optimum; `resolve = "warm" | "cold"`),
//!   with the makespan accruing bit-exactly across epochs through `sim/`;
//! * [`runner`] — a sharded work-stealing batch executor that runs
//!   hundreds of instances concurrently with bit-for-bit shard-count
//!   independence;
//! * [`report`] — percentile/CI aggregates, `metrics::Recorder` series
//!   and JSON emission.
//!
//! Entry points: `hfl scenario --spec <toml>` on the CLI, the
//! [`ScenarioRun`] builder from code (see `examples/failure_study.rs`
//! and `examples/association_study.rs`); the historical
//! [`run_batch`]/[`run_instance`] free functions remain as delegating
//! shims.

pub mod dynamics;
pub mod report;
pub mod run;
pub mod runner;
pub mod spec;

pub use dynamics::{run_instance, run_instance_traced, ScenarioOutcome};
pub use report::{record_batch, strip_measured, BatchReport, SummaryStat};
pub use run::ScenarioRun;
pub use runner::{
    instance_seeds, run_batch, run_batch_traced, run_batch_with, shard_count, BatchResult,
};
pub use spec::{
    BatchSpec, DynamicsSpec, FailureSpec, OptimizerMode, OutageSpec, ResolveMode, ScenarioSpec,
    TraceSpec,
};
