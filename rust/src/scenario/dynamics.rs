//! Epoch-based time-varying dynamics engine.
//!
//! One scenario *instance* is a full protocol run over an evolving world:
//!
//! 1. sample a topology + channel from the instance seed;
//! 2. associate the active UEs (any [`AssocStrategy`]) — incrementally
//!    via [`MaintainedAssociation`] under `assoc_resolve = "warm"`
//!    (dirty-set reprocessing of the epoch's [`WorldDelta`]), or from
//!    scratch under `"cold"`, with bitwise-identical maps either way —
//!    and maintain the delay instance;
//! 3. solve sub-problem I for (a, b) under the configured
//!    [`OptimizerMode`] and ask the accuracy model how many cloud rounds
//!    are still required;
//! 4. simulate one epoch's chunk of rounds through `sim/` (with the
//!    failure model), carrying the absolute clock via
//!    `SimConfig::start_s`;
//! 5. advance the world by the epoch's simulated duration — random-
//!    waypoint mobility (recomputing the moved UEs' channel rows) and
//!    Poisson churn — then loop from (2), counting handovers.
//!
//! A static spec collapses to a single epoch whose makespan equals the
//! closed-form `⌈R⌉ · T(a, b)` (property-tested in `tests/scenario.rs`);
//! everything an epoch does is driven by seeded sub-streams of the
//! instance seed, so runs are bit-for-bit reproducible regardless of how
//! the batch runner schedules them.

use std::time::Instant;

use super::spec::{OptimizerMode, ResolveMode, ScenarioSpec};
use crate::assoc::{self, MaintainedAssociation, WorldDelta};
use crate::config::AssocStrategy;
use crate::delay::{self, cloud_rounds_int, DelayInstance, EdgeDelays, MaintainedInstance};
use crate::net::{Channel, Position, Topology};
use crate::opt::{
    solve_continuous, solve_integer, solve_integer_maintained, solve_warm_checked, IntSolution,
    Solution, SolveOptions, SubgradientSolver,
};
use crate::sim::{simulate, SimConfig};
use crate::trace::{Counter, Phase, PhaseStats, Tee, TraceSink};
use crate::util::Rng;

/// Everything one scenario instance produced.
#[derive(Debug, Clone, Default)]
pub struct ScenarioOutcome {
    /// Batch index (filled by the runner; 0 for direct runs).
    pub instance: usize,
    /// The instance seed the run derived everything from.
    pub seed: u64,
    /// Absolute protocol makespan across all epochs (seconds).
    pub makespan_s: f64,
    /// Deterministic closed-form reference: Σ_epochs chunk · T(a, b).
    /// For a static, failure-free spec this equals `⌈R⌉ · T(a*, b*)` and
    /// the simulated makespan reproduces it to f64 round-off.
    pub closed_form_s: f64,
    /// Cloud rounds executed.
    pub rounds: u64,
    /// Epochs executed (1 for static specs).
    pub epochs: u64,
    /// Whether the accuracy target was met within `max_epochs`.
    pub converged: bool,
    /// Last epoch's local-iteration count a.
    pub a: u64,
    /// Last epoch's edge-iteration count b.
    pub b: u64,
    /// Last epoch's one-cloud-round time T(a, b) (seconds).
    pub round_time_s: f64,
    /// Last epoch's max one-edge-round latency max_m τ_m(a) (seconds) —
    /// the Fig. 5 association objective.
    pub tau_max_s: f64,
    /// Flow-based LP lower bound on the last epoch's min-max association
    /// latency (seconds), under `[optimizer] certify = true`; 0.0 when
    /// certification is off or the epoch had no active UEs. Deterministic
    /// (part of the bitwise contract).
    pub assoc_lower_bound: f64,
    /// `achieved − assoc_lower_bound` for the last epoch's association,
    /// where achieved is the max link latency the map actually incurs on
    /// the same table; ≥ 0 by construction, 0.0 when certification is
    /// off. Deterministic (part of the bitwise contract).
    pub assoc_gap: f64,
    /// UEs whose serving edge changed at an epoch boundary.
    pub handovers: u64,
    /// Churn arrivals over the run.
    pub arrivals: u64,
    /// Churn departures over the run.
    pub departures: u64,
    /// Uploads lost to the dropout failure model.
    pub dropped_uploads: u64,
    /// Uploads that missed the per-round aggregation deadline τ_dl
    /// (scheduled and computed, but dropped at the barrier).
    pub late_uploads: u64,
    /// UE-round uploads scheduled in total — the participation-rate
    /// denominator.
    pub scheduled_uploads: u64,
    /// Fraction of scheduled uploads that made their barrier:
    /// `(scheduled − dropout − late) / scheduled` (1.0 when nothing ran).
    pub participation_rate: f64,
    /// Edge up→down transitions over the run (outage process).
    pub outages: u64,
    /// Edge down→up transitions over the run.
    pub recoveries: u64,
    /// Σ over executed epochs of the number of down edges — the outage
    /// exposure the fleet actually trained under.
    pub down_edge_epochs: u64,
    /// Discrete events processed by the simulator.
    pub events: u64,
    /// Cumulative straggler wait at the per-edge aggregation barrier.
    pub ue_barrier_wait_s: f64,
    /// Cumulative edge idle time at the cloud barrier.
    pub edge_barrier_wait_s: f64,
    /// Wall-clock spent in per-epoch (a, b) re-solves (instance
    /// maintenance + solver), cumulative. Derived from the phase spans
    /// (`phase`: delay + resolve) — one timing source of truth. Measured,
    /// so *not* part of the bitwise-determinism contract.
    pub resolve_time_s: f64,
    /// (a, b) re-solves performed (epochs executed + the final solve that
    /// discovers convergence).
    pub resolves: u64,
    /// Re-solves that ran the cold path: all of them under
    /// `resolve = "cold"` or the subgradient optimizer (which has no warm
    /// variant); under `"warm"` with the integer/continuous optimizers,
    /// only the seedless first solve (plus any continuous-mode
    /// basin-escape fallbacks).
    pub cold_resolves: u64,
    /// The (a, b) used by each executed epoch — the re-solve trajectory
    /// the warm/cold cross-check compares.
    pub ab_per_epoch: Vec<(u64, u64)>,
    /// Wall-clock spent in per-epoch association (engine maintenance or
    /// cold policy runs), cumulative. Derived from the phase spans
    /// (`phase`: assoc). Measured, so *not* part of the
    /// bitwise-determinism contract.
    pub assoc_time_s: f64,
    /// UEs whose association state was reprocessed, cumulative: the
    /// dirty-set sizes under `assoc_resolve = "warm"` (full active
    /// counts on merge/cold fallbacks), the full active count per epoch
    /// under `"cold"`. Deterministic within one mode.
    pub reassociations: u64,
    /// Per-phase wall-time + engine-counter breakdown (the trace
    /// subsystem's always-on aggregate). `phase.counters` is
    /// deterministic within one resolve mode; `phase.wall_s` is measured
    /// and excluded from the bitwise contract.
    pub phase: PhaseStats,
}

/// Random-waypoint state: one target + speed per UE.
struct MobilityState {
    target: Vec<Position>,
    speed: Vec<f64>,
    rng: Rng,
    area_m: f64,
    speed_range: (f64, f64),
}

impl MobilityState {
    fn init(topo: &Topology, speed_range: (f64, f64), mut rng: Rng) -> MobilityState {
        let area = topo.params.area_m;
        let target = topo
            .ues
            .iter()
            .map(|_| Position {
                x: rng.range(0.0, area),
                y: rng.range(0.0, area),
            })
            .collect();
        let speed = topo
            .ues
            .iter()
            .map(|_| rng.range(speed_range.0, speed_range.1))
            .collect();
        MobilityState {
            target,
            speed,
            rng,
            area_m: area,
            speed_range,
        }
    }

    /// Fresh waypoint + speed for a (re-)arriving UE.
    fn respawn(&mut self, n: usize) {
        self.target[n] = Position {
            x: self.rng.range(0.0, self.area_m),
            y: self.rng.range(0.0, self.area_m),
        };
        self.speed[n] = self.rng.range(self.speed_range.0, self.speed_range.1);
    }

    /// Advance every active UE by `dt` seconds of travel, updating its
    /// position and recomputing its channel row. Returns the UEs whose
    /// rows were recomputed — the mobility part of the epoch's
    /// [`WorldDelta`].
    fn step(
        &mut self,
        dt: f64,
        active: &[bool],
        topo: &mut Topology,
        channel: &mut Channel,
    ) -> Vec<usize> {
        let mut moved = Vec::new();
        if dt <= 0.0 {
            return moved;
        }
        for n in 0..topo.ues.len() {
            if !active[n] {
                continue;
            }
            let mut travel = self.speed[n] * dt;
            if travel <= 0.0 {
                continue;
            }
            let mut pos = topo.ues[n].pos;
            // Walk waypoint legs until the travel budget is spent (long
            // epochs at high speed legitimately cross many waypoints).
            // The leg cap only guards degenerate worlds (area ≈ 0) whose
            // legs have zero length and would never drain the budget.
            let mut legs = 0u32;
            loop {
                let d = pos.dist(&self.target[n]);
                if d <= travel {
                    pos = self.target[n];
                    travel -= d;
                    self.target[n] = Position {
                        x: self.rng.range(0.0, self.area_m),
                        y: self.rng.range(0.0, self.area_m),
                    };
                    legs += 1;
                    if travel <= 0.0 || legs > 10_000 {
                        break;
                    }
                } else {
                    pos.x += (self.target[n].x - pos.x) / d * travel;
                    pos.y += (self.target[n].y - pos.y) / d * travel;
                    break;
                }
            }
            topo.ues[n].pos = pos;
            channel.recompute_ue(&topo.params, &topo.ues[n], &topo.edges);
            moved.push(n);
        }
        moved
    }
}

/// One churn transition. Departures are Bernoulli per active UE; arrivals
/// re-activate departed UEs (Poisson count) at fresh uniform positions,
/// capped by total edge capacity so the association stays feasible.
/// Returns the arrived and departed UE ids — the churn part of the
/// epoch's [`WorldDelta`].
fn churn_step(
    rng: &mut Rng,
    active: &mut [bool],
    topo: &mut Topology,
    channel: &mut Channel,
    arrival_rate: f64,
    departure_prob: f64,
    capacity_total: usize,
) -> (Vec<usize>, Vec<usize>) {
    let mut departed = Vec::new();
    if departure_prob > 0.0 {
        for (n, flag) in active.iter_mut().enumerate() {
            if *flag && rng.f64() < departure_prob {
                *flag = false;
                departed.push(n);
            }
        }
    }
    let mut arrived = Vec::new();
    let want = rng.poisson(arrival_rate) as usize;
    for _ in 0..want {
        let active_count = active.iter().filter(|&&a| a).count();
        if active_count >= capacity_total {
            break;
        }
        let inactive: Vec<usize> = (0..active.len()).filter(|&n| !active[n]).collect();
        // An empty pool consumes NO draw: sampling `below(1)` here (the
        // old code) silently advanced the churn stream whenever the fleet
        // was fully active, making every later churn decision depend on
        // pool emptiness — a determinism hazard, not a modeling choice.
        if inactive.is_empty() {
            break;
        }
        let pick = inactive[rng.below(inactive.len() as u64) as usize];
        active[pick] = true;
        let area = topo.params.area_m;
        topo.ues[pick].pos = Position {
            x: rng.range(0.0, area),
            y: rng.range(0.0, area),
        };
        channel.recompute_ue(&topo.params, &topo.ues[pick], &topo.edges);
        arrived.push(pick);
    }
    (arrived, departed)
}

/// Associate the active UEs under the spec's strategy — the cold path.
/// Returns the serving edge per *global* UE id (`None` = inactive).
///
/// Policy strategies run `AssocPolicy::assign_cold` directly on the
/// global channel (no more per-epoch sub-channel copy — at 100k UEs that
/// copy alone was ~150 MB/epoch); random stays rng-driven so warm and
/// cold modes consume the same stream. Down edges (`edge_up`) take no
/// members; an all-up mask takes the exact pre-outage code paths.
#[allow(clippy::too_many_arguments)]
fn associate_active(
    strategy: AssocStrategy,
    topo: &Topology,
    channel: &Channel,
    active: &[bool],
    edge_up: &[bool],
    cap: usize,
    provisional_a: f64,
    rng: &mut Rng,
) -> Result<Vec<Option<usize>>, String> {
    let n = topo.num_ues();
    let m = topo.num_edges();
    let ids: Vec<usize> = (0..n).filter(|&i| active[i]).collect();
    let mut edge_of_global = vec![None; n];
    if ids.is_empty() {
        return Ok(edge_of_global);
    }
    let all_up = edge_up.iter().all(|&u| u);
    let assigned: Vec<usize> = match strategy {
        AssocStrategy::Random if all_up => assoc::random(ids.len(), m, cap, rng)?.edge_of,
        AssocStrategy::Random => {
            // Random over the up edges only: draw on the compacted
            // up-edge index space, then map back to global edge ids.
            // Outage-free epochs take the branch above, consuming the
            // exact historical rng stream.
            let up: Vec<usize> = (0..m).filter(|&e| edge_up[e]).collect();
            let compact = assoc::random(ids.len(), up.len(), cap, rng)?;
            compact.edge_of.iter().map(|&e| up[e]).collect()
        }
        _ => {
            let ctx = assoc::AssocCtx {
                channel,
                topo: Some(topo),
                edge_up: if all_up { None } else { Some(edge_up) },
            };
            assoc::policy_for(strategy, provisional_a)?.assign_cold(&ctx, &ids, cap)?
        }
    };
    for (i, &id) in ids.iter().enumerate() {
        edge_of_global[id] = Some(assigned[i]);
    }
    Ok(edge_of_global)
}

/// Certify one epoch's association: the flow-based LP lower bound on the
/// min-max link latency over the active UEs (down edges masked) next to
/// the max latency the current map actually achieves on the *same* table
/// ([`assoc::incremental::subset_latency_table`], bitwise-equal to the
/// scoring core's expressions). Returns `(lower_bound, gap)`; `(0.0,
/// 0.0)` for empty worlds or tables the bound cannot certify (a reporting
/// knob must never fail the run). Consumes no RNG.
fn certify_epoch(
    topo: &Topology,
    channel: &Channel,
    active: &[bool],
    edge_up: &[bool],
    edge_of: &[Option<usize>],
    cap: usize,
    a: f64,
) -> (f64, f64) {
    let ids: Vec<usize> = (0..active.len()).filter(|&i| active[i]).collect();
    if ids.is_empty() {
        return (0.0, 0.0);
    }
    let all_up = edge_up.iter().all(|&u| u);
    let ctx = assoc::AssocCtx {
        channel,
        topo: Some(topo),
        edge_up: if all_up { None } else { Some(edge_up) },
    };
    let table = match assoc::incremental::subset_latency_table(&ctx, a, &ids) {
        Ok(t) => t,
        Err(_) => return (0.0, 0.0),
    };
    let lower = match assoc::flow_lower_bound(&table, cap) {
        Ok(z) => z,
        Err(_) => return (0.0, 0.0),
    };
    let mut achieved = 0.0f64;
    for (row, &ue) in ids.iter().enumerate() {
        if let Some(e) = edge_of[ue] {
            let l = table.of(row, e);
            if l > achieved {
                achieved = l;
            }
        }
    }
    (lower, achieved - lower)
}

/// One epoch's Markov outage transition: each up edge fails with
/// `fail_prob` — unless losing it would push the up capacity below the
/// active fleet (the feasibility veto; the probability draw still
/// happens, so the rng stream is independent of the veto decision) —
/// and each down edge recovers with `recover_prob`. Edges are visited in
/// id order; returns (downed, restored) edge ids, the outage part of the
/// epoch's [`WorldDelta`].
fn outage_step(
    rng: &mut Rng,
    edge_up: &mut [bool],
    fail_prob: f64,
    recover_prob: f64,
    active_count: usize,
    cap: usize,
) -> (Vec<usize>, Vec<usize>) {
    let mut downed = Vec::new();
    let mut restored = Vec::new();
    let mut up_count = edge_up.iter().filter(|&&u| u).count();
    for e in 0..edge_up.len() {
        if edge_up[e] {
            let fails = rng.f64() < fail_prob;
            if fails && up_count >= 1 && (up_count - 1) * cap >= active_count {
                edge_up[e] = false;
                up_count -= 1;
                downed.push(e);
            }
        } else if rng.f64() < recover_prob {
            edge_up[e] = true;
            up_count += 1;
            restored.push(e);
        }
    }
    (downed, restored)
}

/// Build the delay instance for the current association from scratch
/// (global-id member lists, ascending; inactive UEs excluded; memberless
/// edges keep an empty member list and are excluded from `round_time` by
/// the delay model). The epoch loop itself uses [`MaintainedInstance`]
/// and only diffs per-epoch deltas; this builder remains for one-shot
/// uses (the provisional-a bootstrap, tests).
fn build_instance(
    topo: &Topology,
    channel: &Channel,
    edge_of: &[Option<usize>],
    eps: f64,
) -> DelayInstance {
    let m = topo.num_edges();
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (n, e) in edge_of.iter().enumerate() {
        if let Some(e) = e {
            members[*e].push(n);
        }
    }
    let per_edge = topo
        .edges
        .iter()
        .map(|edge| EdgeDelays {
            ue: members[edge.id]
                .iter()
                .map(|&n| {
                    let ue = &topo.ues[n];
                    (
                        delay::ue_compute_time(ue),
                        delay::upload_time(ue.model_bits, channel.rate_of(n, edge.id)),
                    )
                })
                .collect(),
            backhaul_s: delay::upload_time(edge.model_bits, edge.cloud_rate_bps),
        })
        .collect();
    DelayInstance {
        per_edge,
        gamma: topo.params.gamma,
        zeta: topo.params.zeta,
        c_const: topo.params.c_const,
        eps,
    }
}

/// Fixed-iteration overrides from the base scenario, applied to a solver
/// result. Shared by [`solve_ab`] and [`solve_ab_epoch`] so the warm and
/// cold paths cannot drift apart on the override semantics (the
/// bitwise-trajectory contract depends on them staying identical).
fn apply_fixed_iters(spec: &ScenarioSpec, mut a: u64, mut b: u64) -> (u64, u64) {
    if let Some(fixed_a) = spec.base.train.a {
        a = fixed_a.max(1);
    }
    if let Some(fixed_b) = spec.base.train.b {
        b = fixed_b.max(1);
    }
    (a, b)
}

/// Both iteration counts pinned by the spec (no solve needed at all)?
fn fully_fixed_iters(spec: &ScenarioSpec) -> Option<(u64, u64)> {
    match (spec.base.train.a, spec.base.train.b) {
        (Some(a), Some(b)) => Some((a.max(1), b.max(1))),
        _ => None,
    }
}

/// One-shot cold solve of sub-problem I under the spec's optimizer mode
/// (honoring fixed a/b overrides) — used for the provisional-a bootstrap
/// and the `resolve = "cold"` baseline. The warm epoch loop goes through
/// [`solve_ab_epoch`] instead.
fn solve_ab(spec: &ScenarioSpec, inst: &DelayInstance) -> (u64, u64) {
    if let Some(fixed) = fully_fixed_iters(spec) {
        return fixed;
    }
    let (a, b) = match spec.optimizer {
        OptimizerMode::Integer => {
            let s = solve_integer(inst, &SolveOptions::default());
            (s.a, s.b)
        }
        OptimizerMode::Continuous => {
            let s = solve_continuous(inst, &SolveOptions::default());
            (s.a.round().max(1.0) as u64, s.b.round().max(1.0) as u64)
        }
        OptimizerMode::Subgradient => {
            let s = SubgradientSolver::default().solve(inst);
            (s.a.round().max(1.0) as u64, s.b.round().max(1.0) as u64)
        }
    };
    apply_fixed_iters(spec, a, b)
}

/// Per-epoch (a, b) re-solve over the maintained instance — the
/// `resolve = "warm"` path (`"cold"` rebuilds from scratch and goes
/// through [`solve_ab`] instead). Returns `(a, b, cold)` where `cold`
/// marks an unseeded solve (the first epoch, or a continuous-mode
/// basin-escape fallback). The integer warm path is exact, so warm and
/// cold runs of the same scenario produce identical (a, b) trajectories.
fn solve_ab_epoch(
    spec: &ScenarioSpec,
    maintained: &mut MaintainedInstance,
    opts: &SolveOptions,
    prev_int: &mut Option<IntSolution>,
    prev_cont: &mut Option<Solution>,
) -> (u64, u64, bool) {
    if let Some((a, b)) = fully_fixed_iters(spec) {
        return (a, b, false);
    }
    let warm_ok = spec.resolve == ResolveMode::Warm;
    let (a, b, cold) = match spec.optimizer {
        OptimizerMode::Integer => {
            let seed = if warm_ok {
                prev_int.as_ref().map(|s| (s.a, s.b))
            } else {
                None
            };
            let cold = seed.is_none();
            let s = solve_integer_maintained(maintained, opts, seed);
            let ab = (s.a, s.b);
            *prev_int = Some(s);
            (ab.0, ab.1, cold)
        }
        OptimizerMode::Continuous => {
            let (s, cold) = match prev_cont.as_ref() {
                Some(p) if warm_ok => solve_warm_checked(maintained.instance(), opts, p),
                _ => (solve_continuous(maintained.instance(), opts), true),
            };
            let ab = (s.a.round().max(1.0) as u64, s.b.round().max(1.0) as u64);
            *prev_cont = Some(s);
            (ab.0, ab.1, cold)
        }
        // Algorithm 2 has no warm variant (the dual iteration is its own
        // warm start); always a cold solve.
        OptimizerMode::Subgradient => {
            let s = SubgradientSolver::default().solve(maintained.instance());
            (
                s.a.round().max(1.0) as u64,
                s.b.round().max(1.0) as u64,
                true,
            )
        }
    };
    let (a, b) = apply_fixed_iters(spec, a, b);
    (a, b, cold)
}

/// Run one scenario instance end to end. Pure function of
/// `(spec, seed)` — the batch runner relies on that for shard-count
/// independence.
///
/// Thin shim over [`crate::scenario::ScenarioRun`] (the unified entry).
pub fn run_instance(spec: &ScenarioSpec, seed: u64) -> Result<ScenarioOutcome, String> {
    crate::scenario::ScenarioRun::new(spec).seed(seed).run()
}

/// [`run_instance`] with a trace sink observing per-epoch phase spans,
/// engine counters, and simulated round clocks. The trajectory is
/// bitwise-identical to the untraced run for every sink — the sink only
/// observes (tested in `tests/scenario.rs`); a disabled sink
/// (`enabled() == false`, e.g. [`crate::trace::NullSink`]) receives zero
/// calls.
pub fn run_instance_traced(
    spec: &ScenarioSpec,
    seed: u64,
    sink: &mut dyn TraceSink,
) -> Result<ScenarioOutcome, String> {
    // Direct builder users get the same guardrails as the batch runner
    // (notably the Rayleigh-fading × dynamics rejection).
    spec.validate()?;
    let base = &spec.base;
    let mut topo = Topology::sample_with_devices(
        &base.system,
        &spec.devices,
        base.num_edges,
        base.num_ues,
        seed,
    );
    let mut channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let cap = base.system.edge_capacity();
    let n = base.num_ues;

    // Independent seeded sub-streams: association tie-breaking, simulator
    // noise, churn, mobility, edge outages. Forked from the instance seed
    // only; the outage fork comes *last* so outage-free specs leave the
    // historical streams untouched.
    // hfl-lint: allow(R4, instance master stream root; all epoch randomness forks from here)
    let mut master = Rng::new(seed ^ 0x5CE2_A210_D15C_0FEE);
    let mut assoc_rng = master.fork(0xA550);
    let mut sim_rng = master.fork(0x51ED);
    let mut churn_rng = master.fork(0xC42B);
    let mobility_rng = master.fork(0x30B1);
    let mut outage_rng = master.fork(0x0D6E);
    let mut mobility = MobilityState::init(&topo, spec.dynamics.speed_mps, mobility_rng);

    let mut active = vec![true; n];
    let mut edge_up = vec![true; base.num_edges];
    let mut prev_edge: Vec<Option<usize>> = vec![None; n];

    let mut out = ScenarioOutcome {
        instance: 0,
        seed,
        makespan_s: 0.0,
        closed_form_s: 0.0,
        rounds: 0,
        epochs: 0,
        converged: false,
        a: 0,
        b: 0,
        round_time_s: 0.0,
        tau_max_s: 0.0,
        assoc_lower_bound: 0.0,
        assoc_gap: 0.0,
        handovers: 0,
        arrivals: 0,
        departures: 0,
        dropped_uploads: 0,
        late_uploads: 0,
        scheduled_uploads: 0,
        participation_rate: 1.0,
        outages: 0,
        recoveries: 0,
        down_edge_epochs: 0,
        events: 0,
        ue_barrier_wait_s: 0.0,
        edge_barrier_wait_s: 0.0,
        resolve_time_s: 0.0,
        resolves: 0,
        cold_resolves: 0,
        ab_per_epoch: Vec::new(),
        assoc_time_s: 0.0,
        reassociations: 0,
        phase: PhaseStats::default(),
    };

    // The phase aggregate is always collected (it feeds the outcome's
    // breakdown); the user sink behind the tee only sees events when
    // enabled — NullSink costs one bool check per span, nothing per UE.
    let mut pstats = PhaseStats::default();
    let mut tee = Tee {
        stats: &mut pstats,
        inner: sink,
    };
    tee.instance(seed);

    let mut now = 0.0f64;
    let mut provisional_a = 20.0f64;
    if base.assoc == AssocStrategy::Exact {
        // The matching objective weighs compute vs upload by a, so seed it
        // with a solved a* under a greedy provisional association (the
        // paper's flow, same as `hfl associate`) instead of a magic
        // constant. Later epochs reuse the previous epoch's solved a.
        let greedy_edge_of = associate_active(
            AssocStrategy::Greedy,
            &topo,
            &channel,
            &active,
            &edge_up,
            cap,
            provisional_a,
            &mut assoc_rng,
        )?;
        let greedy_inst = build_instance(&topo, &channel, &greedy_edge_of, base.eps);
        provisional_a = solve_ab(spec, &greedy_inst).0 as f64;
    }
    let opts = SolveOptions::default();
    let mut maint: Option<MaintainedInstance> = None;
    let mut massoc: Option<MaintainedAssociation> = None;
    let mut prev_int: Option<IntSolution> = None;
    let mut prev_cont: Option<Solution> = None;
    // What the previous world-advance changed (empty on the first epoch)
    // and the association last handed to the maintained delay instance —
    // together they form the touched set for the delta-driven syncs.
    let mut delta = WorldDelta::default();
    let mut last_assoc: Vec<Option<usize>> = vec![None; n];
    loop {
        let ep = out.epochs;
        tee.begin_epoch(ep, now);
        // (1) Association for the current world. Warm mode keeps the
        // incremental engine alive across epochs and reprocesses only
        // the delta's dirty set; cold mode re-runs the policy from
        // scratch. The maps are bitwise-identical either way (see
        // assoc/incremental.rs), so both modes share one trajectory.
        let warm_assoc =
            spec.assoc_resolve == ResolveMode::Warm && base.assoc != AssocStrategy::Random;
        // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
        let t_assoc = Instant::now();
        let edge_of = if warm_assoc {
            if let Some(ma) = massoc.as_mut() {
                ma.sync_traced(&topo, &channel, &active, &delta, provisional_a, &mut tee)?;
            } else {
                massoc = Some(MaintainedAssociation::new_sharded(
                    base.assoc,
                    &topo,
                    &channel,
                    &active,
                    cap,
                    spec.assoc_hysteresis,
                    provisional_a,
                    spec.intra_threads,
                    &mut tee,
                )?);
            }
            let ma = massoc.as_ref().expect("maintained association initialized above");
            out.reassociations = ma.reassociations;
            ma.edge_of_global()
        } else {
            let cold = associate_active(
                base.assoc,
                &topo,
                &channel,
                &active,
                &edge_up,
                cap,
                provisional_a,
                &mut assoc_rng,
            )?;
            let n_active = active.iter().filter(|&&on| on).count() as u64;
            out.reassociations += n_active;
            tee.counter(Counter::AssocDirty, n_active);
            tee.counter(Counter::AssocMergeSweep, 1);
            cold
        };
        tee.span(ep, Phase::Assoc, t_assoc.elapsed().as_secs_f64());

        // (2) Re-solve (a, b) for this epoch's world. Warm mode maintains
        // the delay instance in place (dirty-row deltas + cached τ
        // frontiers) and seeds the solver from the previous optimum; cold
        // mode is the from-scratch baseline (full rebuild + unseeded
        // solve — what every epoch cost before the incremental pipeline),
        // kept bit-compatible so the two modes produce identical
        // trajectories. Instance maintenance and the solve itself are
        // separate trace phases (delay vs resolve).
        // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
        let t_delay = Instant::now();
        let mut cold_inst: Option<DelayInstance> = None;
        let (a, b, cold) = if spec.resolve == ResolveMode::Cold {
            let built = build_instance(&topo, &channel, &edge_of, base.eps);
            tee.counter(
                Counter::DelayTouched,
                edge_of.iter().filter(|e| e.is_some()).count() as u64,
            );
            tee.span(ep, Phase::Delay, t_delay.elapsed().as_secs_f64());
            // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
            let t_resolve = Instant::now();
            let (a, b) = solve_ab(spec, &built);
            let resolve_w = t_resolve.elapsed().as_secs_f64();
            cold_inst = Some(built);
            tee.counter(Counter::ColdResolves, 1);
            tee.span(ep, Phase::Resolve, resolve_w);
            (a, b, true)
        } else {
            if let Some(m) = maint.as_mut() {
                // Delta-driven maintenance: the rows the epoch moved plus
                // every UE whose serving edge changed since the last
                // sync, instead of an O(N) re-derivation of all delays.
                let mut touched = delta.touched();
                for (ue, (prev, cur)) in last_assoc.iter().zip(edge_of.iter()).enumerate() {
                    if prev != cur {
                        touched.push(ue);
                    }
                }
                m.sync_delta_traced(&topo, &channel, &edge_of, &touched, &mut tee);
            } else {
                let mut built = MaintainedInstance::build(&topo, &channel, &edge_of, base.eps);
                built.set_intra_threads(spec.intra_threads);
                maint = Some(built);
                tee.counter(
                    Counter::DelayTouched,
                    edge_of.iter().filter(|e| e.is_some()).count() as u64,
                );
            }
            tee.span(ep, Phase::Delay, t_delay.elapsed().as_secs_f64());
            let m = maint.as_mut().expect("maintained instance initialized above");
            // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
            let t_resolve = Instant::now();
            let fr_before = m.frontier_rebuilds();
            let (a, b, cold) = solve_ab_epoch(spec, m, &opts, &mut prev_int, &mut prev_cont);
            let resolve_w = t_resolve.elapsed().as_secs_f64();
            tee.counter(Counter::FrontierRebuilds, m.frontier_rebuilds() - fr_before);
            tee.counter(
                if cold {
                    Counter::ColdResolves
                } else {
                    Counter::WarmResolves
                },
                1,
            );
            tee.span(ep, Phase::Resolve, resolve_w);
            (a, b, cold)
        };
        out.resolves += 1;
        if cold {
            out.cold_resolves += 1;
        }
        last_assoc.clone_from(&edge_of);
        let inst: &DelayInstance = match cold_inst.as_ref() {
            Some(built) => built,
            None => maint.as_ref().expect("warm mode keeps it").instance(),
        };
        let target = cloud_rounds_int(
            a as f64,
            b as f64,
            inst.eps,
            inst.c_const,
            inst.gamma,
            inst.zeta,
        );
        if out.rounds >= target {
            out.converged = true;
            break;
        }
        if out.epochs as usize >= spec.dynamics.max_epochs {
            break;
        }

        // The epoch definitely runs: account handovers against the last
        // epoch's association.
        for (prev, cur) in prev_edge.iter().zip(edge_of.iter()) {
            if let (Some(p), Some(c)) = (prev, cur) {
                if p != c {
                    out.handovers += 1;
                }
            }
        }
        prev_edge.clone_from(&edge_of);
        provisional_a = a as f64;
        out.ab_per_epoch.push((a, b));
        out.down_edge_epochs += edge_up.iter().filter(|&&u| !u).count() as u64;

        // (3) Simulate this epoch's chunk of rounds. The outage process
        // counts as a world dynamic: without an explicit epoch_rounds it
        // forces one-round epochs, else a no-churn/no-mobility spec would
        // run everything in a single epoch and never fail an edge.
        let chunk = spec
            .dynamics
            .chunk_with(target - out.rounds, spec.outage.enabled());
        let cfg = SimConfig {
            a,
            b,
            rounds: Some(chunk),
            jitter_sigma: spec.failure.jitter_sigma,
            dropout_prob: spec.failure.dropout_prob,
            seed: sim_rng.next_u64(),
            start_s: now,
            deadline_s: spec.failure.deadline_s,
        };
        // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
        let t_sim = Instant::now();
        let res = simulate(inst, &cfg);
        let sim_w = t_sim.elapsed().as_secs_f64();
        res.trace_rounds(ep, &mut tee);
        tee.counter(Counter::SimRounds, res.rounds);
        tee.counter(Counter::SimEvents, res.events);
        tee.span(ep, Phase::Sim, sim_w);
        let dt = res.total_time_s - now;
        now = res.total_time_s;

        out.rounds += res.rounds;
        out.epochs += 1;
        out.closed_form_s += chunk as f64 * inst.round_time(a as f64, b as f64);
        out.dropped_uploads += res.dropped_uploads;
        out.late_uploads += res.late_uploads;
        out.scheduled_uploads += res.scheduled_uploads;
        out.events += res.events;
        out.ue_barrier_wait_s += res.ue_barrier_wait_s;
        out.edge_barrier_wait_s += res.edge_barrier_wait_s;
        out.a = a;
        out.b = b;
        out.round_time_s = inst.round_time(a as f64, b as f64);
        out.tau_max_s = inst.tau_max(a as f64);
        if spec.certify {
            // Reporting only: reads the epoch's world and map, consumes
            // no RNG, mutates nothing the trajectory depends on — certify
            // on/off runs stay bitwise-identical.
            let (lb, gap) =
                certify_epoch(&topo, &channel, &active, &edge_up, &edge_of, cap, a as f64);
            out.assoc_lower_bound = lb;
            out.assoc_gap = gap;
        }
        // Deterministic per-epoch summary for streaming consumers (the
        // serve path): this epoch's (a, b), the running makespan, and its
        // own upload participation share.
        let epoch_participation = if res.scheduled_uploads == 0 {
            1.0
        } else {
            (res.scheduled_uploads - res.dropped_uploads - res.late_uploads) as f64
                / res.scheduled_uploads as f64
        };
        tee.epoch_end(ep, a, b, now, epoch_participation);

        // A world without dynamics (outages included — they re-shape the
        // delay instance and hence the accuracy target) cannot change the
        // target, so convergence is decidable now — skip the redundant
        // re-associate + re-solve a full extra loop iteration would spend
        // discovering it.
        if !spec.dynamics.any_dynamics() && !spec.outage.enabled() && out.rounds >= target {
            out.converged = true;
            break;
        }

        // (4) Advance the world for the next epoch, capturing what moved
        // as the delta the incremental association + delay paths consume.
        delta = WorldDelta::default();
        if spec.dynamics.mobility_enabled() {
            // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
            let t_mob = Instant::now();
            delta.moved = mobility.step(dt, &active, &mut topo, &mut channel);
            let w = t_mob.elapsed().as_secs_f64();
            tee.counter(Counter::MovedUes, delta.moved.len() as u64);
            tee.span(ep, Phase::Mobility, w);
        }
        if spec.dynamics.churn_enabled() {
            // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
            let t_churn = Instant::now();
            // Arrivals are capped by the *serving* capacity: edges that
            // are down host nobody.
            let up_capacity = cap.saturating_mul(edge_up.iter().filter(|&&u| u).count());
            let (arrived, departed) = churn_step(
                &mut churn_rng,
                &mut active,
                &mut topo,
                &mut channel,
                spec.dynamics.arrival_rate,
                spec.dynamics.departure_prob,
                up_capacity,
            );
            out.departures += departed.len() as u64;
            out.arrivals += arrived.len() as u64;
            for &id in &arrived {
                mobility.respawn(id);
                prev_edge[id] = None; // re-joining is not a handover
            }
            delta.arrived = arrived;
            delta.departed = departed;
            tee.span(ep, Phase::Churn, t_churn.elapsed().as_secs_f64());
        }
        if spec.outage.enabled() {
            // hfl-lint: allow(R3, trace span wall_s; observability only, stripped for byte-compare)
            let t_outage = Instant::now();
            let active_count = active.iter().filter(|&&on| on).count();
            let (downed, restored) = outage_step(
                &mut outage_rng,
                &mut edge_up,
                spec.outage.fail_prob,
                spec.outage.recover_prob,
                active_count,
                cap,
            );
            out.outages += downed.len() as u64;
            out.recoveries += restored.len() as u64;
            delta.downed = downed;
            delta.restored = restored;
            tee.span(ep, Phase::Outage, t_outage.elapsed().as_secs_f64());
        }
    }
    out.makespan_s = now;
    out.participation_rate = if out.scheduled_uploads == 0 {
        1.0
    } else {
        (out.scheduled_uploads - out.dropped_uploads - out.late_uploads) as f64
            / out.scheduled_uploads as f64
    };
    // One timing source of truth: the legacy totals are views of the
    // phase spans (delay maintenance + solver = "resolve time").
    out.phase = pstats;
    out.assoc_time_s = out.phase.wall(Phase::Assoc);
    out.resolve_time_s = out.phase.wall(Phase::Delay) + out.phase.wall(Phase::Resolve);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::SystemParams;

    /// Regression for the empty-pool arrival bug: when every UE is active
    /// (nothing to re-activate), `churn_step` used to index the pool with
    /// `below(len.max(1))` — consuming a churn-stream draw whose only
    /// effect was to make every later churn decision depend on pool
    /// emptiness. The fixed step must consume exactly the Poisson
    /// arrival-count draw and nothing else.
    #[test]
    fn empty_pool_epoch_consumes_no_extra_churn_draws() {
        let mut topo = Topology::sample(&SystemParams::default(), 2, 8, 3);
        let mut channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let rate = 5.0;
        let mut any_arrivals_wanted = false;
        for seed in 0..8u64 {
            let mut rng = Rng::new(seed);
            let mut probe = rng.clone();
            any_arrivals_wanted |= probe.poisson(rate) > 0;
            let mut reference = rng.clone();
            let mut active = vec![true; topo.num_ues()];
            let (arrived, departed) = churn_step(
                &mut rng,
                &mut active,
                &mut topo,
                &mut channel,
                rate,
                0.0,
                1_000,
            );
            assert!(arrived.is_empty(), "nothing to re-activate");
            assert!(departed.is_empty(), "departure_prob = 0");
            reference.poisson(rate);
            assert_eq!(
                rng.next_u64(),
                reference.next_u64(),
                "seed {seed}: churn stream advanced past the Poisson draw"
            );
        }
        // For the fixed λ=5 at least one of the 8 seeds must have wanted
        // arrivals, otherwise the empty-pool branch was never reached.
        assert!(any_arrivals_wanted);
    }

    /// Seed-stability across empty-pool epochs at the trajectory level: an
    /// arrival-only spec on a fully-active fleet hits the empty-pool path
    /// every epoch, and the whole run must still reproduce bit for bit.
    #[test]
    fn trajectory_is_seed_stable_across_empty_pool_epochs() {
        let spec = ScenarioSpec::new()
            .edges(2)
            .ues(16)
            .eps(0.2)
            .mobility(1.0, 4.0)
            .churn(3.0, 0.0) // arrivals wanted, nobody ever departs
            .epoch_rounds(1)
            .max_epochs(12);
        let a = run_instance(&spec, 41).unwrap();
        let b = run_instance(&spec, 41).unwrap();
        assert!(a.epochs > 1, "must cross epoch boundaries");
        assert_eq!(a.arrivals, 0, "full fleet: the pool is always empty");
        assert_eq!(a.departures, 0);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.closed_form_s.to_bits(), b.closed_form_s.to_bits());
        assert_eq!(a.handovers, b.handovers);
        assert_eq!(a.phase.counters, b.phase.counters);
    }
}
