//! Message types between the cloud leader and the edge actors.

/// Leader → edge.
#[derive(Debug, Clone)]
pub enum CloudMsg {
    /// Start one cloud round from this global model.
    RunRound { round: u64, global: Vec<f32> },
    /// Terminate the actor.
    Shutdown,
}

/// Edge → leader: the edge's aggregate after its `b` edge rounds.
#[derive(Debug, Clone)]
pub struct EdgeReport {
    pub edge: usize,
    pub round: u64,
    pub model: Vec<f32>,
    /// Σ D_n over the edge's members (cloud-aggregation weight, Eq. (10)).
    pub data_size: u64,
    /// Mean member training loss across the edge rounds.
    pub mean_loss: f32,
    /// Error string if the edge failed (poisoned round).
    pub error: Option<String>,
}
