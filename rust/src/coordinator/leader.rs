//! The production HFL runtime: cloud leader + edge actor threads.
//!
//! Topology-faithful implementation of Algorithm 1: one OS thread per
//! edge server (the paper's edges aggregate independently and in
//! parallel), each running its `b` edge rounds over a UE worker pool
//! (`worker.rs`), reporting aggregates to the cloud leader over mpsc
//! channels. The leader performs the cloud aggregation (Eq. (10)),
//! evaluates the global model, stamps simulated protocol time from the
//! delay model, and broadcasts the next round's global model.
//!
//! Determinism: for a given seed this runtime produces bitwise the same
//! models as the sequential `fl::HflEngine` (asserted in
//! `rust/tests/runtime_integration.rs`), because member order fixes the
//! aggregation order and UE streams are keyed by UE id.

use std::sync::mpsc;

use anyhow::{anyhow, bail, Result};

use super::messages::{CloudMsg, EdgeReport};
use super::worker::{parallel_gradients, parallel_local_rounds};
use crate::data::Dataset;
use crate::fl::aggregate::{cloud_aggregate, weighted_average};
use crate::fl::metrics::{CurvePoint, TrainingCurve};
use crate::fl::{LocalSolver, TrainRun, UeState};
use crate::runtime::Engine;

/// Result of a coordinated training run.
#[derive(Debug)]
pub struct HflOutcome {
    pub curve: TrainingCurve,
    pub final_model: Vec<f32>,
    /// Wall-clock seconds of the whole run.
    pub wall_s: f64,
}

/// Edge actor main loop: owns its members' states for the whole run.
fn edge_actor(
    engine: &Engine,
    solver: LocalSolver,
    edge_id: usize,
    mut members: Vec<UeState>,
    b: u64,
    a: u64,
    workers: usize,
    rx: mpsc::Receiver<CloudMsg>,
    tx: mpsc::Sender<EdgeReport>,
) {
    let data_size: u64 = members.iter().map(|u| u.data_size()).sum();
    // hfl-lint: allow(R6, single-producer FIFO command channel; the leader sends rounds in order)
    while let Ok(msg) = rx.recv() {
        let (round, global) = match msg {
            CloudMsg::Shutdown => return,
            CloudMsg::RunRound { round, global } => (round, global),
        };
        let mut w_m = global;
        let mut loss_acc = 0.0f64;
        let mut loss_cnt = 0usize;
        let mut error = None;
        'rounds: for _k in 0..b {
            // DANE corrections if requested.
            let corrections: Vec<Vec<f32>> = if matches!(solver, LocalSolver::Dane { .. }) {
                match parallel_gradients(engine, &w_m, &mut members, workers) {
                    Ok(grads) => {
                        let weights: Vec<(f64, &[f32])> = members
                            .iter()
                            .zip(&grads)
                            .map(|(u, g)| (u.data_size() as f64, g.as_slice()))
                            .collect();
                        let global_grad = weighted_average(&weights);
                        grads
                            .iter()
                            .map(|g| global_grad.iter().zip(g).map(|(gg, gn)| gg - gn).collect())
                            .collect()
                    }
                    Err(e) => {
                        error = Some(e.to_string());
                        break 'rounds;
                    }
                }
            } else {
                vec![Vec::new(); members.len()]
            };
            match parallel_local_rounds(engine, &solver, &w_m, &mut members, a, &corrections, workers)
            {
                Ok(results) => {
                    let refs: Vec<(f64, &[f32])> = results
                        .iter()
                        .map(|r| (r.data_size as f64, r.model.as_slice()))
                        .collect();
                    w_m = weighted_average(&refs);
                    loss_acc += results.iter().map(|r| r.loss as f64).sum::<f64>()
                        / results.len().max(1) as f64;
                    loss_cnt += 1;
                }
                Err(e) => {
                    error = Some(e.to_string());
                    break 'rounds;
                }
            }
        }
        let report = EdgeReport {
            edge: edge_id,
            round,
            model: w_m,
            data_size,
            mean_loss: (loss_acc / loss_cnt.max(1) as f64) as f32,
            error,
        };
        if tx.send(report).is_err() {
            return; // leader gone
        }
    }
}

/// Run hierarchical FL with the threaded coordinator.
///
/// `shards[i]` is UE i's local dataset; `members[m]` lists the UE ids of
/// edge m (the association); `workers` bounds the per-edge UE thread pool
/// (0 = available parallelism / #edges, at least 1).
#[allow(clippy::too_many_arguments)]
pub fn run_hfl(
    engine: &Engine,
    solver: LocalSolver,
    shards: Vec<Dataset>,
    members: Vec<Vec<usize>>,
    test: &Dataset,
    run: &TrainRun,
    workers: usize,
    seed: u64,
) -> Result<HflOutcome> {
    let num_edges = members.len();
    if num_edges == 0 {
        bail!("no edges");
    }
    let n_ues = shards.len();
    for (m, ms) in members.iter().enumerate() {
        for &n in ms {
            if n >= n_ues {
                bail!("edge {m} references UE {n} >= {n_ues}");
            }
        }
    }
    let workers = if workers == 0 {
        (std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4) / num_edges).max(1)
    } else {
        workers
    };

    // Move each UE's state into its edge, preserving global UE-id seeding.
    let mut shard_opts: Vec<Option<Dataset>> = shards.into_iter().map(Some).collect();
    let mut edge_states: Vec<Vec<UeState>> = Vec::with_capacity(num_edges);
    for ms in &members {
        let states = ms
            .iter()
            .map(|&n| {
                let shard = shard_opts[n]
                    .take()
                    .ok_or_else(|| anyhow!("UE {n} assigned to two edges"))?;
                Ok(UeState::seeded(shard, n, seed))
            })
            .collect::<Result<Vec<_>>>()?;
        edge_states.push(states);
    }

    // hfl-lint: allow(R3, wall_s on the training curve is observability, never simulated time)
    let t0 = std::time::Instant::now();
    let (report_tx, report_rx) = mpsc::channel::<EdgeReport>();

    let mut curve = TrainingCurve::new(run.a, run.b);
    let mut final_model = engine.init_params();

    std::thread::scope(|scope| -> Result<()> {
        // Spawn edge actors.
        let mut cmd_txs = Vec::with_capacity(num_edges);
        for (m, states) in edge_states.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<CloudMsg>();
            cmd_txs.push(tx);
            let report_tx = report_tx.clone();
            let solver = solver;
            scope.spawn(move || {
                edge_actor(engine, solver, m, states, run.b, run.a, workers, rx, report_tx)
            });
        }
        drop(report_tx);

        // Leader loop.
        let mut global = engine.init_params();
        let (loss0, acc0) = engine.evaluate(&global, &test.x, &test.y)?;
        curve.push(CurvePoint {
            cloud_round: 0,
            sim_time_s: 0.0,
            wall_s: t0.elapsed().as_secs_f64(),
            test_acc: acc0,
            test_loss: loss0,
            train_loss: f32::NAN,
        });

        for round in 1..=run.cloud_rounds {
            for tx in &cmd_txs {
                tx.send(CloudMsg::RunRound {
                    round,
                    global: global.clone(),
                })
                .map_err(|_| anyhow!("edge actor exited early"))?;
            }
            // Collect all edge reports for this round (order-independent:
            // stored by edge id, aggregated in edge order).
            let mut reports: Vec<Option<EdgeReport>> = (0..num_edges).map(|_| None).collect();
            let mut received = 0;
            while received < num_edges {
                let rep = report_rx
                    .recv() // hfl-lint: allow(R6, reports are slotted by edge id below)
                    .map_err(|_| anyhow!("all edge actors exited"))?;
                if rep.round != round {
                    bail!("edge {} reported round {} during {round}", rep.edge, rep.round);
                }
                if let Some(err) = &rep.error {
                    bail!("edge {} failed: {err}", rep.edge);
                }
                let slot = rep.edge;
                if reports[slot].replace(rep).is_some() {
                    bail!("duplicate report from edge {slot}");
                }
                received += 1;
            }
            let collected: Vec<EdgeReport> =
                reports.into_iter().map(|r| r.expect("filled")).collect();
            let refs: Vec<(u64, &[f32])> = collected
                .iter()
                .filter(|r| r.data_size > 0)
                .map(|r| (r.data_size, r.model.as_slice()))
                .collect();
            if refs.is_empty() {
                bail!("no edge contributed data");
            }
            global = cloud_aggregate(&refs);
            let mean_loss = collected.iter().map(|r| r.mean_loss as f64).sum::<f64>()
                / collected.len() as f64;

            if round % run.eval_every == 0 || round == run.cloud_rounds {
                let (loss, acc) = engine.evaluate(&global, &test.x, &test.y)?;
                curve.push(CurvePoint {
                    cloud_round: round,
                    sim_time_s: round as f64 * run.round_time_s,
                    wall_s: t0.elapsed().as_secs_f64(),
                    test_acc: acc,
                    test_loss: loss,
                    train_loss: mean_loss as f32,
                });
            }
        }
        for tx in &cmd_txs {
            let _ = tx.send(CloudMsg::Shutdown);
        }
        final_model = global;
        Ok(())
    })?;

    Ok(HflOutcome {
        curve,
        final_model,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}
