//! UE worker pool: run one edge round's local training across threads.
//!
//! Each UE's `a` local iterations are independent given the edge-round
//! start model, so members are chunked across `workers` scoped threads,
//! all executing against the shared PJRT [`Engine`] (thread-safe; see the
//! safety note in `runtime/engine.rs`). Results come back in member
//! order, so aggregation — and therefore the whole run — is bitwise
//! deterministic regardless of thread scheduling.

use anyhow::{anyhow, Result};

use crate::fl::solver::{local_gradient_at, local_round};
use crate::fl::{LocalSolver, UeState};
use crate::runtime::Engine;

/// Outcome of one UE's local round.
#[derive(Debug)]
pub struct UeResult {
    pub data_size: u64,
    pub model: Vec<f32>,
    pub loss: f32,
}

/// Run `a` local iterations for every member state in parallel.
/// `corrections[i]` is the DANE correction for member i (empty for GD).
pub fn parallel_local_rounds(
    engine: &Engine,
    solver: &LocalSolver,
    w_m: &[f32],
    members: &mut [UeState],
    a: u64,
    corrections: &[Vec<f32>],
    workers: usize,
) -> Result<Vec<UeResult>> {
    assert_eq!(corrections.len(), members.len());
    let n = members.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1).min(n);
    let chunk = n.div_ceil(workers);

    let mut slots: Vec<Option<Result<UeResult>>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        // Pair each member with its correction and output slot, chunked.
        let member_chunks = members.chunks_mut(chunk);
        let corr_chunks = corrections.chunks(chunk);
        let slot_chunks = slots.chunks_mut(chunk);
        for ((ms, cs), outs) in member_chunks.zip(corr_chunks).zip(slot_chunks) {
            handles.push(scope.spawn(move || {
                for ((ue, corr), out) in ms.iter_mut().zip(cs).zip(outs.iter_mut()) {
                    let res = local_round(engine, solver, w_m, &ue.shard, &mut ue.cursor, a, corr)
                        .map(|(model, loss)| UeResult {
                            data_size: ue.data_size(),
                            model,
                            loss,
                        });
                    *out = Some(res);
                }
            }));
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("UE worker panicked"))?;
        }
        Ok::<(), anyhow::Error>(())
    })?;

    slots
        .into_iter()
        .map(|s| s.expect("every slot filled"))
        .collect()
}

/// Parallel DANE preparation: each member's gradient at `w_m`, in member
/// order.
pub fn parallel_gradients(
    engine: &Engine,
    w_m: &[f32],
    members: &mut [UeState],
    workers: usize,
) -> Result<Vec<Vec<f32>>> {
    let n = members.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1).min(n);
    let chunk = n.div_ceil(workers);
    let mut slots: Vec<Option<Result<Vec<f32>>>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (ms, outs) in members.chunks_mut(chunk).zip(slots.chunks_mut(chunk)) {
            handles.push(scope.spawn(move || {
                for (ue, out) in ms.iter_mut().zip(outs.iter_mut()) {
                    *out = Some(local_gradient_at(engine, w_m, &ue.shard, &mut ue.cursor, 4));
                }
            }));
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("gradient worker panicked"))?;
        }
        Ok::<(), anyhow::Error>(())
    })?;

    slots
        .into_iter()
        .map(|s| s.expect("every slot filled"))
        .collect()
}
