//! L3 coordination runtime (populated by leader/worker/messages).

pub mod messages;
pub mod leader;
pub mod worker;

pub use leader::{run_hfl, HflOutcome};
