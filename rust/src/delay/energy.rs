//! Energy model extension (paper context: ref. [21], "Energy efficient
//! federated learning over wireless communication networks").
//!
//! The paper pins `f_n = f_max`, `p_n = p_max` because its objective is
//! pure time (§IV-C.1). The natural follow-up question — what does the
//! time-optimal schedule COST, and how does the frontier move if UEs
//! scale their CPU down — needs the standard CMOS/transmission energy
//! model, implemented here:
//!
//! * computation: `E_cmp = κ · f² · C_n · D_n` per local iteration
//!   (effective-capacitance model; energy/cycle ∝ f², time ∝ 1/f);
//! * transmission: `E_com = p_n · t_{n→m}^com`.
//!
//! `energy_time_frontier` sweeps a CPU-frequency scaling factor and
//! reports the (time, energy) Pareto curve for a [`DelayInstance`]-like
//! scenario — the ablation `EXPERIMENTS.md` cites for the "max frequency
//! is time-optimal but energy-hungry" observation.

use crate::net::{Channel, Topology};

/// Effective switched capacitance κ (J·s²/cycle³ scale). Typical value
/// in the FL-over-wireless literature: 1e-28.
pub const KAPPA_DEFAULT: f64 = 1e-28;

/// Per-UE energy for one edge round at CPU frequency `f` (Hz):
/// `a` local iterations of compute plus one model upload.
pub fn ue_round_energy(
    kappa: f64,
    f_hz: f64,
    cycles_per_sample: f64,
    num_samples: u64,
    a: f64,
    tx_power_w: f64,
    upload_s: f64,
) -> f64 {
    let cycles = cycles_per_sample * num_samples as f64;
    a * kappa * f_hz * f_hz * cycles + tx_power_w * upload_s
}

/// One point of the time/energy frontier.
#[derive(Debug, Clone, Copy)]
pub struct FrontierPoint {
    /// CPU frequency scale in (0, 1] relative to f_max.
    pub f_scale: f64,
    /// One-cloud-round time T(a,b) under the scaled frequencies (s).
    pub round_time_s: f64,
    /// Total energy across all UEs for one cloud round (J).
    pub round_energy_j: f64,
}

/// Sweep CPU-frequency scaling and report the per-cloud-round
/// (time, energy) frontier for association `members` (edge -> UE ids)
/// at iteration counts (a, b).
pub fn energy_time_frontier(
    topo: &Topology,
    channel: &Channel,
    members: &[Vec<usize>],
    a: f64,
    b: f64,
    kappa: f64,
    scales: &[f64],
) -> Vec<FrontierPoint> {
    scales
        .iter()
        .map(|&s| {
            assert!(s > 0.0 && s <= 1.0, "frequency scale in (0,1]");
            let mut worst_edge = 0.0f64;
            let mut energy = 0.0f64;
            for (m, ues) in members.iter().enumerate() {
                let mut tau = 0.0f64;
                for &n in ues {
                    let ue = &topo.ues[n];
                    let f = ue.cpu_hz * s;
                    let t_cmp = ue.cycles_per_sample * ue.num_samples as f64 / f;
                    let upload = ue.model_bits / channel.rate_of(n, m);
                    tau = tau.max(a * t_cmp + upload);
                    energy += b
                        * ue_round_energy(
                            kappa,
                            f,
                            ue.cycles_per_sample,
                            ue.num_samples,
                            a,
                            ue.tx_power_w,
                            upload,
                        );
                }
                let backhaul = topo.edges[m].model_bits / topo.edges[m].cloud_rate_bps;
                worst_edge = worst_edge.max(b * tau + backhaul);
            }
            FrontierPoint {
                f_scale: s,
                round_time_s: worst_edge,
                round_energy_j: energy,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc;
    use crate::net::{Channel, SystemParams, Topology};

    fn world() -> (Topology, Channel, Vec<Vec<usize>>) {
        let params = SystemParams::default();
        let topo = Topology::sample(&params, 3, 30, 7);
        let ch = Channel::compute(&params, &topo.ues, &topo.edges);
        let assoc = assoc::time_minimized(&ch, params.edge_capacity()).unwrap();
        let members = assoc.members();
        (topo, ch, members)
    }

    #[test]
    fn energy_scales_quadratically_with_frequency() {
        // Pure-compute energy at equal iteration counts: E(f)/E(f/2) = 4.
        let e1 = ue_round_energy(KAPPA_DEFAULT, 2e9, 2e4, 500, 10.0, 0.0, 0.0);
        let e2 = ue_round_energy(KAPPA_DEFAULT, 1e9, 2e4, 500, 10.0, 0.0, 0.0);
        assert!((e1 / e2 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn frontier_is_monotone_tradeoff() {
        let (topo, ch, members) = world();
        let pts = energy_time_frontier(
            &topo,
            &ch,
            &members,
            18.0,
            5.0,
            KAPPA_DEFAULT,
            &[0.25, 0.5, 0.75, 1.0],
        );
        assert_eq!(pts.len(), 4);
        for w in pts.windows(2) {
            // Higher frequency: faster rounds...
            assert!(w[1].round_time_s < w[0].round_time_s);
            // ...but more energy.
            assert!(w[1].round_energy_j > w[0].round_energy_j);
        }
    }

    #[test]
    fn full_speed_matches_delay_model() {
        let (topo, ch, members) = world();
        let assoc = crate::assoc::Association::new(
            {
                let mut edge_of = vec![0usize; topo.num_ues()];
                for (m, ues) in members.iter().enumerate() {
                    for &n in ues {
                        edge_of[n] = m;
                    }
                }
                edge_of
            },
            members.len(),
        );
        let inst = crate::delay::DelayInstance::build(&topo, &ch, &assoc, 0.25);
        let pts =
            energy_time_frontier(&topo, &ch, &members, 18.0, 5.0, KAPPA_DEFAULT, &[1.0]);
        let t_model = inst.round_time(18.0, 5.0);
        assert!(
            (pts[0].round_time_s - t_model).abs() < 1e-9 * t_model,
            "frontier {} vs delay model {}",
            pts[0].round_time_s,
            t_model
        );
    }

    #[test]
    #[should_panic(expected = "frequency scale")]
    fn rejects_bad_scale() {
        let (topo, ch, members) = world();
        energy_time_frontier(&topo, &ch, &members, 1.0, 1.0, KAPPA_DEFAULT, &[1.5]);
    }

    #[test]
    fn energy_magnitudes_plausible() {
        // 2 GHz, 2e4 cyc/sample, 500 samples, 10 iterations:
        // E_cmp = 10 · 1e-28 · (2e9)² · 1e7 = 40 mJ, plus 10 mJ of
        // transmission — the right ballpark for mobile CPU training
        // bursts in the FL-over-wireless literature.
        let e = ue_round_energy(KAPPA_DEFAULT, 2e9, 2e4, 500, 10.0, 0.01, 1.0);
        assert!(e > 1e-3 && e < 10.0, "{e} J");
    }
}
