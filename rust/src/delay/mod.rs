//! The paper's delay / iteration-count model (§III, Eqs. (1)–(15)).
//!
//! This module is the heart of the reproduction: every closed-form
//! quantity the paper defines is implemented here and consumed by the
//! optimizer (`opt/`), the association solvers (`assoc/`), the latency
//! simulator (`sim/`) and the training engine (`fl/`, which *simulates*
//! wall-clock time with these formulas while running real training steps
//! through PJRT).
//!
//! One modeling note recorded in EXPERIMENTS.md: with the continuous
//! cloud-round count of Eq. (15), `ln(1/ε)` is a pure multiplicative
//! factor, so the minimizer (a*, b*) would be independent of ε — which
//! contradicts the paper's own Fig. 2. Rounds are discrete in the real
//! protocol, so [`cloud_rounds_int`] (the ceiling of Eq. (15)) is what the
//! Fig. 2 experiment uses; it restores the ε-dependence the paper reports.

pub mod energy;
pub mod incremental;

pub use incremental::MaintainedInstance;

use crate::assoc::Association;
use crate::net::{Channel, Topology, Ue};

/// Eq. (1): per-iteration local computation time `t_n^cmp = C_n D_n / f_n`.
///
/// `f_n` is per-UE: the paper pins it to `f_max` fleet-wide (§IV-C.1),
/// while the device-class extension (`net::DeviceClassSpec`) samples it
/// per class. Everything downstream — `EdgeDelays`, the Pareto
/// frontiers, `τ_m(a)` — was already a max over per-UE lines, so
/// heterogeneous fleets need no structural change here; `τ_max(a)`
/// stays nondecreasing in `a` (nonnegative slopes), which is the only
/// property the warm integer solver's pruning relies on.
pub fn ue_compute_time(ue: &Ue) -> f64 {
    ue.cycles_per_sample * ue.num_samples as f64 / ue.cpu_hz
}

/// Eq. (2): local iterations to reach local accuracy θ: `a = ζ ln(1/θ)`.
pub fn local_iters_for_accuracy(theta: f64, zeta: f64) -> f64 {
    assert!(theta > 0.0 && theta < 1.0, "θ must be in (0,1)");
    zeta * (1.0 / theta).ln()
}

/// Inverse of Eq. (2): θ(a) = e^{-a/ζ}.
pub fn local_accuracy_of(a: f64, zeta: f64) -> f64 {
    (-a / zeta).exp()
}

/// Eq. (7): edge iterations for edge accuracy μ given local accuracy θ:
/// `b = γ ln(1/μ) / (1-θ)`.
pub fn edge_iters_for_accuracy(mu: f64, theta: f64, gamma: f64) -> f64 {
    assert!(mu > 0.0 && mu < 1.0, "μ must be in (0,1)");
    assert!(theta > 0.0 && theta < 1.0, "θ must be in (0,1)");
    gamma * (1.0 / mu).ln() / (1.0 - theta)
}

/// Inverse of Eq. (7): μ(b, θ) = e^{-(b/γ)(1-θ)}.
pub fn edge_accuracy_of(b: f64, theta: f64, gamma: f64) -> f64 {
    (-(b / gamma) * (1.0 - theta)).exp()
}

/// Eq. (15): continuous cloud-round count
/// `R(a,b,ε) = C ln(1/ε) / (1 - e^{-(b/γ)(1 - e^{-a/ζ})})`.
pub fn cloud_rounds(a: f64, b: f64, eps: f64, c_const: f64, gamma: f64, zeta: f64) -> f64 {
    assert!(eps > 0.0 && eps < 1.0, "ε must be in (0,1)");
    let theta = local_accuracy_of(a, zeta);
    let mu = edge_accuracy_of(b, theta, gamma);
    c_const * (1.0 / eps).ln() / (1.0 - mu)
}

/// Integer (protocol-real) cloud-round count: ⌈Eq. (15)⌉, min 1.
pub fn cloud_rounds_int(a: f64, b: f64, eps: f64, c_const: f64, gamma: f64, zeta: f64) -> u64 {
    cloud_rounds(a, b, eps, c_const, gamma, zeta).ceil().max(1.0) as u64
}

/// Eq. (5): UE→edge upload time for one model of `bits` at `rate_bps`.
pub fn upload_time(bits: f64, rate_bps: f64) -> f64 {
    assert!(rate_bps > 0.0);
    bits / rate_bps
}

/// Per-edge data of the optimization instance: each member UE's
/// `(t_n^cmp, t_{n→m}^com)` pair plus the edge's backhaul time Eq. (8).
#[derive(Debug, Clone)]
pub struct EdgeDelays {
    /// (compute seconds per local iteration, upload seconds per round).
    pub ue: Vec<(f64, f64)>,
    /// Eq. (8): `t_{m→c}^com = d_m / r_m`.
    pub backhaul_s: f64,
}

impl EdgeDelays {
    /// Constraint (16b) boundary: `τ_m(a) = max_n (a t_n^cmp + t_n^com)`.
    pub fn tau(&self, a: f64) -> f64 {
        self.ue
            .iter()
            .map(|&(cmp, com)| a * cmp + com)
            .fold(0.0, f64::max)
    }
}

/// A fully-instantiated delay-model instance: the input to the optimizer
/// and the latency simulator. Built from a topology + channel +
/// association, or synthesized directly in tests.
#[derive(Debug, Clone)]
pub struct DelayInstance {
    pub per_edge: Vec<EdgeDelays>,
    pub gamma: f64,
    pub zeta: f64,
    pub c_const: f64,
    pub eps: f64,
}

impl DelayInstance {
    /// Build from a deployed topology, its channel tables and an
    /// association, under the fixed per-UE bandwidth policy (rates in
    /// `channel.rate_bps`).
    pub fn build(topo: &Topology, channel: &Channel, assoc: &Association, eps: f64) -> Self {
        let members = assoc.members();
        let per_edge = topo
            .edges
            .iter()
            .map(|edge| EdgeDelays {
                ue: members[edge.id]
                    .iter()
                    .map(|&n| {
                        let ue = &topo.ues[n];
                        (
                            ue_compute_time(ue),
                            upload_time(ue.model_bits, channel.rate_of(n, edge.id)),
                        )
                    })
                    .collect(),
                backhaul_s: upload_time(edge.model_bits, edge.cloud_rate_bps),
            })
            .collect();
        DelayInstance {
            per_edge,
            gamma: topo.params.gamma,
            zeta: topo.params.zeta,
            c_const: topo.params.c_const,
            eps,
        }
    }

    /// Same, but with the equal-share bandwidth policy: each member of an
    /// edge with k UEs uploads at `B/k` bandwidth (§III-A.2).
    pub fn build_equal_share(
        topo: &Topology,
        channel: &Channel,
        assoc: &Association,
        eps: f64,
    ) -> Self {
        let members = assoc.members();
        let per_edge = topo
            .edges
            .iter()
            .map(|edge| {
                let k = members[edge.id].len();
                EdgeDelays {
                    ue: members[edge.id]
                        .iter()
                        .map(|&n| {
                            let ue = &topo.ues[n];
                            let r = channel.rate_equal_share(&topo.params, n, edge.id, k);
                            (ue_compute_time(ue), upload_time(ue.model_bits, r))
                        })
                        .collect(),
                    backhaul_s: upload_time(edge.model_bits, edge.cloud_rate_bps),
                }
            })
            .collect();
        DelayInstance {
            per_edge,
            gamma: topo.params.gamma,
            zeta: topo.params.zeta,
            c_const: topo.params.c_const,
            eps,
        }
    }

    /// `τ_m(a)` for every edge (Eq. (33) inner max), indexed by edge —
    /// Algorithm 2's dual update relies on the per-edge alignment, so
    /// memberless edges report `τ = 0` here. They are *excluded* from
    /// [`round_time`](Self::round_time): see that method.
    pub fn taus(&self, a: f64) -> Vec<f64> {
        self.per_edge.iter().map(|e| e.tau(a)).collect()
    }

    /// `max_m τ_m(a)` without the per-edge allocation (the solver's
    /// pruning bound; memberless edges contribute nothing since τ = 0).
    pub fn tau_max(&self, a: f64) -> f64 {
        self.per_edge.iter().map(|e| e.tau(a)).fold(0.0, f64::max)
    }

    /// One cloud-round time (Eq. (34) inner expression):
    /// `T(a,b) = max_m (b τ_m(a) + t_{m→c}^com)`.
    ///
    /// Only edges with members participate: an edge emptied by churn or
    /// handovers hosts no round and uploads no aggregate, so its backhaul
    /// term must not gate the cloud barrier. (The seed erroneously kept
    /// `b·0 + t_{m→c}^com` for memberless edges, inflating `T(a,b)` and
    /// corrupting every post-churn (a, b) re-solve.) The event simulator
    /// excludes the same edges, keeping the closed form and the simulated
    /// makespan in lockstep.
    pub fn round_time(&self, a: f64, b: f64) -> f64 {
        self.per_edge
            .iter()
            .filter(|e| !e.ue.is_empty())
            .map(|e| b * e.tau(a) + e.backhaul_s)
            .fold(0.0, f64::max)
    }

    /// The paper's objective (13): `R(a,b,ε) · T(a,b)` (continuous R).
    pub fn total_time(&self, a: f64, b: f64) -> f64 {
        cloud_rounds(a, b, self.eps, self.c_const, self.gamma, self.zeta) * self.round_time(a, b)
    }

    /// Objective with the protocol-real integer round count (see module
    /// docs — this is what the Fig. 2 sweep uses).
    pub fn total_time_int(&self, a: f64, b: f64) -> f64 {
        cloud_rounds_int(a, b, self.eps, self.c_const, self.gamma, self.zeta) as f64
            * self.round_time(a, b)
    }

    pub fn num_ues(&self) -> usize {
        self.per_edge.iter().map(|e| e.ue.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::Association;
    use crate::net::{SystemParams, Topology};

    #[test]
    fn eq1_hand_computed() {
        let ue = Ue {
            id: 0,
            pos: crate::net::Position { x: 0.0, y: 0.0 },
            cpu_hz: 2e9,
            tx_power_w: 0.01,
            cycles_per_sample: 2e4,
            num_samples: 500,
            model_bits: 1e6,
        };
        // 2e4 * 500 / 2e9 = 5 ms
        assert!((ue_compute_time(&ue) - 0.005).abs() < 1e-12);
    }

    #[test]
    fn eq2_eq7_roundtrip() {
        let (zeta, gamma) = (6.0, 4.0);
        let theta = 0.1;
        let a = local_iters_for_accuracy(theta, zeta);
        assert!((local_accuracy_of(a, zeta) - theta).abs() < 1e-12);
        let mu = 0.05;
        let b = edge_iters_for_accuracy(mu, theta, gamma);
        assert!((edge_accuracy_of(b, theta, gamma) - mu).abs() < 1e-12);
    }

    #[test]
    fn more_accuracy_needs_more_iters() {
        let zeta = 6.0;
        assert!(
            local_iters_for_accuracy(0.01, zeta) > local_iters_for_accuracy(0.1, zeta)
        );
        let gamma = 4.0;
        assert!(
            edge_iters_for_accuracy(0.01, 0.1, gamma) > edge_iters_for_accuracy(0.1, 0.1, gamma)
        );
        // Worse local accuracy (bigger θ) needs more edge iterations.
        assert!(
            edge_iters_for_accuracy(0.1, 0.5, gamma) > edge_iters_for_accuracy(0.1, 0.1, gamma)
        );
    }

    #[test]
    fn rounds_decrease_in_a_and_b() {
        let (c, g, z, eps) = (1.0, 4.0, 6.0, 0.25);
        let r = |a: f64, b: f64| cloud_rounds(a, b, eps, c, g, z);
        assert!(r(10.0, 5.0) > r(20.0, 5.0));
        assert!(r(10.0, 5.0) > r(10.0, 10.0));
        // And increase as ε shrinks.
        assert!(cloud_rounds(10.0, 5.0, 0.05, c, g, z) > r(10.0, 5.0));
        // Continuous rounds always ≥ ln(1/eps)*C.
        assert!(r(1e9, 1e9) >= (1.0 / eps).ln() * 0.999);
    }

    #[test]
    fn integer_rounds_ceil() {
        let r = cloud_rounds(10.0, 5.0, 0.25, 1.0, 4.0, 6.0);
        let ri = cloud_rounds_int(10.0, 5.0, 0.25, 1.0, 4.0, 6.0);
        assert_eq!(ri, r.ceil() as u64);
        assert!(ri >= 1);
    }

    #[test]
    fn tau_is_piecewise_linear_max() {
        let e = EdgeDelays {
            ue: vec![(0.001, 0.5), (0.01, 0.1)],
            backhaul_s: 0.02,
        };
        // Small a: first UE dominates via upload; large a: second via compute.
        assert!((e.tau(1.0) - 0.501).abs() < 1e-12);
        assert!((e.tau(100.0) - 1.1).abs() < 1e-9);
    }

    #[test]
    fn memberless_edge_excluded_from_round_time() {
        // Regression: an edge emptied by churn kept injecting its
        // backhaul into T(a,b) (`b·0 + 50`), dwarfing the live edge.
        let inst = DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.001, 0.1)],
                    backhaul_s: 0.02,
                },
                EdgeDelays {
                    ue: vec![],
                    backhaul_s: 50.0,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        // Only the live edge: 2·(10·0.001 + 0.1) + 0.02.
        assert!((inst.round_time(10.0, 2.0) - 0.24).abs() < 1e-12);
        // taus keeps per-edge indexing (Algorithm 2 needs it): τ = 0 there.
        let taus = inst.taus(10.0);
        assert!((taus[0] - 0.11).abs() < 1e-12);
        assert_eq!(taus[1], 0.0);
        assert!((inst.tau_max(10.0) - 0.11).abs() < 1e-12);
        // Fully-drained world: a round takes no time at all.
        let ghost = DelayInstance {
            per_edge: vec![EdgeDelays {
                ue: vec![],
                backhaul_s: 3.0,
            }],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        assert_eq!(ghost.round_time(5.0, 5.0), 0.0);
        assert_eq!(ghost.total_time_int(5.0, 5.0), 0.0);
    }

    #[test]
    fn instance_round_trip() {
        let topo = Topology::sample(&SystemParams::default(), 3, 15, 9);
        let ch = crate::net::Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let assoc = Association::new((0..15).map(|n| n % 3).collect(), 3);
        let inst = DelayInstance::build(&topo, &ch, &assoc, 0.25);
        assert_eq!(inst.num_ues(), 15);
        assert_eq!(inst.per_edge.len(), 3);
        let t1 = inst.round_time(10.0, 5.0);
        let t2 = inst.round_time(10.0, 10.0);
        assert!(t2 > t1, "round time grows with b");
        assert!(inst.total_time(10.0, 5.0) > 0.0);
        assert!(inst.total_time_int(10.0, 5.0) >= inst.round_time(10.0, 5.0));
    }

    #[test]
    fn equal_share_slower_with_many_ues() {
        // 15 UEs on 1 edge: equal share gives each 20/15 MHz ≈ 1.33 MHz —
        // better than the fixed 1 MHz; with 40 UEs it's 0.5 MHz — worse.
        let topo = Topology::sample(&SystemParams::default(), 1, 40, 11);
        let ch = crate::net::Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let assoc = Association::new(vec![0; 40], 1);
        let fixed = DelayInstance::build(&topo, &ch, &assoc, 0.25);
        let shared = DelayInstance::build_equal_share(&topo, &ch, &assoc, 0.25);
        assert!(shared.round_time(10.0, 1.0) > fixed.round_time(10.0, 1.0));
    }
}
