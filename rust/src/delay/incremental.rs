//! Incrementally-maintained delay instance — the re-solve hot path.
//!
//! The scenario engine re-minimizes `R(a,b,ε)·T(a,b)` every epoch, but an
//! epoch's dynamics touch only a few rows of the world: mobility moves
//! some UEs (changing their upload times), churn removes/re-adds a few,
//! handovers move a few between edges. [`MaintainedInstance`] applies
//! exactly those deltas to a [`DelayInstance`] kept alive across epochs,
//! instead of reallocating the whole member structure per epoch, and
//! caches a per-edge *Pareto frontier* of `(t^cmp, t^com)` lines so that
//! `τ_m(a) = max_n (a·t_n^cmp + t_n^com)` evaluates over the few
//! non-dominated members instead of re-scanning every UE — the operation
//! the integer solver performs thousands of times per re-solve.
//!
//! Bitwise discipline (what the scenario tests rely on):
//!
//! * member lists are kept sorted by global UE id, and every `(cmp, com)`
//!   pair is computed with the same expressions as the from-scratch
//!   build, so [`MaintainedInstance::instance`] is indistinguishable —
//!   bit for bit — from rebuilding via `DelayInstance`-style construction;
//! * a line dominated by another (`cmp` and `com` both ≤) can never
//!   exceed the dominator under IEEE-754 round-to-nearest (rounding is
//!   monotone), so folding the max over the frontier returns the *same
//!   bits* as folding over all members. Warm and cold solvers therefore
//!   see identical objective values.
//!
//! Memberless edges hold an empty frontier and contribute nothing to
//! `round_time`/`tau_max`, matching the post-churn semantics of
//! [`DelayInstance::round_time`].

use super::{cloud_rounds_int, ue_compute_time, upload_time, DelayInstance, EdgeDelays};
use crate::net::{Channel, Topology};
use crate::trace::{Counter, TraceSink};
use crate::util::ShardPool;

/// `max_n (a·cmp_n + com_n)` over a set of delay lines (0 when empty).
#[inline]
fn tau_lines(lines: &[(f64, f64)], a: f64) -> f64 {
    lines.iter().map(|&(cmp, com)| a * cmp + com).fold(0.0, f64::max)
}

/// Non-dominated subset of delay lines: a line survives unless some other
/// line has both a larger-or-equal slope (compute time) and a
/// larger-or-equal intercept (upload time). The max over the survivors
/// equals the max over the full set for every `a ≥ 0`, bit for bit.
fn pareto_frontier(lines: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<(f64, f64)> = lines.to_vec();
    // Descending slope, then descending intercept among equal slopes.
    sorted.sort_by(|x, y| y.0.total_cmp(&x.0).then(y.1.total_cmp(&x.1)));
    let mut keep = Vec::new();
    let mut best_com = f64::NEG_INFINITY;
    for (cmp, com) in sorted {
        if com > best_com {
            keep.push((cmp, com));
            best_com = com;
        }
    }
    keep
}

/// A [`DelayInstance`] that accepts per-UE deltas (mobility row updates,
/// churn arrivals/departures, handovers) and caches per-edge τ-evaluation
/// frontiers for the optimizer. See the module docs for the invariants.
#[derive(Debug, Clone)]
pub struct MaintainedInstance {
    inst: DelayInstance,
    /// `(edge, slot)` of each global UE id; `None` = not associated.
    slot: Vec<Option<(usize, usize)>>,
    /// Global UE id held at `inst.per_edge[e].ue[s]` (sorted ascending).
    member: Vec<Vec<usize>>,
    /// Flat Pareto-frontier store (struct-of-arrays): edge `e`'s cached
    /// frontier is `frontier_store[frontier_off[e]..frontier_off[e + 1]]`.
    /// One allocation instead of one per edge — the layout [`Self::refresh`]
    /// rebuilds as an edge-ordered concatenation, so the bytes are a pure
    /// function of the world regardless of how many threads computed the
    /// per-edge frontiers.
    frontier_store: Vec<(f64, f64)>,
    /// `m + 1` offsets into `frontier_store` (edge-ordered prefix sums).
    frontier_off: Vec<usize>,
    dirty: Vec<bool>,
    /// Intra-instance fork/join pool for [`Self::refresh`] — purely a
    /// speed knob, every thread count yields bitwise-identical frontiers.
    pool: ShardPool,
    /// Cumulative frontiers rebuilt by [`Self::refresh`] — deterministic
    /// telemetry (the solver calls `refresh`, so this is a counter the
    /// scenario loop reads by delta rather than a sink parameter).
    frontier_rebuilds: u64,
}

impl MaintainedInstance {
    /// Build from a world snapshot and a per-global-UE serving edge
    /// (`None` = inactive), mirroring the scenario engine's association
    /// output. Members land in ascending global-id order.
    pub fn build(
        topo: &Topology,
        channel: &Channel,
        edge_of: &[Option<usize>],
        eps: f64,
    ) -> MaintainedInstance {
        debug_assert_eq!(edge_of.len(), topo.num_ues());
        let m = topo.num_edges();
        let inst = DelayInstance {
            per_edge: topo
                .edges
                .iter()
                .map(|edge| EdgeDelays {
                    ue: Vec::new(),
                    backhaul_s: upload_time(edge.model_bits, edge.cloud_rate_bps),
                })
                .collect(),
            gamma: topo.params.gamma,
            zeta: topo.params.zeta,
            c_const: topo.params.c_const,
            eps,
        };
        let mut maintained = MaintainedInstance {
            inst,
            slot: vec![None; edge_of.len()],
            member: vec![Vec::new(); m],
            frontier_store: Vec::new(),
            frontier_off: vec![0; m + 1],
            dirty: vec![true; m],
            pool: ShardPool::serial(),
            frontier_rebuilds: 0,
        };
        for (n, e) in edge_of.iter().enumerate() {
            if let Some(e) = e {
                maintained.insert(n, *e, topo, channel);
            }
        }
        maintained
    }

    /// The live instance (always structurally up to date; `refresh` is
    /// only needed before the frontier-backed evaluation methods).
    pub fn instance(&self) -> &DelayInstance {
        &self.inst
    }

    /// Diff the maintained state against the current world: re-derives
    /// every active UE's `(t^cmp, t^com)` from the (possibly moved)
    /// channel row, applies churn departures/arrivals and handovers, and
    /// marks only the touched edges' frontiers dirty. O(N) float work,
    /// zero allocation when membership is unchanged.
    pub fn sync(&mut self, topo: &Topology, channel: &Channel, edge_of: &[Option<usize>]) {
        debug_assert_eq!(edge_of.len(), self.slot.len());
        for (n, desired) in edge_of.iter().enumerate() {
            self.sync_one(n, *desired, topo, channel);
        }
    }

    /// [`Self::sync`] restricted to a known touched set — the delta-driven
    /// path the scenario engine uses once it knows exactly which channel
    /// rows moved and whose membership changed, making the per-epoch
    /// maintenance O(touched) instead of O(N) float re-derivations.
    ///
    /// Caller contract: `touched` must contain every UE whose channel row
    /// changed since the last sync *and* every UE whose desired edge
    /// differs from the maintained one. Duplicates are harmless (the
    /// per-UE update is idempotent). With a complete set the result is
    /// bitwise-identical to a full [`Self::sync`].
    pub fn sync_delta(
        &mut self,
        topo: &Topology,
        channel: &Channel,
        edge_of: &[Option<usize>],
        touched: &[usize],
    ) {
        debug_assert_eq!(edge_of.len(), self.slot.len());
        for &n in touched {
            self.sync_one(n, edge_of[n], topo, channel);
        }
    }

    /// [`Self::sync_delta`] plus telemetry: reports the touched-set size
    /// to `sink`. The maintained state is identical to the untraced call.
    pub fn sync_delta_traced(
        &mut self,
        topo: &Topology,
        channel: &Channel,
        edge_of: &[Option<usize>],
        touched: &[usize],
        sink: &mut dyn TraceSink,
    ) {
        if sink.enabled() {
            sink.counter(Counter::DelayTouched, touched.len() as u64);
        }
        self.sync_delta(topo, channel, edge_of, touched);
    }

    /// One UE's sync step, shared by [`Self::sync`] and
    /// [`Self::sync_delta`] so the two paths cannot drift apart.
    fn sync_one(&mut self, n: usize, desired: Option<usize>, topo: &Topology, channel: &Channel) {
        match (self.slot[n], desired) {
            (Some((e, s)), Some(d)) if e == d => {
                let ue = &topo.ues[n];
                let delays = (
                    ue_compute_time(ue),
                    upload_time(ue.model_bits, channel.rate_of(n, e)),
                );
                if self.inst.per_edge[e].ue[s] != delays {
                    self.inst.per_edge[e].ue[s] = delays;
                    self.dirty[e] = true;
                }
            }
            (Some(_), _) => {
                self.remove(n);
                if let Some(d) = desired {
                    self.insert(n, d, topo, channel);
                }
            }
            (None, Some(d)) => self.insert(n, d, topo, channel),
            (None, None) => {}
        }
    }

    fn insert(&mut self, n: usize, e: usize, topo: &Topology, channel: &Channel) {
        debug_assert!(self.slot[n].is_none(), "UE {n} already assigned");
        let ue = &topo.ues[n];
        let delays = (
            ue_compute_time(ue),
            upload_time(ue.model_bits, channel.rate_of(n, e)),
        );
        let pos = self.member[e].partition_point(|&id| id < n);
        self.member[e].insert(pos, n);
        self.inst.per_edge[e].ue.insert(pos, delays);
        for (s, &id) in self.member[e].iter().enumerate().skip(pos) {
            self.slot[id] = Some((e, s));
        }
        self.dirty[e] = true;
    }

    fn remove(&mut self, n: usize) {
        let (e, s) = self.slot[n].take().expect("UE not assigned");
        self.member[e].remove(s);
        self.inst.per_edge[e].ue.remove(s);
        for (s2, &id) in self.member[e].iter().enumerate().skip(s) {
            self.slot[id] = Some((e, s2));
        }
        self.dirty[e] = true;
    }

    /// Set the refresh thread count (0 = one per core). Purely a speed
    /// knob: every thread count yields bitwise-identical frontiers
    /// (property-tested in `tests/parallel.rs`).
    pub fn set_intra_threads(&mut self, threads: usize) {
        self.pool = ShardPool::new(threads);
    }

    /// Resolved refresh thread count.
    pub fn intra_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Edge `e`'s cached Pareto frontier (valid after [`Self::refresh`]).
    #[inline]
    pub fn frontier_of(&self, e: usize) -> &[(f64, f64)] {
        &self.frontier_store[self.frontier_off[e]..self.frontier_off[e + 1]]
    }

    /// Rebuild the frontiers of edges whose membership or delays changed
    /// since the last refresh. Call once before a batch of evaluations.
    ///
    /// The dirty edges' frontiers are computed edge-parallel (each is a
    /// pure function of its edge's member lines), then spliced back into
    /// the flat store serially in ascending edge order — so the store's
    /// bytes never depend on the thread count.
    pub fn refresh(&mut self) {
        let dirty_edges: Vec<usize> = (0..self.dirty.len()).filter(|&e| self.dirty[e]).collect();
        if dirty_edges.is_empty() {
            return;
        }
        let pool = self.pool;
        let fresh: Vec<Vec<(f64, f64)>> = pool.map(
            dirty_edges
                .iter()
                .map(|&e| self.inst.per_edge[e].ue.as_slice())
                .collect(),
            |_, lines| pareto_frontier(lines),
        );
        let m = self.dirty.len();
        let mut store = Vec::with_capacity(self.frontier_store.len());
        let mut off = Vec::with_capacity(m + 1);
        off.push(0);
        let mut next_fresh = dirty_edges.iter().zip(&fresh).peekable();
        for e in 0..m {
            match next_fresh.peek() {
                Some(&(&d, f)) if d == e => {
                    store.extend_from_slice(f);
                    next_fresh.next();
                }
                _ => store.extend_from_slice(
                    &self.frontier_store[self.frontier_off[e]..self.frontier_off[e + 1]],
                ),
            }
            off.push(store.len());
        }
        self.frontier_store = store;
        self.frontier_off = off;
        for &e in &dirty_edges {
            self.dirty[e] = false;
        }
        self.frontier_rebuilds += dirty_edges.len() as u64;
    }

    /// Cumulative per-edge frontier rebuilds performed by
    /// [`Self::refresh`] over this instance's lifetime (deterministic).
    pub fn frontier_rebuilds(&self) -> u64 {
        self.frontier_rebuilds
    }

    #[inline]
    fn assert_fresh(&self) {
        debug_assert!(
            !self.dirty.iter().any(|&d| d),
            "MaintainedInstance: refresh() before frontier evaluation"
        );
    }

    /// `max_m τ_m(a)` via the cached frontiers (memberless edges give 0).
    pub fn tau_max(&self, a: f64) -> f64 {
        self.assert_fresh();
        (0..self.frontier_off.len() - 1)
            .map(|e| tau_lines(self.frontier_of(e), a))
            .fold(0.0, f64::max)
    }

    /// `T(a,b) = max_m (b·τ_m(a) + t_{m→c}^com)` over edges with members,
    /// bitwise equal to [`DelayInstance::round_time`].
    pub fn round_time(&self, a: f64, b: f64) -> f64 {
        self.assert_fresh();
        (0..self.inst.per_edge.len())
            .filter(|&e| self.frontier_off[e] < self.frontier_off[e + 1])
            .map(|e| b * tau_lines(self.frontier_of(e), a) + self.inst.per_edge[e].backhaul_s)
            .fold(0.0, f64::max)
    }

    /// `⌈R(a,b,ε)⌉ · T(a,b)`, bitwise equal to
    /// [`DelayInstance::total_time_int`].
    pub fn total_time_int(&self, a: f64, b: f64) -> f64 {
        let i = &self.inst;
        cloud_rounds_int(a, b, i.eps, i.c_const, i.gamma, i.zeta) as f64 * self.round_time(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Position, SystemParams};

    fn world(seed: u64) -> (Topology, Channel) {
        let t = Topology::sample(&SystemParams::default(), 3, 18, seed);
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        (t, ch)
    }

    /// From-scratch reference build (the scenario engine's original
    /// per-epoch construction): members in ascending global-id order.
    fn rebuild(
        topo: &Topology,
        channel: &Channel,
        edge_of: &[Option<usize>],
        eps: f64,
    ) -> DelayInstance {
        let m = topo.num_edges();
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); m];
        for (n, e) in edge_of.iter().enumerate() {
            if let Some(e) = e {
                members[*e].push(n);
            }
        }
        DelayInstance {
            per_edge: topo
                .edges
                .iter()
                .map(|edge| EdgeDelays {
                    ue: members[edge.id]
                        .iter()
                        .map(|&n| {
                            let ue = &topo.ues[n];
                            (
                                ue_compute_time(ue),
                                upload_time(ue.model_bits, channel.rate_of(n, edge.id)),
                            )
                        })
                        .collect(),
                    backhaul_s: upload_time(edge.model_bits, edge.cloud_rate_bps),
                })
                .collect(),
            gamma: topo.params.gamma,
            zeta: topo.params.zeta,
            c_const: topo.params.c_const,
            eps,
        }
    }

    fn check_equal(maintained: &MaintainedInstance, expect: &DelayInstance) {
        let got = maintained.instance();
        assert_eq!(got.per_edge.len(), expect.per_edge.len());
        for (g, e) in got.per_edge.iter().zip(&expect.per_edge) {
            assert_eq!(g.ue, e.ue, "member delays must match bitwise");
            assert_eq!(g.backhaul_s.to_bits(), e.backhaul_s.to_bits());
        }
    }

    #[test]
    fn build_then_sync_matches_rebuild_bitwise() {
        let (mut topo, mut ch) = world(9);
        let eps = 0.25;
        // Some UEs start inactive (None), like a churned world.
        let mut edge_of: Vec<Option<usize>> = (0..18)
            .map(|i| if i % 5 == 4 { None } else { Some(i % 3) })
            .collect();
        let mut m = MaintainedInstance::build(&topo, &ch, &edge_of, eps);
        check_equal(&m, &rebuild(&topo, &ch, &edge_of, eps));

        // Mobility: two UEs move, their channel rows are recomputed.
        topo.ues[2].pos = Position { x: 10.0, y: 20.0 };
        ch.recompute_ue(&topo.params, &topo.ues[2], &topo.edges);
        topo.ues[7].pos = Position { x: 400.0, y: 90.0 };
        ch.recompute_ue(&topo.params, &topo.ues[7], &topo.edges);
        // Churn departure, churn re-arrival, handover.
        edge_of[6] = None;
        edge_of[4] = Some(2);
        edge_of[0] = Some(1);
        m.sync(&topo, &ch, &edge_of);
        check_equal(&m, &rebuild(&topo, &ch, &edge_of, eps));

        // A no-op sync stays identical.
        m.sync(&topo, &ch, &edge_of);
        check_equal(&m, &rebuild(&topo, &ch, &edge_of, eps));
    }

    #[test]
    fn sync_delta_matches_full_sync_bitwise() {
        let (mut topo, mut ch) = world(13);
        let eps = 0.25;
        let mut edge_of: Vec<Option<usize>> = (0..18)
            .map(|i| if i % 7 == 6 { None } else { Some(i % 3) })
            .collect();
        let mut full = MaintainedInstance::build(&topo, &ch, &edge_of, eps);
        let mut delta = full.clone();

        // Mobility on two rows, one departure, one arrival, one handover.
        topo.ues[1].pos = Position { x: 44.0, y: 301.0 };
        ch.recompute_ue(&topo.params, &topo.ues[1], &topo.edges);
        topo.ues[9].pos = Position { x: 402.0, y: 77.0 };
        ch.recompute_ue(&topo.params, &topo.ues[9], &topo.edges);
        edge_of[3] = None;
        edge_of[6] = Some(2);
        edge_of[2] = Some(1);
        let touched = vec![1usize, 9, 3, 6, 2, 2]; // duplicate on purpose

        full.sync(&topo, &ch, &edge_of);
        delta.sync_delta(&topo, &ch, &edge_of, &touched);
        check_equal(&delta, full.instance());

        // An empty delta is a no-op.
        delta.sync_delta(&topo, &ch, &edge_of, &[]);
        check_equal(&delta, full.instance());
    }

    #[test]
    fn frontier_eval_matches_full_scan_bitwise() {
        let (topo, ch) = world(4);
        let edge_of: Vec<Option<usize>> = (0..18).map(|i| Some(i % 3)).collect();
        let mut m = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        m.refresh();
        let inst = rebuild(&topo, &ch, &edge_of, 0.25);
        for a in [1.0, 3.0, 17.0, 60.5, 200.0] {
            assert_eq!(m.tau_max(a).to_bits(), inst.tau_max(a).to_bits());
            for b in [1.0, 2.0, 9.0, 40.0] {
                assert_eq!(m.round_time(a, b).to_bits(), inst.round_time(a, b).to_bits());
                assert_eq!(
                    m.total_time_int(a, b).to_bits(),
                    inst.total_time_int(a, b).to_bits()
                );
            }
        }
    }

    #[test]
    fn refresh_is_bitwise_identical_for_any_thread_count() {
        let (mut topo, mut ch) = world(6);
        let edge_of: Vec<Option<usize>> = (0..18).map(|i| Some(i % 3)).collect();
        let mut serial = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        serial.refresh();
        for threads in [2usize, 8] {
            let mut par = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
            par.set_intra_threads(threads);
            assert_eq!(par.intra_threads(), threads);
            par.refresh();
            assert_eq!(par.frontier_store, serial.frontier_store, "threads={threads}");
            assert_eq!(par.frontier_off, serial.frontier_off);
        }
        // Partial refresh (only one edge dirty) splices, not rebuilds.
        topo.ues[4].pos = Position { x: 312.0, y: 18.0 };
        ch.recompute_ue(&topo.params, &topo.ues[4], &topo.edges);
        serial.sync_delta(&topo, &ch, &edge_of, &[4]);
        serial.refresh();
        for threads in [2usize, 8] {
            let mut par = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
            par.set_intra_threads(threads);
            par.refresh();
            assert_eq!(par.frontier_store, serial.frontier_store, "threads={threads}");
            assert_eq!(par.frontier_off, serial.frontier_off);
        }
    }

    #[test]
    fn frontier_prunes_dominated_members() {
        let (topo, ch) = world(7);
        // Pile everyone on edge 0: plenty of dominated lines.
        let edge_of: Vec<Option<usize>> = (0..18).map(|_| Some(0)).collect();
        let mut m = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        m.refresh();
        assert!(!m.frontier_of(0).is_empty());
        assert!(
            m.frontier_of(0).len() <= m.inst.per_edge[0].ue.len(),
            "frontier cannot exceed the member count"
        );
        // Frontier intercepts strictly increase as slopes decrease.
        for w in m.frontier_of(0).windows(2) {
            assert!(w[0].0 >= w[1].0 && w[0].1 < w[1].1);
        }
    }

    #[test]
    fn memberless_edge_excluded_from_eval() {
        let (topo, ch) = world(2);
        // Edge 1 gets nobody.
        let edge_of: Vec<Option<usize>> = (0..18)
            .map(|i| Some(if i % 2 == 0 { 0 } else { 2 }))
            .collect();
        let mut m = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        m.refresh();
        assert!(m.frontier_of(1).is_empty());
        let inst = rebuild(&topo, &ch, &edge_of, 0.25);
        assert_eq!(m.round_time(10.0, 4.0).to_bits(), inst.round_time(10.0, 4.0).to_bits());
    }

    #[test]
    fn hetero_fleet_frontier_matches_full_scan_bitwise() {
        // Device classes make the per-edge lines genuinely unequal (1000x
        // slope spread); the Pareto pruning argument never assumed equal
        // members, so the frontier evaluation must stay bitwise-equal to
        // the full scan — and the frontier should actually prune, since a
        // slow-CPU member dominates fast ones at matching upload times.
        use crate::net::DeviceClassSpec;
        let params = SystemParams::default();
        let devices = DeviceClassSpec::new()
            .class("fast", 1.0, 1.0, 1.0, 1.0)
            .class("slow", 1.0, 0.001, 0.5, 2.0);
        let topo = Topology::sample_with_devices(&params, &devices, 3, 24, 19);
        let ch = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let edge_of: Vec<Option<usize>> = (0..24).map(|i| Some(i % 3)).collect();
        let mut m = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        m.refresh();
        let inst = rebuild(&topo, &ch, &edge_of, 0.25);
        for a in [1.0, 5.0, 42.0, 150.0] {
            assert_eq!(m.tau_max(a).to_bits(), inst.tau_max(a).to_bits());
            for b in [1.0, 3.0, 17.0] {
                assert_eq!(m.round_time(a, b).to_bits(), inst.round_time(a, b).to_bits());
            }
        }
    }

    #[test]
    fn refresh_counts_frontier_rebuilds() {
        let (topo, ch) = world(3);
        let edge_of: Vec<Option<usize>> = (0..18).map(|i| Some(i % 3)).collect();
        let mut m = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        assert_eq!(m.frontier_rebuilds(), 0);
        m.refresh();
        assert_eq!(m.frontier_rebuilds(), 3, "all edges dirty after build");
        m.refresh();
        assert_eq!(m.frontier_rebuilds(), 3, "clean refresh rebuilds nothing");
    }

    #[test]
    fn sync_delta_traced_matches_untraced_and_counts() {
        use crate::trace::StatsSink;
        let (mut topo, mut ch) = world(13);
        let edge_of: Vec<Option<usize>> = (0..18).map(|i| Some(i % 3)).collect();
        let mut a = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        let mut b = a.clone();
        topo.ues[5].pos = Position { x: 99.0, y: 44.0 };
        ch.recompute_ue(&topo.params, &topo.ues[5], &topo.edges);
        let touched = vec![5usize, 11];
        a.sync_delta(&topo, &ch, &edge_of, &touched);
        let mut sink = StatsSink::default();
        b.sync_delta_traced(&topo, &ch, &edge_of, &touched, &mut sink);
        check_equal(&b, a.instance());
        assert_eq!(sink.stats.count(Counter::DelayTouched), 2);
    }

    #[test]
    fn maintained_solver_matches_plain_under_drift() {
        use crate::opt::{solve_integer, solve_integer_maintained, SolveOptions};
        let (mut topo, mut ch) = world(11);
        let edge_of: Vec<Option<usize>> = (0..18).map(|i| Some(i % 3)).collect();
        let mut m = MaintainedInstance::build(&topo, &ch, &edge_of, 0.25);
        let opts = SolveOptions::default();
        let mut prev = None;
        for step in 0..6usize {
            let n = step * 3 % 18;
            topo.ues[n].pos = Position {
                x: 30.0 * (step as f64 + 1.0),
                y: 250.0,
            };
            ch.recompute_ue(&topo.params, &topo.ues[n], &topo.edges);
            m.sync(&topo, &ch, &edge_of);
            let reference = solve_integer(&rebuild(&topo, &ch, &edge_of, 0.25), &opts);
            let warm = solve_integer_maintained(&mut m, &opts, prev);
            assert_eq!((warm.a, warm.b), (reference.a, reference.b), "step {step}");
            assert_eq!(warm.objective.to_bits(), reference.objective.to_bits());
            prev = Some((warm.a, warm.b));
        }
    }
}
