//! Seeded property-test harness (offline substitute for `proptest`).
//!
//! A property is a closure over a [`Rng`]; the harness runs it across many
//! derived seeds and, on failure, reports the failing seed so the case can
//! be replayed deterministically (`HFL_PROP_SEED=<seed> cargo test ...`).
//! No shrinking — instances are kept small enough to debug directly.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` across `cases` random instances. Panics (with the failing
/// seed) on the first violation so `cargo test` reports it.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut prop: F) {
    // Replay a single seed if requested.
    if let Ok(seed_str) = std::env::var("HFL_PROP_SEED") {
        if let Ok(seed) = seed_str.parse::<u64>() {
            // hfl-lint: allow(R4, replay of an explicitly requested failing seed)
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
    }
    let base = 0xD1B5_4A32_D192_ED03u64 ^ fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // hfl-lint: allow(R4, per-case seed is a pure function of the property name and index)
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {case} (replay with HFL_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// `check` with the default case count.
pub fn check_default<F: FnMut(&mut Rng)>(name: &str, prop: F) {
    check(name, DEFAULT_CASES, prop)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("unit interval", 64, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failing_seed() {
        check("always fails", 8, |_rng| {
            panic!("boom");
        });
    }
}
