//! Minimal TOML-subset parser for scenario config files.
//!
//! Supports exactly what `config/*.toml` needs: `[section]` headers,
//! `key = value` with string / integer / float / bool / homogeneous array
//! values, `#` comments, and blank lines. Nested tables, dates and inline
//! tables are intentionally out of scope.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section name -> key -> value. Top-level keys live in
/// the "" section.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let lineno = idx + 1;
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key).and_then(|v| v.as_f64())
    }

    pub fn i64(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key).and_then(|v| v.as_i64())
    }

    pub fn str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(|v| v.as_str())
    }

    pub fn bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(|v| v.as_bool())
    }
}

fn err(line: usize, msg: &str) -> TomlError {
    TomlError {
        line,
        msg: msg.to_string(),
    }
}

/// Remove a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<TomlValue, TomlError> {
    if text.is_empty() {
        return Err(err(line, "empty value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    let clean = text.replace('_', "");
    if !clean.contains(['.', 'e', 'E']) {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    clean
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| err(line, &format!("cannot parse value '{text}'")))
}

/// Split an array body on commas (no nested arrays needed, but strings may
/// contain commas).
fn split_top_level(text: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in text.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&text[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&text[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = TomlDoc::parse(
            r#"
# scenario
title = "fig5"
[network]
num_ues = 100         # total UEs
area_m = 500.0
bandwidth_hz = 20e6
ofdma = true
eps_sweep = [0.05, 0.1, 0.25]
names = ["a", "b"]
"#,
        )
        .unwrap();
        assert_eq!(doc.str("", "title"), Some("fig5"));
        assert_eq!(doc.i64("network", "num_ues"), Some(100));
        assert_eq!(doc.f64("network", "area_m"), Some(500.0));
        assert_eq!(doc.f64("network", "bandwidth_hz"), Some(2.0e7));
        assert_eq!(doc.bool("network", "ofdma"), Some(true));
        let arr = doc.get("network", "eps_sweep").unwrap();
        match arr {
            TomlValue::Arr(items) => assert_eq!(items.len(), 3),
            _ => panic!("not an array"),
        }
    }

    #[test]
    fn underscores_in_numbers() {
        let doc = TomlDoc::parse("n = 1_000_000").unwrap();
        assert_eq!(doc.i64("", "n"), Some(1_000_000));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.str("", "k"), Some("a#b"));
    }
}
