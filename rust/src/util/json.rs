//! Minimal JSON parser + emitter (offline substitute for `serde_json`).
//!
//! Used to read `artifacts/meta.json` (written by the python AOT path) and
//! to emit machine-readable experiment/bench reports. Supports the full
//! JSON value grammar, including `\u` surrogate pairs beyond the BMP;
//! lone surrogates are a parse error (they have no UTF-8 encoding, so
//! accepting them would break Display round-trips).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let code = match self.hex_quad()? {
                            // High surrogate: must be immediately followed by a
                            // `\uXXXX` low surrogate; combine into one scalar.
                            hi @ 0xD800..=0xDBFF => {
                                if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                match self.hex_quad()? {
                                    lo @ 0xDC00..=0xDFFF => {
                                        0x1_0000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                    }
                                    _ => return Err(self.err("invalid low surrogate")),
                                }
                            }
                            0xDC00..=0xDFFF => return Err(self.err("lone low surrogate")),
                            code => code,
                        };
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    /// Four hex digits of a `\u` escape (the leading `\u` already consumed).
    fn hex_quad(&mut self) -> Result<u32, JsonError> {
        let mut code = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            code = code * 16
                + (d as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("bad hex digit"))?;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"hfl","nums":[1,2.5,-3],"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }

    #[test]
    fn surrogate_pair_decodes_non_bmp() {
        // U+1F600 GRINNING FACE as an escaped surrogate pair.
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
        // Mixed escaped + literal content around the pair.
        assert_eq!(
            Json::parse("\"a\\uD83D\\uDE00b\"").unwrap(),
            Json::Str("a😀b".into())
        );
        // U+10000, the first supplementary-plane scalar (boundary case).
        assert_eq!(
            Json::parse("\"\\ud800\\udc00\"").unwrap(),
            Json::Str("\u{10000}".into())
        );
    }

    #[test]
    fn lone_surrogates_are_errors() {
        // Lone high surrogate at end of string.
        assert!(Json::parse("\"\\ud83d\"").is_err());
        // High surrogate followed by a non-escape character.
        assert!(Json::parse("\"\\ud83dx\"").is_err());
        // High surrogate followed by a non-\u escape.
        assert!(Json::parse("\"\\ud83d\\n\"").is_err());
        // High surrogate followed by another high surrogate.
        assert!(Json::parse("\"\\ud83d\\ud83d\"").is_err());
        // Lone low surrogate.
        assert!(Json::parse("\"\\ude00\"").is_err());
    }

    #[test]
    fn non_bmp_roundtrip_through_display() {
        // Parsed escape form and raw UTF-8 form both emit raw UTF-8 and
        // re-parse to the same value.
        let escaped = Json::parse("\"\\ud83d\\ude00 done\"").unwrap();
        let raw = Json::parse("\"😀 done\"").unwrap();
        assert_eq!(escaped, raw);
        let emitted = escaped.to_string();
        assert_eq!(emitted, "\"😀 done\"");
        assert_eq!(Json::parse(&emitted).unwrap(), escaped);
    }
}
