//! Shared utilities: PRNG, JSON/TOML codecs, statistics, bench and
//! property-test harnesses. These are the in-repo substitutes for the
//! crates.io dependencies a networked build would use (see Cargo.toml).

pub mod bench;
pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod toml;

pub use par::ShardPool;
pub use rng::Rng;
