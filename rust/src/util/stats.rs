//! Small statistics helpers shared by metrics, benches and tests.

/// Streaming mean/variance (Welford) with min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }
}
