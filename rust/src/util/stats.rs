//! Small statistics helpers shared by metrics, benches and tests.

/// Streaming mean/variance (Welford) with min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (linear interpolation); `q` is clamped to
/// [0, 100] (out-of-range ranks used to index out of bounds). Input may
/// be unsorted; NaN samples sort last (total_cmp) instead of panicking.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let q = q.clamp(0.0, 100.0);
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_clamps_out_of_range_q() {
        // q beyond [0, 100] used to compute an out-of-bounds rank.
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, -5.0), 1.0);
        assert_eq!(percentile(&xs, 150.0), 3.0);
    }

    #[test]
    fn percentile_single_sample_and_unsorted() {
        assert_eq!(percentile(&[5.0], 0.0), 5.0);
        assert_eq!(percentile(&[5.0], 73.0), 5.0);
        assert_eq!(percentile(&[5.0], 100.0), 5.0);
        // Unsorted input is sorted internally.
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 50.0), 5.0);
        assert!((percentile(&[40.0, 10.0, 20.0, 30.0], 25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_nan_sorts_last_without_panicking() {
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn online_stats_small_n() {
        let mut s = OnlineStats::new();
        // n = 0: no spread, no samples.
        assert_eq!(s.count(), 0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.std(), 0.0);
        // n = 1: mean is the sample, variance still undefined → 0.
        s.push(4.5);
        assert_eq!(s.count(), 1);
        assert_eq!(s.mean(), 4.5);
        assert_eq!(s.variance(), 0.0);
        assert_eq!((s.min(), s.max()), (4.5, 4.5));
        // n = 2: Bessel-corrected variance kicks in.
        s.push(6.5);
        assert!((s.variance() - 2.0).abs() < 1e-12);
        assert_eq!(std(&[4.5]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
