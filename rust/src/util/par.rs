//! Deterministic fork/join executor for intra-instance data parallelism.
//!
//! The maintained engines (`assoc::MaintainedAssociation`,
//! `delay::MaintainedInstance`) partition their per-UE state into UE-id
//! **range shards** and run each epoch's maintenance shard-parallel. The
//! contract mirrors the batch runner's shard-count independence, one level
//! down: results must be **bitwise-identical for any thread count**. The
//! executor guarantees the structural half of that contract —
//!
//! * work items are mapped by a pure function of the item (workers share
//!   no mutable state), and
//! * results are returned **in input order**, regardless of which worker
//!   ran which item or in what order they finished —
//!
//! so any reduction the caller folds over the returned Vec is a fixed
//! shard-order reduction. The callers supply the other half: per-shard
//! outputs that depend only on that shard's inputs (disjoint `chunks_mut`
//! slices, per-shard counters summed in shard order).
//!
//! No work stealing and no channels: items are assigned round-robin to at
//! most `threads` scoped workers, each returns its `(index, result)` pairs
//! on join, and the pairs are slotted back by index. With `threads <= 1`
//! (or a single item) the map runs inline on the caller's stack — the
//! serial path *is* the parallel path with one worker, not separate code.

use std::thread;

/// A fixed-width pool descriptor. Copy-cheap (just the resolved thread
/// count); the OS threads are scoped to each [`ShardPool::map`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPool {
    threads: usize,
}

impl ShardPool {
    /// `requested == 0` resolves to the machine's available parallelism
    /// (same convention as the batch runner's `shards = 0`). The resolved
    /// count is only a *speed* knob: outputs are bitwise-identical for
    /// every value, so auto-resolution does not hurt reproducibility.
    pub fn new(requested: usize) -> Self {
        let threads = if requested == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            requested
        };
        ShardPool { threads: threads.max(1) }
    }

    /// A pool that always runs inline.
    pub fn serial() -> Self {
        ShardPool { threads: 1 }
    }

    /// Resolved worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Width of a UE-id range shard for `n` items: `ceil(n / threads)`,
    /// at least 1. Shard `s` owns ids `[s * width, (s + 1) * width)`; the
    /// shard of id `i` is `i / width`. Range sharding (not modulo) keeps
    /// each shard's ids contiguous, so per-shard outputs concatenated in
    /// shard order are already in global id order — the property the
    /// deterministic reductions lean on.
    pub fn shard_width(&self, n: usize) -> usize {
        n.div_ceil(self.threads).max(1)
    }

    /// Map `f` over owned work items on up to `threads()` scoped workers;
    /// results come back **in input order**. `f` receives `(index, item)`.
    ///
    /// Items may carry `&mut` slices (e.g. disjoint `chunks_mut` views of
    /// a flat array) — ownership moves into exactly one worker, so the
    /// borrows stay exclusive. A panic in any worker propagates.
    pub fn map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(usize, I) -> T + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
        }
        let workers = self.threads.min(n);
        let mut buckets: Vec<Vec<(usize, I)>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, x) in items.into_iter().enumerate() {
            buckets[i % workers].push((i, x));
        }
        let f = &f;
        let done: Vec<Vec<(usize, T)>> = thread::scope(|scope| {
            let handles: Vec<_> = buckets
                .into_iter()
                .map(|bucket| {
                    scope.spawn(move || {
                        bucket
                            .into_iter()
                            .map(|(i, x)| (i, f(i, x)))
                            .collect::<Vec<(usize, T)>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for pairs in done {
            for (i, t) in pairs {
                debug_assert!(slots[i].is_none());
                slots[i] = Some(t);
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("every work item produces a result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_resolves_to_at_least_one() {
        assert!(ShardPool::new(0).threads() >= 1);
        assert_eq!(ShardPool::new(3).threads(), 3);
        assert_eq!(ShardPool::serial().threads(), 1);
    }

    #[test]
    fn shard_width_covers_all_ids() {
        for threads in 1..=9usize {
            let pool = ShardPool::new(threads);
            for n in [0usize, 1, 7, 64, 1000] {
                let w = pool.shard_width(n);
                assert!(w >= 1);
                // Every id lands in a shard index < threads.
                for i in 0..n {
                    assert!(i / w < threads, "n={n} threads={threads} id={i}");
                }
            }
        }
    }

    #[test]
    fn map_returns_results_in_input_order_for_any_thread_count() {
        let serial: Vec<u64> = (0..97u64).map(|x| x * x + 1).collect();
        for threads in [1usize, 2, 3, 8, 16] {
            let pool = ShardPool::new(threads);
            let got = pool.map((0..97u64).collect(), |i, x| {
                assert_eq!(i as u64, x);
                x * x + 1
            });
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn map_supports_disjoint_mutable_chunks() {
        // The engines' idiom: chunk a flat array by shard width, ship each
        // chunk to a worker, fold per-shard counters in shard order.
        let mut data = vec![0u32; 1000];
        let pool = ShardPool::new(4);
        let width = pool.shard_width(data.len());
        let chunks: Vec<&mut [u32]> = data.chunks_mut(width).collect();
        let counts = pool.map(chunks, |s, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (s * width + j) as u32;
            }
            chunk.len() as u64
        });
        assert_eq!(counts.iter().sum::<u64>(), 1000);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let pool = ShardPool::new(8);
        let empty: Vec<u8> = pool.map(Vec::<u8>::new(), |_, x| x);
        assert!(empty.is_empty());
        assert_eq!(pool.map(vec![5u8], |i, x| x + i as u8), vec![5]);
    }
}
