//! Seeded PRNG + distributions (offline substitute for the `rand` crate).
//!
//! The generator is xoshiro256++ (Blackman & Vigna), seeded through
//! SplitMix64 so any `u64` seed yields a well-mixed state. Every stochastic
//! component in the library (topology sampling, data partitioning, the
//! random-association baseline, property tests) takes an explicit seed so
//! all experiments are reproducible bit-for-bit.

/// xoshiro256++ pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-entity streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased).
    ///
    /// Panics on `n == 0` in every build profile: an empty range has no
    /// uniform sample, and the release-mode fallback of "return 0" would
    /// silently hand callers an index into nothing.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0): cannot sample an empty range");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Poisson with mean `lambda` (used by the scenario engine's churn
    /// model). Knuth's product method for small means; for large means a
    /// rounded-normal approximation keeps the cost O(1).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            return self.normal_ms(lambda, lambda.sqrt()).round().max(0.0) as u64;
        }
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0f64;
        loop {
            p *= self.f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (used by the Dirichlet
    /// non-IID partitioner).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Dirichlet sample with symmetric concentration `alpha`, length `k`.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Pick one element uniformly. Panics with an explicit message on an
    /// empty slice (previously an opaque index-out-of-bounds via `below`).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "Rng::choose: cannot pick from an empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical loop; too slow under the interpreter
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical loop; too slow under the interpreter
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical loop; too slow under the interpreter
    fn poisson_mean_close_and_degenerate_cases() {
        let mut r = Rng::new(17);
        for &lambda in &[0.3, 2.0, 8.0, 50.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda)).sum::<u64>() as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda}: mean {mean}"
            );
        }
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-1.0), 0);
    }

    #[test]
    #[should_panic(expected = "Rng::below(0)")]
    fn below_zero_panics_explicitly() {
        // Must panic in release builds too (a plain assert!, not a
        // debug_assert!): cfg(test) binaries honor the profile's
        // debug-assertions flag, so this test pins the message either way.
        Rng::new(1).below(0);
    }

    #[test]
    #[should_panic(expected = "Rng::choose")]
    fn choose_empty_panics_explicitly() {
        let empty: [u8; 0] = [];
        Rng::new(1).choose(&empty);
    }

    /// Knuth's product method, transcribed independently of `poisson` so
    /// the branch-boundary tests below detect any drift in either arm.
    fn knuth_reference(rng: &mut Rng, lambda: f64) -> u64 {
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0f64;
        loop {
            p *= rng.f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }

    #[test]
    fn poisson_branch_boundary_is_pinned_at_lambda_30() {
        // λ = 30 exactly must take the Knuth arm (the switch is a strict
        // `> 30.0`); the next representable λ above 30 must take the
        // rounded-normal arm. Pinning both sides means the approximation
        // switch cannot silently move and shift every churn stream.
        for seed in [1u64, 77, 901] {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            assert_eq!(
                a.poisson(30.0),
                knuth_reference(&mut b, 30.0),
                "lambda=30.0 must use Knuth's method (seed {seed})"
            );
            // Same draw count consumed -> streams stay aligned afterwards.
            assert_eq!(a.next_u64(), b.next_u64(), "stream alignment after Knuth arm");

            let above = f64::from_bits(30.0f64.to_bits() + 1);
            let mut c = Rng::new(seed);
            let mut d = Rng::new(seed);
            let expect = d.normal_ms(above, above.sqrt()).round().max(0.0) as u64;
            assert_eq!(
                c.poisson(above),
                expect,
                "lambda just above 30 must use the rounded-normal arm (seed {seed})"
            );
            assert_eq!(c.next_u64(), d.next_u64(), "stream alignment after normal arm");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical loop; too slow under the interpreter
    fn gamma_positive_and_mean_close() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let shape = 2.5;
        let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
        assert!((mean - shape).abs() < 0.1, "mean {mean}");
    }
}
