//! Zero-cost epoch telemetry for the scenario engine and warm engines.
//!
//! The scenario hot loop ([`crate::scenario::dynamics`]) and the two
//! incremental engines ([`crate::assoc::MaintainedAssociation`],
//! [`crate::delay::MaintainedInstance`]) emit *spans* (per-epoch,
//! per-phase wall time) and *counters* (dirty-set sizes, fast-path hits,
//! frontier rebuilds, ...) through a non-generic `&mut dyn TraceSink`
//! handle. Three sinks are provided:
//!
//! * [`NullSink`] — `enabled() == false`; every emission site checks
//!   `enabled()` first (via [`Tee`]), so a disabled sink receives **zero**
//!   calls and the hot loop does no formatting or allocation for it.
//! * [`JsonlSink`] — buffers one JSON object per line in memory; the
//!   *content* (event kinds, epochs, phases, counters, simulated clocks)
//!   is seed-deterministic, only the `wall_s` fields are measured. Use
//!   [`strip_walls`] to compare traces across runs.
//! * [`StatsSink`] — in-memory aggregation into [`PhaseStats`].
//!
//! Determinism rules: counters and event ordering are part of the
//! deterministic trajectory (warm == cold bookkeeping is *not* implied —
//! warm and cold paths legitimately count different work — but the same
//! seed + spec always yields the same counters). Wall-clock spans are
//! measured and therefore excluded from any bitwise contract, exactly
//! like `resolve_time_s`/`assoc_time_s` in `ScenarioOutcome` (which are
//! now *derived from* these spans — one timing source of truth).

use crate::metrics::Series;
use crate::util::json::Json;

/// A phase of one scenario epoch, in hot-loop order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Association build / dirty-set maintenance (`assoc/incremental.rs`).
    Assoc,
    /// Delay-instance build / `sync_delta` maintenance (`delay/incremental.rs`).
    Delay,
    /// The (a, b) re-solve (warm-started or cold).
    Resolve,
    /// Event-driven round simulation (`sim/events.rs`).
    Sim,
    /// Random-waypoint mobility step + channel recompute.
    Mobility,
    /// Poisson arrivals / departures.
    Churn,
    /// Edge failure / recovery process.
    Outage,
}

/// Number of [`Phase`] variants (array sizing).
pub const NUM_PHASES: usize = 7;

impl Phase {
    /// All phases, in hot-loop order (also the report column order).
    pub const ALL: [Phase; NUM_PHASES] = [
        Phase::Assoc,
        Phase::Delay,
        Phase::Resolve,
        Phase::Sim,
        Phase::Mobility,
        Phase::Churn,
        Phase::Outage,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Assoc => "assoc",
            Phase::Delay => "delay",
            Phase::Resolve => "resolve",
            Phase::Sim => "sim",
            Phase::Mobility => "mobility",
            Phase::Churn => "churn",
            Phase::Outage => "outage",
        }
    }

    /// Report / CSV column name (`phase_<name>_s`).
    pub fn col(&self) -> &'static str {
        match self {
            Phase::Assoc => "phase_assoc_s",
            Phase::Delay => "phase_delay_s",
            Phase::Resolve => "phase_resolve_s",
            Phase::Sim => "phase_sim_s",
            Phase::Mobility => "phase_mobility_s",
            Phase::Churn => "phase_churn_s",
            Phase::Outage => "phase_outage_s",
        }
    }

    pub fn idx(&self) -> usize {
        *self as usize
    }

    pub fn from_name(name: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.name() == name)
    }
}

/// A deterministic engine counter. Values are *part of the trajectory*:
/// same seed + spec ⇒ same counts, independent of tracing or shard count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// UEs in the association dirty set when `reassign` ran.
    AssocDirty,
    /// Proposed-strategy argmax fast path taken (per reassign).
    AssocFastPath,
    /// Proposed-strategy merge-sweep fallback / cold full assignment.
    AssocMergeSweep,
    /// UEs re-scored because a hysteresis threshold tripped.
    AssocRescored,
    /// Outage-mask retarget passes (rows pointing at downed edges).
    AssocMaskRetargets,
    /// UEs re-synced into the maintained delay instance.
    DelayTouched,
    /// Per-edge Pareto frontiers rebuilt during solver refresh.
    FrontierRebuilds,
    /// Warm-seeded (a, b) re-solves.
    WarmResolves,
    /// Cold (from-scratch) (a, b) resolves.
    ColdResolves,
    /// Simulated FL rounds executed.
    SimRounds,
    /// Discrete events processed by the round simulator.
    SimEvents,
    /// UEs moved by the mobility step.
    MovedUes,
}

/// Number of [`Counter`] variants (array sizing).
pub const NUM_COUNTERS: usize = 12;

impl Counter {
    pub const ALL: [Counter; NUM_COUNTERS] = [
        Counter::AssocDirty,
        Counter::AssocFastPath,
        Counter::AssocMergeSweep,
        Counter::AssocRescored,
        Counter::AssocMaskRetargets,
        Counter::DelayTouched,
        Counter::FrontierRebuilds,
        Counter::WarmResolves,
        Counter::ColdResolves,
        Counter::SimRounds,
        Counter::SimEvents,
        Counter::MovedUes,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Counter::AssocDirty => "assoc_dirty",
            Counter::AssocFastPath => "assoc_fast_path",
            Counter::AssocMergeSweep => "assoc_merge_sweep",
            Counter::AssocRescored => "assoc_rescored",
            Counter::AssocMaskRetargets => "assoc_mask_retargets",
            Counter::DelayTouched => "delay_touched",
            Counter::FrontierRebuilds => "frontier_rebuilds",
            Counter::WarmResolves => "warm_resolves",
            Counter::ColdResolves => "cold_resolves",
            Counter::SimRounds => "sim_rounds",
            Counter::SimEvents => "sim_events",
            Counter::MovedUes => "moved_ues",
        }
    }

    /// Report / CSV column name (`ctr_<name>`).
    pub fn col(&self) -> &'static str {
        match self {
            Counter::AssocDirty => "ctr_assoc_dirty",
            Counter::AssocFastPath => "ctr_assoc_fast_path",
            Counter::AssocMergeSweep => "ctr_assoc_merge_sweep",
            Counter::AssocRescored => "ctr_assoc_rescored",
            Counter::AssocMaskRetargets => "ctr_assoc_mask_retargets",
            Counter::DelayTouched => "ctr_delay_touched",
            Counter::FrontierRebuilds => "ctr_frontier_rebuilds",
            Counter::WarmResolves => "ctr_warm_resolves",
            Counter::ColdResolves => "ctr_cold_resolves",
            Counter::SimRounds => "ctr_sim_rounds",
            Counter::SimEvents => "ctr_sim_events",
            Counter::MovedUes => "ctr_moved_ues",
        }
    }

    pub fn idx(&self) -> usize {
        *self as usize
    }

    pub fn from_name(name: &str) -> Option<Counter> {
        Counter::ALL.iter().copied().find(|c| c.name() == name)
    }
}

/// Aggregated per-phase wall time + counter totals for one instance.
///
/// `wall_s` entries are measured (excluded from bitwise contracts);
/// `counters` entries are deterministic.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseStats {
    pub wall_s: [f64; NUM_PHASES],
    pub counters: [u64; NUM_COUNTERS],
}

impl PhaseStats {
    pub fn wall(&self, p: Phase) -> f64 {
        self.wall_s[p.idx()]
    }

    pub fn count(&self, c: Counter) -> u64 {
        self.counters[c.idx()]
    }

    pub fn add_span(&mut self, p: Phase, wall_s: f64) {
        self.wall_s[p.idx()] += wall_s;
    }

    pub fn add_count(&mut self, c: Counter, v: u64) {
        self.counters[c.idx()] += v;
    }

    /// Total traced wall time across all phases.
    pub fn total_wall_s(&self) -> f64 {
        self.wall_s.iter().sum()
    }
}

/// Receiver for trace events. All methods default to no-ops; emission
/// sites (via [`Tee`]) skip calls entirely when `enabled()` is false,
/// so an inert sink costs one virtual bool check per span — nothing in
/// the per-UE inner loops.
pub trait TraceSink {
    /// Whether this sink wants events at all.
    fn enabled(&self) -> bool {
        false
    }

    /// Instance header: scenario RNG seed (emitted once, before epoch 0).
    fn instance(&mut self, _seed: u64) {}

    /// An epoch begins at simulated clock `clock_s`.
    fn begin_epoch(&mut self, _epoch: u64, _clock_s: f64) {}

    /// A deterministic engine counter increment (attributed to the
    /// next `span` by [`JsonlSink`]).
    fn counter(&mut self, _c: Counter, _v: u64) {}

    /// A phase of `epoch` took `wall_s` seconds of measured wall time.
    fn span(&mut self, _epoch: u64, _phase: Phase, _wall_s: f64) {}

    /// Per-round simulated completion clocks for `epoch` (deterministic).
    fn rounds(&mut self, _epoch: u64, _end_s: &[f64]) {}

    /// An epoch finished executing: the `(a, b)` it ran with, the
    /// simulated clock after its rounds (the running makespan), and its
    /// upload participation share. Everything here is deterministic —
    /// this is the per-epoch summary the serve path streams to clients.
    fn epoch_end(
        &mut self,
        _epoch: u64,
        _a: u64,
        _b: u64,
        _clock_s: f64,
        _participation: f64,
    ) {
    }
}

/// The disabled sink: `enabled() == false`, every method a no-op.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {}

/// In-memory aggregating sink: sums spans/counters into [`PhaseStats`].
#[derive(Debug, Clone, Default)]
pub struct StatsSink {
    pub stats: PhaseStats,
    /// Epochs begun (≥ completed epochs; the final partial epoch counts).
    pub epochs: u64,
}

impl TraceSink for StatsSink {
    fn enabled(&self) -> bool {
        true
    }

    fn begin_epoch(&mut self, _epoch: u64, _clock_s: f64) {
        self.epochs += 1;
    }

    fn counter(&mut self, c: Counter, v: u64) {
        self.stats.add_count(c, v);
    }

    fn span(&mut self, _epoch: u64, phase: Phase, wall_s: f64) {
        self.stats.add_span(phase, wall_s);
    }
}

/// Buffers a JSONL event stream in memory (one JSON object per line).
///
/// Counters emitted between spans are attached to the *next* span record
/// as flat fields, so one line carries a phase's wall time and the work
/// it did. Everything except `wall_s` is seed-deterministic.
#[derive(Debug, Clone, Default)]
pub struct JsonlSink {
    out: String,
    pending: Vec<(Counter, u64)>,
}

impl JsonlSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// A sink whose first line is an instance header carrying the batch
    /// slot index (the seed follows via [`TraceSink::instance`]).
    pub fn for_instance(instance: usize) -> Self {
        let mut s = Self::default();
        s.out.push_str(&format!(
            "{{\"ev\":\"begin\",\"instance\":{instance}}}\n"
        ));
        s
    }

    pub fn as_str(&self) -> &str {
        &self.out
    }

    pub fn into_string(self) -> String {
        self.out
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }
}

impl TraceSink for JsonlSink {
    fn enabled(&self) -> bool {
        true
    }

    fn instance(&mut self, seed: u64) {
        self.out.push_str(&format!("{{\"ev\":\"instance\",\"seed\":{seed}}}\n"));
    }

    fn begin_epoch(&mut self, epoch: u64, clock_s: f64) {
        self.out.push_str(&format!(
            "{{\"ev\":\"epoch\",\"epoch\":{epoch},\"clock_s\":{}}}\n",
            fmt_f64(clock_s)
        ));
    }

    fn counter(&mut self, c: Counter, v: u64) {
        // Merge repeats of the same counter within a phase.
        if let Some(slot) = self.pending.iter_mut().find(|(pc, _)| *pc == c) {
            slot.1 += v;
        } else {
            self.pending.push((c, v));
        }
    }

    fn span(&mut self, epoch: u64, phase: Phase, wall_s: f64) {
        self.out.push_str(&format!(
            "{{\"ev\":\"span\",\"epoch\":{epoch},\"phase\":\"{}\",\"wall_s\":{}",
            phase.name(),
            fmt_f64(wall_s)
        ));
        for (c, v) in self.pending.drain(..) {
            self.out.push_str(&format!(",\"{}\":{v}", c.name()));
        }
        self.out.push_str("}\n");
    }

    fn rounds(&mut self, epoch: u64, end_s: &[f64]) {
        self.out
            .push_str(&format!("{{\"ev\":\"rounds\",\"epoch\":{epoch},\"end_s\":["));
        for (i, t) in end_s.iter().enumerate() {
            if i > 0 {
                self.out.push(',');
            }
            self.out.push_str(&fmt_f64(*t));
        }
        self.out.push_str("]}\n");
    }

    fn epoch_end(&mut self, epoch: u64, a: u64, b: u64, clock_s: f64, participation: f64) {
        self.out.push_str(&format!(
            "{{\"ev\":\"epoch_end\",\"epoch\":{epoch},\"a\":{a},\"b\":{b},\"clock_s\":{},\
             \"participation\":{}}}\n",
            fmt_f64(clock_s),
            fmt_f64(participation)
        ));
    }
}

fn fmt_f64(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Fan-out used by the hot loop: *always* accumulates into the local
/// [`PhaseStats`] (that is how `ScenarioOutcome` gets its phase
/// breakdown) and forwards to the user sink only when it is enabled —
/// so a [`NullSink`] behind a `Tee` receives zero calls.
pub struct Tee<'a> {
    pub stats: &'a mut PhaseStats,
    pub inner: &'a mut dyn TraceSink,
}

impl TraceSink for Tee<'_> {
    fn enabled(&self) -> bool {
        true
    }

    fn instance(&mut self, seed: u64) {
        if self.inner.enabled() {
            self.inner.instance(seed);
        }
    }

    fn begin_epoch(&mut self, epoch: u64, clock_s: f64) {
        if self.inner.enabled() {
            self.inner.begin_epoch(epoch, clock_s);
        }
    }

    fn counter(&mut self, c: Counter, v: u64) {
        self.stats.add_count(c, v);
        if self.inner.enabled() {
            self.inner.counter(c, v);
        }
    }

    fn span(&mut self, epoch: u64, phase: Phase, wall_s: f64) {
        self.stats.add_span(phase, wall_s);
        if self.inner.enabled() {
            self.inner.span(epoch, phase, wall_s);
        }
    }

    fn rounds(&mut self, epoch: u64, end_s: &[f64]) {
        if self.inner.enabled() {
            self.inner.rounds(epoch, end_s);
        }
    }

    fn epoch_end(&mut self, epoch: u64, a: u64, b: u64, clock_s: f64, participation: f64) {
        if self.inner.enabled() {
            self.inner.epoch_end(epoch, a, b, clock_s, participation);
        }
    }
}

/// Remove every measured `wall_s` field from a JSONL trace, returning
/// the deterministic content (canonically re-serialized). Two same-seed
/// runs must produce identical output here.
pub fn strip_walls(jsonl: &str) -> Result<String, String> {
    let mut out = String::with_capacity(jsonl.len());
    for (lineno, line) in jsonl.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let v = match v {
            Json::Obj(mut m) => {
                m.remove("wall_s");
                Json::Obj(m)
            }
            other => other,
        };
        out.push_str(&v.to_string());
        out.push('\n');
    }
    Ok(out)
}

/// Per-counter aggregate across span records (for the profile table).
#[derive(Debug, Clone, Copy, Default)]
struct CounterAgg {
    total: u64,
    max: u64,
    records: u64,
}

/// Aggregated view of a JSONL trace: time share per phase, counter
/// stats, and the top-k slowest epochs. Built by `hfl trace`.
#[derive(Debug, Clone)]
pub struct TraceProfile {
    pub instances: u64,
    pub epochs: u64,
    pub spans: u64,
    phase_wall: [f64; NUM_PHASES],
    phase_spans: [u64; NUM_PHASES],
    counters: [CounterAgg; NUM_COUNTERS],
    /// (instance, epoch, summed span wall) — all epoch records.
    epoch_walls: Vec<(u64, u64, f64)>,
}

impl TraceProfile {
    /// Parse a JSONL trace (as written by `--trace` / [`JsonlSink`]).
    pub fn parse_jsonl(text: &str) -> Result<TraceProfile, String> {
        let mut p = TraceProfile {
            instances: 0,
            epochs: 0,
            spans: 0,
            phase_wall: [0.0; NUM_PHASES],
            phase_spans: [0; NUM_PHASES],
            counters: [CounterAgg::default(); NUM_COUNTERS],
            epoch_walls: Vec::new(),
        };
        let mut cur_instance: u64 = 0;
        for (lineno, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| format!("line {}: {msg}", lineno + 1);
            let v = Json::parse(line).map_err(|e| err(&e.to_string()))?;
            let ev = v
                .get("ev")
                .and_then(|e| e.as_str())
                .ok_or_else(|| err("missing \"ev\" field"))?;
            match ev {
                "begin" => {
                    p.instances += 1;
                    cur_instance = v
                        .get("instance")
                        .and_then(|x| x.as_f64())
                        .map(|x| x as u64)
                        .unwrap_or(p.instances - 1);
                }
                "instance" => {
                    // Seed header; counted via "begin" (standalone sinks
                    // without a begin line still profile fine).
                    if p.instances == 0 {
                        p.instances = 1;
                    }
                }
                "epoch" => {
                    p.epochs += 1;
                    let epoch = v
                        .get("epoch")
                        .and_then(|x| x.as_f64())
                        .ok_or_else(|| err("epoch record without epoch number"))?
                        as u64;
                    p.epoch_walls.push((cur_instance, epoch, 0.0));
                }
                "span" => {
                    p.spans += 1;
                    let phase = v
                        .get("phase")
                        .and_then(|x| x.as_str())
                        .and_then(Phase::from_name)
                        .ok_or_else(|| err("span record without known phase"))?;
                    let wall = v.get("wall_s").and_then(|x| x.as_f64()).unwrap_or(0.0);
                    p.phase_wall[phase.idx()] += wall;
                    p.phase_spans[phase.idx()] += 1;
                    if let Some((_, _, w)) = p.epoch_walls.last_mut() {
                        *w += wall;
                    }
                    if let Json::Obj(m) = &v {
                        for (k, val) in m {
                            if let (Some(c), Some(x)) = (Counter::from_name(k), val.as_f64()) {
                                let agg = &mut p.counters[c.idx()];
                                let x = x as u64;
                                agg.total += x;
                                agg.max = agg.max.max(x);
                                agg.records += 1;
                            }
                        }
                    }
                }
                "rounds" => {}
                // Per-epoch summary (a, b, clock, participation) for the
                // streaming path; the profile draws nothing from it yet.
                "epoch_end" => {}
                other => return Err(err(&format!("unknown event kind {other:?}"))),
            }
        }
        if p.spans == 0 {
            return Err("no span records found (is this a --trace JSONL file?)".into());
        }
        Ok(p)
    }

    /// Total traced wall time across phases.
    pub fn total_wall_s(&self) -> f64 {
        self.phase_wall.iter().sum()
    }

    pub fn phase_wall(&self, p: Phase) -> f64 {
        self.phase_wall[p.idx()]
    }

    pub fn counter_total(&self, c: Counter) -> u64 {
        self.counters[c.idx()].total
    }

    /// The `k` slowest epochs by summed span wall time, descending;
    /// ties (and NaN walls, which sort first) break toward the lower
    /// (instance, epoch) pair so the ranking is a total order — the old
    /// `partial_cmp(..).unwrap_or(Equal)` comparator was *not* one under
    /// NaN (a NaN wall compared Equal to everything, so the "order" was
    /// intransitive and the sort result unspecified).
    pub fn slowest_epochs(&self, k: usize) -> Vec<(u64, u64, f64)> {
        let mut v = self.epoch_walls.clone();
        v.sort_by(|a, b| b.2.total_cmp(&a.2).then_with(|| (a.0, a.1).cmp(&(b.0, b.1))));
        v.truncate(k);
        v
    }

    /// Print the profile: phase time-share table, counter stats, and the
    /// top-k slowest epochs (all via `metrics::Series::print`).
    pub fn print(&self, topk: usize) {
        let total = self.total_wall_s();
        let head = format!(
            "trace: {} instance(s), {} epoch record(s), {} span(s), {:.3}s traced wall time",
            self.instances, self.epochs, self.spans, total
        );
        println!("{head}"); // stdout-ok: this *is* the `hfl trace` display surface

        let mut phases = Series::new(&["wall_s", "share_pct", "spans", "mean_ms"]);
        for p in Phase::ALL {
            let w = self.phase_wall[p.idx()];
            let n = self.phase_spans[p.idx()];
            let share = if total > 0.0 { 100.0 * w / total } else { 0.0 };
            let mean_ms = if n > 0 { 1e3 * w / n as f64 } else { 0.0 };
            phases.push_labeled(p.name(), vec![w, share, n as f64, mean_ms]);
        }
        phases.print("phase profile");

        let mut ctrs = Series::new(&["total", "records", "mean_per_rec", "max_per_rec"]);
        for c in Counter::ALL {
            let a = self.counters[c.idx()];
            if a.records == 0 {
                continue;
            }
            let mean = a.total as f64 / a.records as f64;
            ctrs.push_labeled(
                c.name(),
                vec![a.total as f64, a.records as f64, mean, a.max as f64],
            );
        }
        ctrs.print("engine counters");

        let slow = self.slowest_epochs(topk);
        if !slow.is_empty() {
            let mut s = Series::new(&["instance", "epoch", "wall_ms"]);
            for (inst, ep, w) in slow {
                s.push(vec![inst as f64, ep as f64, 1e3 * w]);
            }
            s.print(&format!("top {} slowest epochs", s.rows.len()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_and_counter_tables_are_consistent() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.idx(), i);
            assert_eq!(Phase::from_name(p.name()), Some(*p));
            assert!(p.col().starts_with("phase_"));
        }
        for (i, c) in Counter::ALL.iter().enumerate() {
            assert_eq!(c.idx(), i);
            assert_eq!(Counter::from_name(c.name()), Some(*c));
            assert!(c.col().starts_with("ctr_"));
        }
    }

    #[test]
    fn null_sink_is_disabled() {
        assert!(!NullSink.enabled());
    }

    #[test]
    fn tee_accumulates_and_skips_disabled_inner() {
        struct Counting {
            on: bool,
            calls: u64,
        }
        impl TraceSink for Counting {
            fn enabled(&self) -> bool {
                self.on
            }
            fn instance(&mut self, _s: u64) {
                self.calls += 1;
            }
            fn begin_epoch(&mut self, _e: u64, _c: f64) {
                self.calls += 1;
            }
            fn counter(&mut self, _c: Counter, _v: u64) {
                self.calls += 1;
            }
            fn span(&mut self, _e: u64, _p: Phase, _w: f64) {
                self.calls += 1;
            }
            fn rounds(&mut self, _e: u64, _r: &[f64]) {
                self.calls += 1;
            }
            fn epoch_end(&mut self, _e: u64, _a: u64, _b: u64, _c: f64, _p: f64) {
                self.calls += 1;
            }
        }
        for on in [false, true] {
            let mut stats = PhaseStats::default();
            let mut inner = Counting { on, calls: 0 };
            let mut tee = Tee {
                stats: &mut stats,
                inner: &mut inner,
            };
            tee.instance(7);
            tee.begin_epoch(0, 0.0);
            tee.counter(Counter::AssocDirty, 3);
            tee.span(0, Phase::Assoc, 0.5);
            tee.rounds(0, &[1.0]);
            tee.epoch_end(0, 5, 2, 1.0, 1.0);
            assert_eq!(stats.count(Counter::AssocDirty), 3);
            assert_eq!(stats.wall(Phase::Assoc), 0.5);
            assert_eq!(inner.calls, if on { 6 } else { 0 });
        }
    }

    #[test]
    fn epoch_end_lines_are_deterministic_and_parse() {
        let mut s = JsonlSink::new();
        s.begin_epoch(0, 0.0);
        s.span(0, Phase::Sim, 0.25);
        s.epoch_end(0, 5, 2, 12.5, 0.975);
        let last = s.as_str().lines().last().unwrap();
        assert_eq!(
            last,
            "{\"ev\":\"epoch_end\",\"epoch\":0,\"a\":5,\"b\":2,\"clock_s\":12.5,\
             \"participation\":0.975}"
        );
        // The profile accepts (and currently skips) the summary event.
        let p = TraceProfile::parse_jsonl(s.as_str()).unwrap();
        assert_eq!(p.epochs, 1);
        // And strip_walls passes it through untouched (nothing measured).
        assert!(strip_walls(s.as_str()).unwrap().contains("\"ev\":\"epoch_end\""));
    }

    #[test]
    fn jsonl_sink_attaches_pending_counters_to_next_span() {
        let mut s = JsonlSink::for_instance(2);
        s.instance(42);
        s.begin_epoch(0, 0.0);
        s.counter(Counter::AssocDirty, 4);
        s.counter(Counter::AssocDirty, 2); // merged
        s.counter(Counter::AssocFastPath, 1);
        s.span(0, Phase::Assoc, 1.5e-4);
        s.rounds(0, &[0.25, 0.5]);
        s.span(0, Phase::Sim, 2.0);
        let text = s.as_str();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 6);
        assert!(lines[0].contains("\"ev\":\"begin\"") && lines[0].contains("\"instance\":2"));
        assert!(lines[1].contains("\"seed\":42"));
        assert!(lines[3].contains("\"assoc_dirty\":6"));
        assert!(lines[3].contains("\"assoc_fast_path\":1"));
        assert!(lines[4].contains("\"end_s\":[0.25,0.5]"));
        // Second span carries no counters.
        assert!(!lines[5].contains("assoc_dirty"));
        // Every line parses as JSON.
        for line in &lines {
            Json::parse(line).unwrap();
        }
    }

    #[test]
    fn strip_walls_removes_only_wall_fields() {
        let mut a = JsonlSink::new();
        a.span(0, Phase::Assoc, 0.123);
        let mut b = JsonlSink::new();
        b.span(0, Phase::Assoc, 0.456);
        assert_ne!(a.as_str(), b.as_str());
        assert_eq!(strip_walls(a.as_str()).unwrap(), strip_walls(b.as_str()).unwrap());
        assert!(strip_walls(a.as_str()).unwrap().contains("\"phase\":\"assoc\""));
    }

    #[test]
    fn profile_aggregates_spans_and_counters() {
        let mut s = JsonlSink::for_instance(0);
        s.instance(7);
        s.begin_epoch(0, 0.0);
        s.counter(Counter::AssocDirty, 5);
        s.span(0, Phase::Assoc, 0.25);
        s.span(0, Phase::Sim, 0.75);
        s.begin_epoch(1, 10.0);
        s.counter(Counter::AssocDirty, 3);
        s.span(1, Phase::Assoc, 1.0);
        let p = TraceProfile::parse_jsonl(s.as_str()).unwrap();
        assert_eq!(p.instances, 1);
        assert_eq!(p.epochs, 2);
        assert_eq!(p.spans, 3);
        assert!((p.phase_wall(Phase::Assoc) - 1.25).abs() < 1e-12);
        assert!((p.total_wall_s() - 2.0).abs() < 1e-12);
        assert_eq!(p.counter_total(Counter::AssocDirty), 8);
        let slow = p.slowest_epochs(1);
        assert_eq!(slow.len(), 1);
        assert_eq!((slow[0].0, slow[0].1), (0, 1));
        assert!((slow[0].2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn profile_rejects_garbage() {
        assert!(TraceProfile::parse_jsonl("not json\n").is_err());
        assert!(TraceProfile::parse_jsonl("{\"ev\":\"mystery\"}\n").is_err());
        assert!(TraceProfile::parse_jsonl("").is_err());
    }

    /// Regression: `slowest_epochs` must be a total order even when a
    /// wall is NaN. The old `partial_cmp(..).unwrap_or(Equal)` comparator
    /// made NaN compare Equal to everything — an intransitive "order"
    /// under which the sort result (and thus the report) was unspecified.
    #[test]
    fn slowest_epochs_totally_ordered_under_nan_and_ties() {
        let mut s = JsonlSink::for_instance(0);
        s.begin_epoch(0, 0.0);
        s.span(0, Phase::Assoc, 1.0);
        let mut p = TraceProfile::parse_jsonl(s.as_str()).unwrap();
        p.epoch_walls = vec![
            (0, 0, 1.0),
            (0, 1, f64::NAN),
            (1, 0, 3.0),
            (1, 1, 1.0), // ties with (0, 0): lower (instance, epoch) first
            (1, 2, f64::NAN),
        ];
        let ranked = p.slowest_epochs(5);
        let keys: Vec<(u64, u64)> = ranked.iter().map(|e| (e.0, e.1)).collect();
        // NaN sorts first (total_cmp: NaN > all finite), then descending
        // by wall, ties broken toward the lower (instance, epoch).
        assert_eq!(keys, vec![(0, 1), (1, 2), (1, 0), (0, 0), (1, 1)]);
        assert!(ranked[0].2.is_nan() && ranked[1].2.is_nan());
        // Truncation keeps the top of the same total order.
        assert_eq!(
            p.slowest_epochs(2).iter().map(|e| (e.0, e.1)).collect::<Vec<_>>(),
            vec![(0, 1), (1, 2)]
        );
    }
}
