//! Bounded MPMC job queue: `Mutex<VecDeque>` + `Condvar`.
//!
//! Deliberately *not* a channel: the serve path needs (a) an explicit
//! full/busy rejection instead of unbounded buffering — backpressure is
//! part of the protocol — and (b) a close-and-drain handoff so graceful
//! shutdown can send every queued job a clean `rejected` frame. A lock +
//! condvar expresses both directly.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a [`JobQueue::push`] was refused; carries the item back.
#[derive(Debug)]
pub enum PushError<T> {
    /// At capacity — the caller should surface backpressure (`busy`).
    Full(T),
    /// [`JobQueue::close`] already ran — the server is shutting down.
    Closed(T),
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer FIFO.
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    /// A queue admitting at most `capacity` queued (not yet claimed)
    /// items. `capacity == 0` means every push is `Full` — a serve
    /// configuration that only accepts work when a worker is idle is
    /// expressed at the caller, not here.
    pub fn new(capacity: usize) -> JobQueue<T> {
        JobQueue {
            state: Mutex::new(State {
                items: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue, or hand the item back with the reason.
    pub fn push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(PushError::Closed(item));
        }
        if s.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        s.items.push_back(item);
        self.ready.notify_one();
        Ok(s.items.len())
    }

    /// Enqueue ignoring the capacity bound. Only for checkpoint resume,
    /// where journaled jobs must never be dropped at startup even if
    /// there are more of them than `queue_depth`.
    pub fn restore(&self, item: T) -> Result<(), PushError<T>> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(PushError::Closed(item));
        }
        s.items.push_back(item);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until an item is available; `None` once the queue is closed
    /// (workers use this as their exit signal).
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).unwrap();
        }
    }

    /// Close the queue and hand back everything still queued, in FIFO
    /// order. Blocked `pop`s wake and return `None`; later pushes fail
    /// with [`PushError::Closed`].
    pub fn close(&self) -> Vec<T> {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        let drained: Vec<T> = s.items.drain(..).collect();
        self.ready.notify_all();
        drained
    }

    /// Queued (unclaimed) items right now.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// Whether nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_and_backpressure() {
        let q = JobQueue::new(2);
        assert_eq!(q.push(1).unwrap(), 1);
        assert_eq!(q.push(2).unwrap(), 2);
        match q.push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.push(3).unwrap(), 2);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn restore_bypasses_capacity() {
        let q = JobQueue::new(1);
        q.push(1).unwrap();
        q.restore(2).unwrap();
        q.restore(3).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_drains_and_wakes_poppers() {
        let q = Arc::new(JobQueue::new(4));
        q.push(10).unwrap();
        q.push(11).unwrap();
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                // Consume the two queued items, then block until close.
                let mut got = Vec::new();
                while let Some(x) = q.pop() {
                    got.push(x);
                }
                got
            })
        };
        // Give the waiter a chance to drain and block, then close: the
        // drained list must be empty (waiter took both) OR contain what
        // the waiter missed — between them, everything is accounted for.
        let drained = loop {
            if q.is_empty() {
                break q.close();
            }
            std::thread::yield_now();
        };
        let mut all = waiter.join().unwrap();
        all.extend(drained);
        all.sort_unstable();
        assert_eq!(all, vec![10, 11]);
        match q.push(12) {
            Err(PushError::Closed(12)) => {}
            other => panic!("expected Closed(12), got {other:?}"),
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_returns_unclaimed_items_in_order() {
        let q: JobQueue<u32> = JobQueue::new(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.close(), vec![1, 2, 3]);
    }
}
