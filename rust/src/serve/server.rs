//! The resident scenario service: listener, worker pool, job lifecycle.
//!
//! `hfl serve` binds a TCP listener and runs scenario jobs submitted as
//! newline-delimited JSON ([`super::protocol`]). Per job:
//!
//! 1. a connection handler parses the frame and resolves the spec
//!    through [`ScenarioSpec::load_layered`] — the *same* code path as
//!    `hfl scenario`, which is what makes wire jobs bitwise-identical to
//!    batch runs;
//! 2. the job enters a bounded [`JobQueue`]; a full queue is answered
//!    with an explicit `busy` frame (backpressure, never silent buffering);
//! 3. a worker claims it and runs it on the sharded deterministic runner
//!    via [`ScenarioRun::run_batch_with_sinks`], streaming per-epoch
//!    `epoch` frames through a [`WireSink`] when the client asked to
//!    stream;
//! 4. the worker emits per-instance `outcome` frames (instance order)
//!    and a final `done` frame carrying the same report JSON that
//!    `hfl scenario --report` writes.
//!
//! **Graceful shutdown**: a `shutdown` command stops accepting, closes
//! the queue (queued jobs get `rejected` frames), and drains in-flight
//! jobs to completion before [`Server::run`] returns.
//!
//! **Checkpoint/resume**: with a journal ([`super::checkpoint`]), every
//! accepted job is durable; jobs pending at startup re-run and their
//! reports land next to the journal file.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::checkpoint::Journal;
use super::protocol::{self, ClientCmd, JobRequest};
use super::queue::{JobQueue, PushError};
use crate::config::Args;
use crate::scenario::{BatchReport, ScenarioRun, ScenarioSpec};
use crate::trace::{Phase, TraceSink, NUM_PHASES};
use crate::util::toml::TomlDoc;

/// Resolved server configuration. Layering mirrors the scenario spec:
/// CLI > `HFL_*` environment > `[server]` TOML table > defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Concurrent jobs (worker threads). Each job still shards its
    /// instances per its own `batch.shards`.
    pub workers: usize,
    /// Jobs admitted beyond the ones workers are busy with; a full
    /// queue answers `busy`.
    pub queue_depth: usize,
    /// Journal path for checkpoint/resume; `None` disables durability.
    pub checkpoint: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:4710".to_string(),
            workers: 2,
            queue_depth: 8,
            checkpoint: None,
        }
    }
}

impl ServeConfig {
    /// Layer a config from an optional `[server]` TOML table, the
    /// `HFL_*` environment and the CLI (ascending precedence). The env
    /// layer is *strict*: `hfl serve` owns the whole `HFL_*` namespace
    /// it reads, so a stray scenario variable (say `HFL_SEED`) in the
    /// server's environment fails startup loudly instead of silently
    /// doing nothing — submitted jobs carry their own env layer.
    pub fn load_layered(
        doc: Option<&TomlDoc>,
        env: &Args,
        cli: &Args,
    ) -> Result<ServeConfig, String> {
        let mut cfg = ServeConfig::default();
        if let Some(doc) = doc {
            if let Some(s) = doc.str("server", "addr") {
                cfg.addr = s.to_string();
            }
            if let Some(v) = doc.i64("server", "workers") {
                cfg.workers = v as usize;
            }
            if let Some(v) = doc.i64("server", "queue_depth") {
                cfg.queue_depth = v as usize;
            }
            if let Some(s) = doc.str("server", "checkpoint") {
                cfg.checkpoint = Some(s.to_string());
            }
        }
        for layer in [env, cli] {
            if let Some(s) = layer.str("addr") {
                cfg.addr = s;
            }
            if let Some(v) = layer.get::<usize>("workers").map_err(|e| e.to_string())? {
                cfg.workers = v;
            }
            if let Some(v) = layer.get::<usize>("queue-depth").map_err(|e| e.to_string())? {
                cfg.queue_depth = v;
            }
            if let Some(s) = layer.str("checkpoint") {
                cfg.checkpoint = Some(s);
            }
        }
        env.reject_unknown()
            .map_err(|e| format!("environment overrides (HFL_*): {e}"))?;
        if cfg.workers == 0 {
            return Err("server.workers must be >= 1".to_string());
        }
        Ok(cfg)
    }

    /// Multi-line effective-config dump for `--validate-only`.
    pub fn describe(&self) -> String {
        let mut s = String::new();
        let mut line = |k: &str, v: String| s.push_str(&format!("  {k:<22} = {v}\n"));
        line("server.addr", self.addr.clone());
        line("server.workers", self.workers.to_string());
        line("server.queue_depth", self.queue_depth.to_string());
        line(
            "server.checkpoint",
            self.checkpoint.clone().unwrap_or_else(|| "off".to_string()),
        );
        s
    }
}

/// Write side of one client connection, shared between the handler and
/// the worker streaming that client's job.
type Conn = Arc<Mutex<TcpStream>>;

/// Write one frame + newline; `false` means the client is gone (the
/// caller should stop streaming — the job itself always runs to
/// completion, results are durable via the journal when configured).
fn send(conn: &Conn, line: &str) -> bool {
    let mut s = conn.lock().unwrap();
    write_frame(&mut s, line).is_ok()
}

fn write_frame<W: Write>(w: &mut W, line: &str) -> std::io::Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// An admitted job: spec already resolved, client handle attached
/// (`None` for journal-resumed jobs whose submitter is long gone).
struct Job {
    id: u64,
    spec: ScenarioSpec,
    stream: bool,
    client: Option<Conn>,
}

struct Shared {
    queue: JobQueue<Job>,
    shutdown: AtomicBool,
    next_job: AtomicU64,
    journal: Mutex<Option<Journal>>,
    checkpoint_path: Option<PathBuf>,
    queue_depth: usize,
    addr: SocketAddr,
}

impl Shared {
    fn journal_submitted(&self, id: u64, req: &JobRequest) {
        if let Some(j) = self.journal.lock().unwrap().as_mut() {
            // Best-effort: a failed journal write degrades durability,
            // never correctness of the running job.
            let _ = j.record_submitted(id, req);
        }
    }

    fn journal_done(&self, id: u64) {
        if let Some(j) = self.journal.lock().unwrap().as_mut() {
            let _ = j.record_done(id);
        }
    }
}

/// A bound-but-not-yet-running service.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    workers: usize,
    resumed: Vec<Job>,
}

impl Server {
    /// Bind the listener and, when checkpointing, replay the journal.
    pub fn bind(cfg: ServeConfig) -> Result<Server, String> {
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        let mut journal = None;
        let mut resumed = Vec::new();
        let mut next_id = 1u64;
        if let Some(p) = &cfg.checkpoint {
            let (mut j, pending, max_id) = Journal::open(Path::new(p))?;
            next_id = max_id + 1;
            for p in pending {
                match resolve_request(&p.request) {
                    Ok(spec) => resumed.push(Job {
                        id: p.id,
                        spec,
                        stream: false,
                        client: None,
                    }),
                    // A journaled request that no longer resolves (e.g.
                    // edited journal) would fail identically on every
                    // restart — retire it instead of wedging startup.
                    Err(_) => {
                        let _ = j.record_done(p.id);
                    }
                }
            }
            journal = Some(j);
        }
        let shared = Arc::new(Shared {
            queue: JobQueue::new(cfg.queue_depth),
            shutdown: AtomicBool::new(false),
            next_job: AtomicU64::new(next_id),
            journal: Mutex::new(journal),
            checkpoint_path: cfg.checkpoint.as_ref().map(PathBuf::from),
            queue_depth: cfg.queue_depth,
            addr,
        });
        Ok(Server {
            listener,
            shared,
            workers: cfg.workers,
            resumed,
        })
    }

    /// The actual bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Jobs recovered from the journal that will run at startup.
    pub fn resumed_jobs(&self) -> usize {
        self.resumed.len()
    }

    /// Serve until a `shutdown` command arrives, then drain in-flight
    /// jobs and return. Blocks the calling thread.
    pub fn run(self) -> Result<(), String> {
        let shared = self.shared;
        for job in self.resumed {
            // Capacity-exempt: journaled jobs are never dropped.
            if shared.queue.restore(job).is_err() {
                break;
            }
        }
        let workers: Vec<_> = (0..self.workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    while let Some(job) = shared.queue.pop() {
                        run_job(&shared, job);
                    }
                })
            })
            .collect();
        for stream in self.listener.incoming() {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || handle_conn(&shared, stream));
        }
        // Drain: queued jobs are handed back for clean rejection,
        // workers finish what they already claimed.
        for job in shared.queue.close() {
            if let Some(conn) = &job.client {
                send(conn, &protocol::rejected_line(job.id, "server shutting down"));
            }
            // Deliberately NOT journaled as done: with a checkpoint, a
            // queued-but-rejected job resumes on the next start.
        }
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}

/// Resolve a wire request into a spec through the exact layered path
/// batch mode uses (TOML -> env argv -> CLI argv), then reject unknown
/// CLI keys so a typo fails the submission instead of being ignored.
/// Public so `hfl submit --validate-only` runs the *same* function
/// client-side that the server will run on the real submission.
pub fn resolve_request(req: &JobRequest) -> Result<ScenarioSpec, String> {
    let env = Args::parse(req.env.iter().cloned()).map_err(|e| format!("env layer: {e}"))?;
    let cli = Args::parse(req.args.iter().cloned()).map_err(|e| format!("args layer: {e}"))?;
    let spec = ScenarioSpec::load_layered(
        req.spec_toml.as_deref().map(|t| ("submitted spec", Some(t))),
        &env,
        &cli,
    )?;
    cli.reject_unknown().map_err(|e| format!("args layer: {e}"))?;
    Ok(spec)
}

fn handle_conn(shared: &Arc<Shared>, stream: TcpStream) {
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let conn: Conn = Arc::new(Mutex::new(write_half));
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_client_line(&line) {
            Err(e) => {
                if !send(&conn, &protocol::invalid_line(&e)) {
                    break;
                }
            }
            Ok(ClientCmd::Ping) => {
                if !send(&conn, &protocol::pong_line()) {
                    break;
                }
            }
            Ok(ClientCmd::Shutdown) => {
                shared.shutdown.store(true, Ordering::SeqCst);
                send(&conn, &protocol::shutdown_ack_line());
                // Unblock the accept loop so it observes the flag.
                let _ = TcpStream::connect(shared.addr);
                break;
            }
            Ok(ClientCmd::Submit(req)) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    send(&conn, &protocol::invalid_line("server is shutting down"));
                    continue;
                }
                let spec = match resolve_request(&req) {
                    Ok(spec) => spec,
                    Err(e) => {
                        if !send(&conn, &protocol::invalid_line(&e)) {
                            break;
                        }
                        continue;
                    }
                };
                let id = shared.next_job.fetch_add(1, Ordering::SeqCst);
                let job = Job {
                    id,
                    spec,
                    stream: req.stream,
                    client: Some(Arc::clone(&conn)),
                };
                // Hold the connection write lock across admission so the
                // accepted/busy frame is on the wire before any worker
                // can interleave this job's epoch frames.
                let mut w = conn.lock().unwrap();
                let ok = match shared.queue.push(job) {
                    Ok(_) => {
                        shared.journal_submitted(id, &req);
                        write_frame(&mut *w, &protocol::accepted_line(id))
                    }
                    Err(PushError::Full(_)) => {
                        write_frame(&mut *w, &protocol::busy_line(shared.queue_depth))
                    }
                    Err(PushError::Closed(_)) => {
                        write_frame(&mut *w, &protocol::invalid_line("server is shutting down"))
                    }
                };
                if ok.is_err() {
                    break;
                }
            }
        }
    }
}

fn run_job(shared: &Shared, job: Job) {
    // hfl-lint: allow(R3, job wall-time for the done frame only; no simulated quantity derives from it)
    let t0 = std::time::Instant::now();
    let stream_conn = if job.stream { job.client.clone() } else { None };
    let result = ScenarioRun::new(&job.spec)
        .run_batch_with_sinks(|i| WireSink::new(stream_conn.clone(), job.id, i));
    match result {
        Ok((batch, _sinks)) => {
            let report = BatchReport::from_outcomes(&batch.outcomes);
            if let Some(conn) = &job.client {
                let mut live = true;
                for o in &batch.outcomes {
                    live = live && send(conn, &protocol::outcome_line(job.id, o));
                }
                if live {
                    send(
                        conn,
                        &protocol::done_line(
                            job.id,
                            report.to_json(Some(&job.spec)),
                            t0.elapsed().as_secs_f64(),
                            batch.shards,
                        ),
                    );
                }
            } else if let Some(cp) = &shared.checkpoint_path {
                // Journal-resumed job: the submitter is gone, so the
                // report lands next to the journal.
                let path = PathBuf::from(format!("{}.job{}.json", cp.display(), job.id));
                let _ = report.write(&path, Some(&job.spec));
            }
            shared.journal_done(job.id);
        }
        Err(e) => {
            if let Some(conn) = &job.client {
                send(conn, &protocol::error_line(job.id, &e));
            }
            // A job is a pure function of its layers: it would fail
            // identically on resume, so failure also retires it.
            shared.journal_done(job.id);
        }
    }
}

/// Per-instance [`TraceSink`] that forwards each epoch summary to the
/// submitting client as an `epoch` frame. Only the measured per-phase
/// walls observed *before* the epoch summary (association, delay,
/// resolve, simulate) ride along, as the `phases` object — they are
/// stripped before any determinism comparison anyway.
struct WireSink {
    conn: Option<Conn>,
    job: u64,
    instance: usize,
    walls: [f64; NUM_PHASES],
}

impl WireSink {
    fn new(conn: Option<Conn>, job: u64, instance: usize) -> WireSink {
        WireSink {
            conn,
            job,
            instance,
            walls: [0.0; NUM_PHASES],
        }
    }
}

impl TraceSink for WireSink {
    fn enabled(&self) -> bool {
        self.conn.is_some()
    }

    fn begin_epoch(&mut self, _epoch: u64, _clock_s: f64) {
        self.walls = [0.0; NUM_PHASES];
    }

    fn span(&mut self, _epoch: u64, phase: Phase, wall_s: f64) {
        self.walls[phase.idx()] += wall_s;
    }

    fn epoch_end(&mut self, epoch: u64, a: u64, b: u64, clock_s: f64, participation: f64) {
        let Some(conn) = &self.conn else { return };
        let walls: Vec<(&'static str, f64)> = Phase::ALL
            .iter()
            .map(|&p| (p.name(), self.walls[p.idx()]))
            .collect();
        let line = protocol::epoch_line(
            self.job,
            self.instance,
            epoch,
            a,
            b,
            clock_s,
            participation,
            &walls,
        );
        if !send(conn, &line) {
            // Client hung up: stop streaming, keep computing.
            self.conn = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_layers_in_precedence_order() {
        let doc = TomlDoc::parse(
            "[server]\naddr = \"0.0.0.0:9000\"\nworkers = 4\nqueue_depth = 2\ncheckpoint = \"j.jsonl\"\n",
        )
        .unwrap();
        let vars = vec![("HFL_WORKERS".to_string(), "8".to_string())];
        let env = Args::from_prefixed_vars("HFL_", vars);
        let cli = Args::parse(["--queue-depth", "5"].iter().map(|s| s.to_string())).unwrap();
        let cfg = ServeConfig::load_layered(Some(&doc), &env, &cli).unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:9000"); // TOML (no override)
        assert_eq!(cfg.workers, 8); // env beats TOML
        assert_eq!(cfg.queue_depth, 5); // CLI beats TOML
        assert_eq!(cfg.checkpoint.as_deref(), Some("j.jsonl"));
    }

    #[test]
    fn stray_env_vars_fail_startup() {
        let vars = vec![("HFL_SEED".to_string(), "7".to_string())];
        let env = Args::from_prefixed_vars("HFL_", vars);
        let cli = Args::parse(std::iter::empty()).unwrap();
        let err = ServeConfig::load_layered(None, &env, &cli).unwrap_err();
        assert!(err.contains("environment overrides"), "got '{err}'");
        assert!(err.contains("seed"), "got '{err}'");
    }

    #[test]
    fn zero_workers_rejected_and_describe_lists_fields() {
        let env = Args::parse(std::iter::empty()).unwrap();
        let cli = Args::parse(["--workers", "0"].iter().map(|s| s.to_string())).unwrap();
        assert!(ServeConfig::load_layered(None, &env, &cli).is_err());
        let d = ServeConfig::default().describe();
        let keys = ["server.addr", "server.workers", "server.queue_depth", "server.checkpoint"];
        for key in keys {
            assert!(d.contains(key), "describe missing {key}: {d}");
        }
        assert!(d.contains("127.0.0.1:4710") && d.contains("off"));
    }

    #[test]
    fn resolve_request_applies_layers_and_rejects_typos() {
        let req = JobRequest {
            spec_toml: Some("[dynamics]\nmax_epochs = 8\n[batch]\ninstances = 3\n".to_string()),
            env: vec!["--max-epochs".into(), "16".into()],
            args: vec!["--instances".into(), "7".into()],
            stream: false,
        };
        let spec = resolve_request(&req).unwrap();
        assert_eq!(spec.dynamics.max_epochs, 16, "env beats TOML");
        assert_eq!(spec.batch.instances, 7, "CLI beats TOML");

        let bad = JobRequest {
            args: vec!["--instancez".into(), "7".into()],
            ..req
        };
        let err = resolve_request(&bad).unwrap_err();
        assert!(err.contains("instancez"), "got '{err}'");
    }
}
