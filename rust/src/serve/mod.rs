//! `hfl serve` — a resident scenario service.
//!
//! Batch mode (`hfl scenario`) pays spec parsing, binary startup and
//! thread-pool spin-up per invocation; the service keeps a process
//! resident, accepts scenario jobs as newline-delimited JSON over TCP
//! and streams per-epoch results while they run. Zero dependencies:
//! `std::net` + the crate's own JSON/TOML codecs.
//!
//! * [`protocol`] — the wire frames, client and server side;
//! * [`queue`] — the bounded job queue (explicit `busy` backpressure);
//! * [`server`] — listener, worker pool, job lifecycle, streaming sinks;
//! * [`checkpoint`] — the append-only journal behind `--checkpoint`.
//!
//! The headline guarantee: a job submitted over the wire produces
//! **bitwise-identical** deterministic outcomes to `hfl scenario` run
//! in-process on the same spec layers — for any worker count and with
//! concurrent tenants — because both paths funnel into
//! [`ScenarioSpec::load_layered`](crate::scenario::ScenarioSpec::load_layered)
//! and [`ScenarioRun`](crate::scenario::ScenarioRun) on the sharded
//! deterministic runner. `tests/serve.rs` proves it end to end by
//! byte-comparing measurement-stripped reports.

pub mod checkpoint;
pub mod protocol;
pub mod queue;
pub mod server;

pub use protocol::JobRequest;
pub use queue::{JobQueue, PushError};
pub use server::{resolve_request, ServeConfig, Server};
