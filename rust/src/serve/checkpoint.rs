//! Append-only job journal behind `hfl serve --checkpoint`.
//!
//! One JSON object per line:
//!
//! ```text
//! {"op":"submitted","job":3,"spec_toml":"...","env":[...],"args":[...],"stream":false}
//! {"op":"done","job":3}
//! ```
//!
//! On startup the journal is replayed: jobs with a `submitted` record but
//! no `done` record are *pending* and get re-enqueued (their reports land
//! next to the checkpoint file, since the submitting connection is gone).
//! Because a job is a pure function of its submitted layers, re-running a
//! pending job after a crash produces the outcome the crashed run would
//! have — resume changes *when* results appear, never *what* they are.
//!
//! The journal records the raw [`JobRequest`] layers, not the resolved
//! spec, for the same reason the wire protocol does: resolution always
//! happens in one place.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use super::protocol::JobRequest;
use crate::util::json::Json;

/// A journaled job that never finished.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingJob {
    pub id: u64,
    pub request: JobRequest,
}

/// Open (append) handle on a journal file.
pub struct Journal {
    file: File,
    /// Path the journal lives at; job reports for resumed jobs are
    /// written as siblings (`<path>.job<N>.json`).
    pub path: PathBuf,
}

impl Journal {
    /// Open `path` (creating it if absent), replay it, and return the
    /// handle plus the pending jobs (ascending id) and the highest job
    /// id ever journaled (0 if none) so the server can continue the id
    /// sequence without reuse.
    pub fn open(path: &Path) -> Result<(Journal, Vec<PendingJob>, u64), String> {
        let mut submitted: BTreeMap<u64, JobRequest> = BTreeMap::new();
        let mut done: BTreeSet<u64> = BTreeSet::new();
        let mut max_id = 0u64;
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("checkpoint {}: {e}", path.display()))?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let rec = parse_record(line).map_err(|e| {
                    format!("checkpoint {} line {}: {e}", path.display(), lineno + 1)
                })?;
                match rec {
                    Record::Submitted(id, req) => {
                        max_id = max_id.max(id);
                        submitted.insert(id, req);
                    }
                    Record::Done(id) => {
                        max_id = max_id.max(id);
                        done.insert(id);
                    }
                }
            }
        }
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("checkpoint {}: {e}", path.display()))?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("checkpoint {}: {e}", path.display()))?;
        let pending = submitted
            .into_iter()
            .filter(|(id, _)| !done.contains(id))
            .map(|(id, request)| PendingJob { id, request })
            .collect();
        Ok((
            Journal {
                file,
                path: path.to_path_buf(),
            },
            pending,
            max_id,
        ))
    }

    /// Record an accepted submission. Flushes before returning so an
    /// accepted job survives a crash right after its `accepted` frame.
    pub fn record_submitted(&mut self, id: u64, req: &JobRequest) -> std::io::Result<()> {
        let argv = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::str(s)).collect());
        let mut fields = vec![("op", Json::str("submitted")), ("job", Json::num(id as f64))];
        if let Some(toml) = &req.spec_toml {
            fields.push(("spec_toml", Json::str(toml)));
        }
        fields.push(("env", argv(&req.env)));
        fields.push(("args", argv(&req.args)));
        fields.push(("stream", Json::Bool(req.stream)));
        self.append(Json::obj(fields))
    }

    /// Record completion (success *or* job-level failure — a failed job
    /// is not retried: it is a pure function of its layers and would
    /// fail identically on every resume).
    pub fn record_done(&mut self, id: u64) -> std::io::Result<()> {
        self.append(Json::obj(vec![
            ("op", Json::str("done")),
            ("job", Json::num(id as f64)),
        ]))
    }

    fn append(&mut self, rec: Json) -> std::io::Result<()> {
        self.file.write_all(rec.to_string().as_bytes())?;
        self.file.write_all(b"\n")?;
        self.file.flush()
    }
}

enum Record {
    Submitted(u64, JobRequest),
    Done(u64),
}

fn parse_record(line: &str) -> Result<Record, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    let id = v
        .get("job")
        .and_then(Json::as_f64)
        .ok_or_else(|| "record has no numeric \"job\"".to_string())? as u64;
    match v.get("op").and_then(Json::as_str) {
        Some("done") => Ok(Record::Done(id)),
        Some("submitted") => {
            let argv = |key: &str| -> Result<Vec<String>, String> {
                match v.get(key) {
                    None | Some(Json::Null) => Ok(Vec::new()),
                    Some(a) => a
                        .as_arr()
                        .ok_or_else(|| format!("\"{key}\" must be an array"))?
                        .iter()
                        .map(|x| {
                            x.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| format!("\"{key}\" must hold strings"))
                        })
                        .collect(),
                }
            };
            Ok(Record::Submitted(
                id,
                JobRequest {
                    spec_toml: v.get("spec_toml").and_then(Json::as_str).map(str::to_string),
                    env: argv("env")?,
                    args: argv("args")?,
                    stream: v.get("stream").and_then(Json::as_bool).unwrap_or(false),
                },
            ))
        }
        other => Err(format!("unknown journal op {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hfl_journal_{}_{name}", std::process::id()))
    }

    fn req(n: u64) -> JobRequest {
        JobRequest {
            spec_toml: Some(format!("[batch]\ninstances = {n}\n")),
            env: vec!["--max-epochs".into(), "2".into()],
            args: vec![],
            stream: false,
        }
    }

    #[test]
    fn replay_returns_unfinished_jobs_and_max_id() {
        let path = tmp("replay");
        let _ = std::fs::remove_file(&path);
        {
            let (mut j, pending, max_id) = Journal::open(&path).unwrap();
            assert!(pending.is_empty());
            assert_eq!(max_id, 0);
            j.record_submitted(1, &req(1)).unwrap();
            j.record_submitted(2, &req(2)).unwrap();
            j.record_done(1).unwrap();
            j.record_submitted(3, &req(3)).unwrap();
        }
        let (_j, pending, max_id) = Journal::open(&path).unwrap();
        assert_eq!(max_id, 3);
        assert_eq!(
            pending.iter().map(|p| p.id).collect::<Vec<_>>(),
            vec![2, 3],
            "job 1 is done; 2 and 3 resume in id order"
        );
        assert_eq!(pending[0].request, req(2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_journal_fails_with_line_context() {
        let path = tmp("corrupt");
        std::fs::write(&path, "{\"op\":\"done\",\"job\":1}\nnot json\n").unwrap();
        let err = Journal::open(&path).unwrap_err();
        assert!(err.contains("line 2"), "got '{err}'");
        let _ = std::fs::remove_file(&path);
    }
}
