//! Newline-delimited JSON wire protocol shared by `hfl serve` and
//! `hfl submit`.
//!
//! One JSON object per line, both directions. Client → server:
//!
//! ```text
//! {"cmd":"submit","spec_toml":"...","env":["--max-epochs","4"],
//!  "args":["--instances","8"],"stream":true}
//! {"cmd":"ping"}
//! {"cmd":"shutdown"}
//! ```
//!
//! Server → client frames all carry an `"ev"` tag: `accepted`, `busy`,
//! `invalid`, `rejected`, `pong`, `shutdown`, then per job a stream of
//! `epoch` events (when `"stream":true`), the per-instance `outcome`
//! frames in instance order, and finally `done` (or `error`).
//!
//! **Determinism.** A submission ships the *layers* of spec resolution
//! (raw TOML text + env argv + CLI argv), never a pre-resolved spec: the
//! server funnels them through the same
//! [`ScenarioSpec::load_layered`](crate::scenario::ScenarioSpec::load_layered)
//! path as `hfl scenario`, so a wire job and a batch run see
//! byte-identical specs by construction. Frames are emitted through
//! [`crate::util::json::Json`], whose `Display` is canonical (sorted
//! keys, stable float formatting), so frame bytes are comparable across
//! runs.

use crate::scenario::ScenarioOutcome;
use crate::util::json::Json;

/// A job submission as it travels over the wire: the raw layers of spec
/// resolution. The client reads the spec file; the server never touches
/// the client's filesystem.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobRequest {
    /// Inline TOML spec text (optional — pure-CLI jobs are legal).
    pub spec_toml: Option<String>,
    /// `HFL_*` environment layer, argv-style (`["--speed-mps", "12"]`).
    /// Sits between the TOML and `args`, mirroring batch-mode precedence.
    pub env: Vec<String>,
    /// CLI layer, argv-style. Highest precedence.
    pub args: Vec<String>,
    /// Stream per-epoch `epoch` events while the job runs.
    pub stream: bool,
}

/// Parsed client command.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientCmd {
    Submit(Box<JobRequest>),
    Ping,
    Shutdown,
}

/// Parse one client line into a [`ClientCmd`].
pub fn parse_client_line(line: &str) -> Result<ClientCmd, String> {
    let v = Json::parse(line).map_err(|e| format!("bad frame: {e}"))?;
    let cmd = v
        .get("cmd")
        .and_then(Json::as_str)
        .ok_or_else(|| "frame has no string \"cmd\" field".to_string())?;
    match cmd {
        "ping" => Ok(ClientCmd::Ping),
        "shutdown" => Ok(ClientCmd::Shutdown),
        "submit" => {
            let argv = |key: &str| -> Result<Vec<String>, String> {
                match v.get(key) {
                    None | Some(Json::Null) => Ok(Vec::new()),
                    Some(a) => a
                        .as_arr()
                        .ok_or_else(|| format!("\"{key}\" must be an array of strings"))?
                        .iter()
                        .map(|x| {
                            x.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| format!("\"{key}\" must be an array of strings"))
                        })
                        .collect(),
                }
            };
            Ok(ClientCmd::Submit(Box::new(JobRequest {
                spec_toml: v.get("spec_toml").and_then(Json::as_str).map(str::to_string),
                env: argv("env")?,
                args: argv("args")?,
                stream: v.get("stream").and_then(Json::as_bool).unwrap_or(true),
            })))
        }
        other => Err(format!("unknown cmd {other:?}")),
    }
}

/// Build the client `submit` line for a request (no trailing newline).
pub fn submit_line(req: &JobRequest) -> String {
    let argv = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::str(s)).collect());
    let mut fields = Vec::new();
    fields.push(("cmd", Json::str("submit")));
    if let Some(toml) = &req.spec_toml {
        fields.push(("spec_toml", Json::str(toml)));
    }
    fields.push(("env", argv(&req.env)));
    fields.push(("args", argv(&req.args)));
    fields.push(("stream", Json::Bool(req.stream)));
    Json::obj(fields).to_string()
}

/// Client `ping` line.
pub fn ping_line() -> String {
    Json::obj(vec![("cmd", Json::str("ping"))]).to_string()
}

/// Client `shutdown` line.
pub fn shutdown_cmd_line() -> String {
    Json::obj(vec![("cmd", Json::str("shutdown"))]).to_string()
}

fn ev(kind: &str, mut rest: Vec<(&str, Json)>) -> String {
    let mut fields = vec![("ev", Json::str(kind))];
    fields.append(&mut rest);
    Json::obj(fields).to_string()
}

/// Server: job admitted to the queue.
pub fn accepted_line(job: u64) -> String {
    ev("accepted", vec![("job", Json::num(job as f64))])
}

/// Server: queue full — explicit backpressure, the client must retry.
pub fn busy_line(queue_depth: usize) -> String {
    let fields = vec![("queue_depth", Json::num(queue_depth as f64)), ("retry", Json::Bool(true))];
    ev("busy", fields)
}

/// Server: submission failed spec resolution / frame parsing.
pub fn invalid_line(error: &str) -> String {
    ev("invalid", vec![("error", Json::str(error))])
}

/// Server: an accepted-but-queued job was dropped (graceful shutdown).
pub fn rejected_line(job: u64, reason: &str) -> String {
    let fields = vec![("job", Json::num(job as f64)), ("reason", Json::str(reason))];
    ev("rejected", fields)
}

/// Server: a running job failed.
pub fn error_line(job: u64, error: &str) -> String {
    let fields = vec![("job", Json::num(job as f64)), ("error", Json::str(error))];
    ev("error", fields)
}

/// Server: ping reply.
pub fn pong_line() -> String {
    ev("pong", vec![])
}

/// Server: shutdown acknowledged; in-flight jobs drain, queued jobs get
/// `rejected` frames.
pub fn shutdown_ack_line() -> String {
    ev("shutdown", vec![])
}

/// Server: one per-epoch summary, streamed while the job runs. The
/// deterministic fields mirror the `epoch_end` trace event; `phases`
/// carries the wall-clock observed so far this epoch and is *measured*
/// (stripped by [`crate::scenario::strip_measured`] before comparisons).
#[allow(clippy::too_many_arguments)]
pub fn epoch_line(
    job: u64,
    instance: usize,
    epoch: u64,
    a: u64,
    b: u64,
    clock_s: f64,
    participation: f64,
    phase_walls: &[(&'static str, f64)],
) -> String {
    let phases = Json::obj(
        phase_walls
            .iter()
            .filter(|(_, w)| *w > 0.0)
            .map(|(name, w)| (*name, Json::num(*w)))
            .collect(),
    );
    ev(
        "epoch",
        vec![
            ("job", Json::num(job as f64)),
            ("instance", Json::num(instance as f64)),
            ("epoch", Json::num(epoch as f64)),
            ("a", Json::num(a as f64)),
            ("b", Json::num(b as f64)),
            ("clock_s", Json::num(clock_s)),
            ("participation", Json::num(participation)),
            ("phases", phases),
        ],
    )
}

/// The deterministic slice of a [`ScenarioOutcome`] as JSON. Measured
/// fields (`resolve_time_s`, `assoc_time_s`, per-phase walls) are left
/// out by construction, so these frames are bitwise-comparable between a
/// wire job and an in-process batch. The seed is a string: it is a
/// full-range `u64` and must not round through `f64`.
pub fn outcome_json(o: &ScenarioOutcome) -> Json {
    Json::obj(vec![
        ("instance", Json::num(o.instance as f64)),
        ("seed", Json::str(&o.seed.to_string())),
        ("makespan_s", Json::num(o.makespan_s)),
        ("closed_form_s", Json::num(o.closed_form_s)),
        ("rounds", Json::num(o.rounds as f64)),
        ("epochs", Json::num(o.epochs as f64)),
        ("converged", Json::Bool(o.converged)),
        ("a", Json::num(o.a as f64)),
        ("b", Json::num(o.b as f64)),
        ("round_time_s", Json::num(o.round_time_s)),
        ("tau_max_s", Json::num(o.tau_max_s)),
        ("handovers", Json::num(o.handovers as f64)),
        ("arrivals", Json::num(o.arrivals as f64)),
        ("departures", Json::num(o.departures as f64)),
        ("dropped_uploads", Json::num(o.dropped_uploads as f64)),
        ("late_uploads", Json::num(o.late_uploads as f64)),
        ("scheduled_uploads", Json::num(o.scheduled_uploads as f64)),
        ("participation_rate", Json::num(o.participation_rate)),
        ("outages", Json::num(o.outages as f64)),
        ("recoveries", Json::num(o.recoveries as f64)),
        ("down_edge_epochs", Json::num(o.down_edge_epochs as f64)),
        ("events", Json::num(o.events as f64)),
        ("ue_barrier_wait_s", Json::num(o.ue_barrier_wait_s)),
        ("edge_barrier_wait_s", Json::num(o.edge_barrier_wait_s)),
        ("resolves", Json::num(o.resolves as f64)),
        ("cold_resolves", Json::num(o.cold_resolves as f64)),
        ("reassociations", Json::num(o.reassociations as f64)),
        ("assoc_lower_bound", Json::num(o.assoc_lower_bound)),
        ("assoc_gap", Json::num(o.assoc_gap)),
    ])
}

/// Server: one completed instance (instance order, after the job ran).
pub fn outcome_line(job: u64, o: &ScenarioOutcome) -> String {
    ev(
        "outcome",
        vec![
            ("job", Json::num(job as f64)),
            ("instance", Json::num(o.instance as f64)),
            ("outcome", outcome_json(o)),
        ],
    )
}

/// Server: job finished; carries the full batch report JSON (the same
/// document `hfl scenario --report` writes) plus measured job wall time.
pub fn done_line(job: u64, report: Json, wall_s: f64, shards: usize) -> String {
    ev(
        "done",
        vec![
            ("job", Json::num(job as f64)),
            ("report", report),
            ("wall_s", Json::num(wall_s)),
            ("shards", Json::num(shards as f64)),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_round_trips() {
        let req = JobRequest {
            spec_toml: Some("[batch]\ninstances = 4\n".to_string()),
            env: vec!["--max-epochs".into(), "4".into()],
            args: vec!["--instances".into(), "8".into()],
            stream: true,
        };
        let line = submit_line(&req);
        match parse_client_line(&line).unwrap() {
            ClientCmd::Submit(parsed) => assert_eq!(*parsed, req),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn submit_without_spec_defaults() {
        let line = r#"{"cmd":"submit"}"#;
        match parse_client_line(line).unwrap() {
            ClientCmd::Submit(req) => {
                assert_eq!(req.spec_toml, None);
                assert!(req.env.is_empty() && req.args.is_empty());
                assert!(req.stream, "stream defaults on");
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn control_commands_parse() {
        assert_eq!(parse_client_line(&ping_line()).unwrap(), ClientCmd::Ping);
        assert_eq!(
            parse_client_line(&shutdown_cmd_line()).unwrap(),
            ClientCmd::Shutdown
        );
    }

    #[test]
    fn bad_frames_are_rejected_with_context() {
        assert!(parse_client_line("not json").is_err());
        let err = parse_client_line(r#"{"cmd":"dance"}"#).unwrap_err();
        assert!(err.contains("dance"), "got '{err}'");
        let err = parse_client_line(r#"{"cmd":"submit","env":"oops"}"#).unwrap_err();
        assert!(err.contains("array of strings"), "got '{err}'");
        let err = parse_client_line(r#"{"x":1}"#).unwrap_err();
        assert!(err.contains("cmd"), "got '{err}'");
    }

    #[test]
    fn frames_are_single_canonical_lines() {
        for line in [
            accepted_line(3),
            busy_line(8),
            invalid_line("no"),
            rejected_line(4, "server shutting down"),
            error_line(5, "boom"),
            pong_line(),
            shutdown_ack_line(),
            epoch_line(1, 0, 2, 5, 3, 12.5, 0.975, &[("sim", 0.25), ("assoc", 0.0)]),
        ] {
            assert!(!line.contains('\n'), "frame must be one line: {line}");
            let v = Json::parse(&line).unwrap();
            assert!(v.get("ev").and_then(Json::as_str).is_some());
            // Canonical: re-serialization is a fixed point.
            assert_eq!(v.to_string(), line);
        }
        // Zero walls are dropped from the phases object.
        let e = epoch_line(1, 0, 2, 5, 3, 12.5, 0.975, &[("sim", 0.25), ("assoc", 0.0)]);
        assert!(e.contains("\"sim\"") && !e.contains("\"assoc\""));
    }

    #[test]
    fn outcome_json_has_no_measured_fields_and_exact_seed() {
        let o = ScenarioOutcome {
            seed: u64::MAX - 1,
            resolve_time_s: 1.25,
            assoc_time_s: 0.5,
            ..Default::default()
        };
        let j = outcome_json(&o);
        assert!(j.get("resolve_time_s").is_none());
        assert!(j.get("assoc_time_s").is_none());
        assert!(j.get("phases").is_none());
        assert_eq!(j.get("seed").and_then(Json::as_str), Some("18446744073709551614"));
        let line = outcome_line(7, &o);
        let stripped = crate::scenario::strip_measured(&line).unwrap();
        assert_eq!(stripped, line, "outcome frames survive strip_measured unchanged");
    }

    #[test]
    fn outcome_json_carries_certificate_fields() {
        let o = ScenarioOutcome {
            assoc_lower_bound: 0.125,
            assoc_gap: 0.0625,
            ..Default::default()
        };
        let j = outcome_json(&o);
        assert_eq!(j.get("assoc_lower_bound").and_then(Json::as_f64), Some(0.125));
        assert_eq!(j.get("assoc_gap").and_then(Json::as_f64), Some(0.0625));
        // Certificates are deterministic, not measured: they survive the
        // wire-vs-batch strip intact.
        let line = outcome_line(1, &o);
        assert_eq!(crate::scenario::strip_measured(&line).unwrap(), line);
    }

    #[test]
    fn non_bmp_strings_round_trip_through_submit_frames() {
        // Astral-plane text (emoji, CJK extension B) in every string
        // layer of a submission: raw UTF-8 in the frame must survive
        // parse → re-serialize → parse, and escaped surrogate-pair input
        // must decode to the same request.
        let req = JobRequest {
            spec_toml: Some("[run]\n# 😀 smoke \u{2603} \u{10348}\n".to_string()),
            env: vec!["--label".into(), "𠜎𠜱".into()],
            args: vec!["--note".into(), "done 🏁".into()],
            stream: false,
        };
        let line = submit_line(&req);
        match parse_client_line(&line).unwrap() {
            ClientCmd::Submit(parsed) => assert_eq!(*parsed, req),
            other => panic!("parsed {other:?}"),
        }
        // Canonical fixed point with the raw UTF-8 intact.
        assert_eq!(Json::parse(&line).unwrap().to_string(), line);

        // A frame carrying the surrogate-pair escape form parses to the
        // same text as raw UTF-8 (satellite: the codec's non-BMP
        // decoding).
        let escaped =
            "{\"cmd\":\"submit\",\"spec_toml\":\"\\ud83d\\ude00\",\"stream\":true}";
        match parse_client_line(escaped).unwrap() {
            ClientCmd::Submit(parsed) => {
                assert_eq!(parsed.spec_toml.as_deref(), Some("\u{1F600}"))
            }
            other => panic!("parsed {other:?}"),
        }
        // Lone surrogates must be rejected at the frame boundary, not
        // smuggled into a spec.
        assert!(parse_client_line(r#"{"cmd":"submit","spec_toml":"\ud83d"}"#).is_err());
    }
}
