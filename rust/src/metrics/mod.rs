//! Experiment metrics: named series, CSV/JSON emission, scoped timers.
//!
//! Examples and benches record every figure's series through a
//! [`Recorder`], then dump `results/<name>.csv` + `.json` so the tables in
//! EXPERIMENTS.md are regenerable from artifacts rather than retyped.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// A named table: column names + rows.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Series {
    pub fn new(columns: &[&str]) -> Series {
        Series {
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row arity mismatch for columns {:?}",
            self.columns
        );
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "columns",
                Json::arr(self.columns.iter().map(|c| Json::str(c))),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|&v| Json::num(v)))),
                ),
            ),
        ])
    }

    /// Pretty-print as an aligned text table (what benches show on stdout).
    pub fn print(&self, title: &str) {
        println!("\n--- {title} ---");
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|r| format!("{:.6}", r[i]).len())
                    .chain(std::iter::once(c.len()))
                    .max()
                    .unwrap_or(8)
            })
            .collect();
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        println!("{}", header.join("  "));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(v, w)| format!("{:>w$}", format_cell(*v)))
                .collect();
            println!("{}", cells.join("  "));
        }
    }
}

fn format_cell(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e9 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

/// Collects named series and writes them out together.
#[derive(Debug, Default)]
pub struct Recorder {
    pub series: BTreeMap<String, Series>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn series(&mut self, name: &str, columns: &[&str]) -> &mut Series {
        self.series
            .entry(name.to_string())
            .or_insert_with(|| Series::new(columns))
    }

    /// Write every series as `<dir>/<name>.csv` and a combined JSON file.
    pub fn write_dir(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut all = BTreeMap::new();
        for (name, series) in &self.series {
            let mut f = std::fs::File::create(dir.join(format!("{name}.csv")))?;
            f.write_all(series.to_csv().as_bytes())?;
            all.insert(name.clone(), series.to_json());
        }
        let mut f = std::fs::File::create(dir.join("results.json"))?;
        f.write_all(Json::Obj(all).to_string().as_bytes())?;
        Ok(())
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
    label: String,
}

impl Timer {
    pub fn start(label: &str) -> Timer {
        Timer {
            start: Instant::now(),
            label: label.to_string(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn stop(self) -> f64 {
        let dt = self.elapsed_s();
        println!("[timer] {}: {:.3}s", self.label, dt);
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut s = Series::new(&["a", "b"]);
        s.push(vec![1.0, 2.5]);
        s.push(vec![3.0, 4.0]);
        let csv = s.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("a,b\n"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut s = Series::new(&["a", "b"]);
        s.push(vec![1.0]);
    }

    #[test]
    fn recorder_writes_files() {
        let dir = std::env::temp_dir().join(format!("hfl_metrics_{}", std::process::id()));
        let mut rec = Recorder::new();
        rec.series("t1", &["x", "y"]).push(vec![1.0, 2.0]);
        rec.write_dir(&dir).unwrap();
        assert!(dir.join("t1.csv").exists());
        assert!(dir.join("results.json").exists());
        let json = std::fs::read_to_string(dir.join("results.json")).unwrap();
        assert!(Json::parse(&json).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn timer_measures() {
        let t = Timer::start("t");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
    }
}
