//! Experiment metrics: named series, CSV/JSON emission, scoped timers.
//!
//! Examples and benches record every figure's series through a
//! [`Recorder`], then dump `results/<name>.csv` + `.json` so the tables in
//! EXPERIMENTS.md are regenerable from artifacts rather than retyped.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// A named table: column names + rows, with an optional text label per
/// row (used by `hfl trace` for phase/counter names; empty = unlabeled,
/// and unlabeled output is byte-identical to the pre-label format).
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
    pub labels: Vec<String>,
}

impl Series {
    pub fn new(columns: &[&str]) -> Series {
        Series {
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            labels: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row arity mismatch for columns {:?}",
            self.columns
        );
        assert!(
            self.labels.is_empty(),
            "labeled series requires push_labeled"
        );
        self.rows.push(row);
    }

    /// Push a row with a leading text label. Mixing with [`Series::push`]
    /// is rejected: a series is either fully labeled or fully unlabeled.
    pub fn push_labeled(&mut self, label: &str, row: Vec<f64>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row arity mismatch for columns {:?}",
            self.columns
        );
        assert_eq!(
            self.labels.len(),
            self.rows.len(),
            "cannot mix push and push_labeled"
        );
        self.labels.push(label.to_string());
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let labeled = !self.labels.is_empty();
        let mut out = String::new();
        if labeled {
            out.push_str("name,");
        }
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for (i, row) in self.rows.iter().enumerate() {
            if labeled {
                out.push_str(&self.labels[i]);
                out.push(',');
            }
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "columns",
                Json::arr(self.columns.iter().map(|c| Json::str(c))),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|&v| Json::num(v)))),
                ),
            ),
        ];
        if !self.labels.is_empty() {
            // Only labeled series carry the extra key (unlabeled JSON is
            // byte-identical to the pre-label format).
            fields.push(("labels", Json::arr(self.labels.iter().map(|l| Json::str(l)))));
        }
        Json::obj(fields)
    }

    /// Pretty-print as an aligned text table (what benches show on stdout).
    pub fn print(&self, title: &str) {
        println!("\n--- {title} ---"); // stdout-ok: Series::print is a display API
        let label_w = self.labels.iter().map(|l| l.len()).max().unwrap_or(0);
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|r| format!("{:.6}", r[i]).len())
                    .chain(std::iter::once(c.len()))
                    .max()
                    .unwrap_or(8)
            })
            .collect();
        let mut header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        if label_w > 0 {
            header.insert(0, " ".repeat(label_w));
        }
        println!("{}", header.join("  ")); // stdout-ok: Series::print is a display API
        for (i, row) in self.rows.iter().enumerate() {
            let mut cells: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(v, w)| format!("{:>w$}", format_cell(*v)))
                .collect();
            if label_w > 0 {
                cells.insert(0, format!("{:<label_w$}", self.labels[i]));
            }
            println!("{}", cells.join("  ")); // stdout-ok: Series::print is a display API
        }
    }
}

fn format_cell(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e9 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

/// Collects named series and writes them out together.
#[derive(Debug, Default)]
pub struct Recorder {
    pub series: BTreeMap<String, Series>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn series(&mut self, name: &str, columns: &[&str]) -> &mut Series {
        self.series
            .entry(name.to_string())
            .or_insert_with(|| Series::new(columns))
    }

    /// Write every series as `<dir>/<name>.csv` and a combined JSON file.
    pub fn write_dir(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut all = BTreeMap::new();
        for (name, series) in &self.series {
            let mut f = std::fs::File::create(dir.join(format!("{name}.csv")))?;
            f.write_all(series.to_csv().as_bytes())?;
            all.insert(name.clone(), series.to_json());
        }
        let mut f = std::fs::File::create(dir.join("results.json"))?;
        f.write_all(Json::Obj(all).to_string().as_bytes())?;
        Ok(())
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
    label: String,
}

impl Timer {
    pub fn start(label: &str) -> Timer {
        Timer {
            start: Instant::now(),
            label: label.to_string(),
        }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Stop and return the elapsed seconds. Silent: recording belongs to
    /// the caller (a [`Series`] row, a `trace::TraceSink` span, ...) —
    /// library code must not write to stdout.
    pub fn stop(self) -> f64 {
        self.elapsed_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut s = Series::new(&["a", "b"]);
        s.push(vec![1.0, 2.5]);
        s.push(vec![3.0, 4.0]);
        let csv = s.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("a,b\n"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut s = Series::new(&["a", "b"]);
        s.push(vec![1.0]);
    }

    #[test]
    fn recorder_writes_files() {
        let dir = std::env::temp_dir().join(format!("hfl_metrics_{}", std::process::id()));
        let mut rec = Recorder::new();
        rec.series("t1", &["x", "y"]).push(vec![1.0, 2.0]);
        rec.write_dir(&dir).unwrap();
        assert!(dir.join("t1.csv").exists());
        assert!(dir.join("results.json").exists());
        let json = std::fs::read_to_string(dir.join("results.json")).unwrap();
        assert!(Json::parse(&json).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn labeled_series_csv_has_name_column() {
        let mut s = Series::new(&["x"]);
        s.push_labeled("alpha", vec![1.0]);
        s.push_labeled("beta", vec![2.0]);
        let csv = s.to_csv();
        assert!(csv.starts_with("name,x\n"));
        assert!(csv.contains("alpha,1\n") && csv.contains("beta,2\n"));
    }

    #[test]
    #[should_panic(expected = "cannot mix")]
    fn mixing_push_and_push_labeled_panics() {
        let mut s = Series::new(&["x"]);
        s.push(vec![1.0]);
        s.push_labeled("a", vec![2.0]);
    }

    #[test]
    fn timer_stop_is_silent_and_returns_elapsed() {
        let t = Timer::start("quiet");
        assert_eq!(t.label(), "quiet");
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.stop() >= 0.001);
    }

    #[test]
    fn timer_measures() {
        let t = Timer::start("t");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
    }
}
