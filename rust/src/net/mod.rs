//! Wireless-network substrate for the paper's system model (§III).
//!
//! The paper evaluates on a *simulated* wireless deployment: UEs uniform in
//! a 500 m x 500 m square, edge servers at cell centers, free-space path
//! loss at 28 GHz, OFDMA uplinks with Shannon-capacity rates, and a wired
//! edge→cloud backhaul. This module owns all of that physical-layer state;
//! `delay/` turns it into the paper's timing quantities.

pub mod bandwidth;
pub mod channel;
pub mod devices;
pub mod topology;

pub use bandwidth::BandwidthPolicy;
pub use channel::{path_loss_gain, shannon_rate, snr, Channel};
pub use devices::{DeviceClass, DeviceClassSpec};
pub use topology::{EdgeServer, Position, SystemParams, Topology, Ue};
