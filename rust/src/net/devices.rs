//! Heterogeneous device classes (extension; the paper's fleet is uniform).
//!
//! The paper samples every UE from one implicit "device": `f_n = f_max`,
//! `p_n = p_max`, cycles-per-sample uniform in a single range. Real HFL
//! fleets mix flagships, mid-tier phones and IoT nodes whose compute and
//! radio differ by orders of magnitude — the heterogeneity that makes
//! per-edge round time `τ_m(a) = max_n (a·t_n^cmp + t_n^com)` a genuine
//! max over *unequal* members instead of a near-tie. A
//! [`DeviceClassSpec`] is a weighted distribution over named classes,
//! each scaling the three per-UE physical quantities:
//!
//! * `f_cpu_scale`  — CPU frequency relative to `f_max` (Eq. (1) `f_n`);
//! * `power_scale`  — transmit power relative to `p_max` (SNR → rate);
//! * `cycles_scale` — multiplier on the drawn cycles-per-sample `C_n`.
//!
//! Sampling discipline (what the strict-generalization property rests
//! on): class draws come from a **separate** RNG stream forked off the
//! topology seed, never from the stream that draws positions and data
//! sizes. The base topology is therefore bitwise-identical with or
//! without device classes, and a single class with all scales `1.0`
//! reproduces the homogeneous fleet exactly — bit for bit, at every
//! level of the stack (property-tested in `tests/hetero.rs`).
//!
//! Compact text format (TOML `[devices] classes = "..."` and the
//! `--device-classes` CLI flag):
//!
//! ```text
//! name:weight:f_cpu_scale:power_scale:cycles_scale[, ...]
//! e.g. "flagship:0.2:1.0:1.0:1.0, mid:0.5:0.5:0.8:1.0, iot:0.3:0.1:0.4:2.0"
//! ```

use crate::util::Rng;

/// One device class: a weight (relative share of the fleet) plus the
/// three physical scale factors applied to a sampled UE.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceClass {
    pub name: String,
    /// Relative sampling weight (need not be normalized; ≥ 0).
    pub weight: f64,
    /// `f_n = f_cpu_scale · f_max`.
    pub f_cpu_scale: f64,
    /// `p_n = power_scale · p_max` (watts, post dBm conversion).
    pub power_scale: f64,
    /// Multiplier on the drawn cycles-per-sample `C_n`.
    pub cycles_scale: f64,
}

impl DeviceClass {
    /// The homogeneous identity class (all scales 1).
    pub fn baseline(name: &str, weight: f64) -> DeviceClass {
        DeviceClass {
            name: name.to_string(),
            weight,
            f_cpu_scale: 1.0,
            power_scale: 1.0,
            cycles_scale: 1.0,
        }
    }
}

/// A weighted distribution over device classes. Empty = the paper's
/// homogeneous fleet (no class pass runs at all).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DeviceClassSpec {
    pub classes: Vec<DeviceClass>,
}

impl DeviceClassSpec {
    pub fn new() -> DeviceClassSpec {
        DeviceClassSpec::default()
    }

    /// Append one class (builder style).
    pub fn class(
        mut self,
        name: &str,
        weight: f64,
        f_cpu_scale: f64,
        power_scale: f64,
        cycles_scale: f64,
    ) -> Self {
        self.classes.push(DeviceClass {
            name: name.to_string(),
            weight,
            f_cpu_scale,
            power_scale,
            cycles_scale,
        });
        self
    }

    /// No classes at all — the untouched homogeneous sampler runs.
    pub fn is_empty(&self) -> bool {
        self.classes.is_empty()
    }

    /// Would applying this spec change nothing about a sampled fleet?
    /// True when empty, or when every positive-weight class is the
    /// identity (all scales exactly 1) — the strict-generalization case.
    pub fn is_homogeneous(&self) -> bool {
        self.classes
            .iter()
            .filter(|c| c.weight > 0.0)
            .all(|c| c.f_cpu_scale == 1.0 && c.power_scale == 1.0 && c.cycles_scale == 1.0)
    }

    /// Parse the compact `name:w:f:p:c[, ...]` format (see module docs).
    pub fn parse(text: &str) -> Result<DeviceClassSpec, String> {
        let mut spec = DeviceClassSpec::default();
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').map(str::trim).collect();
            if fields.len() != 5 {
                return Err(format!(
                    "device class '{part}': expected name:weight:f_cpu:power:cycles (5 fields, \
                     got {})",
                    fields.len()
                ));
            }
            let num = |i: usize, what: &str| -> Result<f64, String> {
                fields[i].parse::<f64>().map_err(|_| {
                    format!("device class '{}': bad {what} '{}'", fields[0], fields[i])
                })
            };
            spec.classes.push(DeviceClass {
                name: fields[0].to_string(),
                weight: num(1, "weight")?,
                f_cpu_scale: num(2, "f_cpu_scale")?,
                power_scale: num(3, "power_scale")?,
                cycles_scale: num(4, "cycles_scale")?,
            });
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Round-trip of [`Self::parse`] (for spec summaries / provenance).
    pub fn to_compact(&self) -> String {
        self.classes
            .iter()
            .map(|c| {
                format!(
                    "{}:{}:{}:{}:{}",
                    c.name, c.weight, c.f_cpu_scale, c.power_scale, c.cycles_scale
                )
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.classes.is_empty() {
            return Ok(());
        }
        let mut total = 0.0;
        for c in &self.classes {
            if !c.weight.is_finite() || c.weight < 0.0 {
                return Err(format!("device class '{}': weight must be >= 0", c.name));
            }
            for (what, v) in [
                ("f_cpu_scale", c.f_cpu_scale),
                ("power_scale", c.power_scale),
                ("cycles_scale", c.cycles_scale),
            ] {
                if !v.is_finite() || v <= 0.0 {
                    return Err(format!(
                        "device class '{}': {what} must be finite and > 0, got {v}",
                        c.name
                    ));
                }
            }
            total += c.weight;
        }
        if total <= 0.0 {
            return Err("device classes need positive total weight".to_string());
        }
        Ok(())
    }

    /// Draw one class index by weight. Deterministic walk over the
    /// cumulative weights; zero-weight classes are unreachable (u is
    /// strictly below the total, and a zero-weight class never advances
    /// the cumulative sum past u on its own).
    pub fn pick(&self, rng: &mut Rng) -> usize {
        debug_assert!(!self.classes.is_empty());
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        let u = rng.f64() * total;
        let mut acc = 0.0;
        let mut last_positive = 0;
        for (i, c) in self.classes.iter().enumerate() {
            if c.weight > 0.0 {
                last_positive = i;
            }
            acc += c.weight;
            if u < acc {
                return i;
            }
        }
        // Float round-off on the final cumulative sum: clamp to the last
        // class that can actually be drawn.
        last_positive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        let spec = DeviceClassSpec::parse(
            "flagship:0.2:1.0:1.0:1.0, mid:0.5:0.5:0.8:1.0, iot:0.3:0.1:0.4:2.0",
        )
        .unwrap();
        assert_eq!(spec.classes.len(), 3);
        assert_eq!(spec.classes[1].name, "mid");
        assert_eq!(spec.classes[1].f_cpu_scale, 0.5);
        assert_eq!(spec.classes[2].cycles_scale, 2.0);
        let again = DeviceClassSpec::parse(&spec.to_compact()).unwrap();
        assert_eq!(spec, again);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(DeviceClassSpec::parse("a:1:1:1").is_err()); // 4 fields
        assert!(DeviceClassSpec::parse("a:x:1:1:1").is_err()); // bad number
        assert!(DeviceClassSpec::parse("a:1:0:1:1").is_err()); // zero scale
        assert!(DeviceClassSpec::parse("a:-1:1:1:1").is_err()); // negative weight
        assert!(DeviceClassSpec::parse("a:0:1:1:1").is_err()); // zero total weight
        assert!(DeviceClassSpec::parse("").unwrap().is_empty()); // empty = homogeneous
    }

    #[test]
    fn homogeneity_detection() {
        assert!(DeviceClassSpec::new().is_homogeneous());
        assert!(DeviceClassSpec::new().class("one", 1.0, 1.0, 1.0, 1.0).is_homogeneous());
        // A zero-weight non-identity class is never drawn: still homogeneous.
        assert!(DeviceClassSpec::new()
            .class("one", 1.0, 1.0, 1.0, 1.0)
            .class("ghost", 0.0, 0.1, 0.1, 5.0)
            .is_homogeneous());
        assert!(!DeviceClassSpec::new().class("slow", 1.0, 0.5, 1.0, 1.0).is_homogeneous());
    }

    #[test]
    fn pick_respects_weights_and_skips_zero() {
        let spec = DeviceClassSpec::new()
            .class("a", 1.0, 1.0, 1.0, 1.0)
            .class("ghost", 0.0, 0.1, 1.0, 1.0)
            .class("b", 3.0, 0.5, 1.0, 1.0);
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[spec.pick(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0, "zero-weight class must never be drawn");
        // 1:3 weight ratio within loose tolerance.
        let frac_b = counts[2] as f64 / 4000.0;
        assert!((frac_b - 0.75).abs() < 0.05, "b fraction {frac_b}");
    }

    #[test]
    fn pick_single_class_is_always_zero() {
        let spec = DeviceClassSpec::new().class("only", 0.25, 0.5, 1.0, 1.0);
        let mut rng = Rng::new(1);
        for _ in 0..64 {
            assert_eq!(spec.pick(&mut rng), 0);
        }
    }
}
