//! Deployment geometry + per-entity physical parameters (paper §V-A).

use crate::util::Rng;

/// 2-D position in meters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Position {
    pub x: f64,
    pub y: f64,
}

impl Position {
    pub fn dist(&self, other: &Position) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }
}

/// Physical / learning constants of a scenario. Defaults follow the
/// paper's §V-A experiment settings; everything is overridable from TOML
/// or the CLI (see `config/`).
#[derive(Debug, Clone)]
pub struct SystemParams {
    /// Side of the square deployment area (m). Paper: 500.
    pub area_m: f64,
    /// Carrier frequency (Hz). Paper: 28 GHz.
    pub carrier_hz: f64,
    /// Noise power spectral density (dBm/Hz). Thermal: -174.
    pub noise_dbm_per_hz: f64,
    /// Total uplink bandwidth per edge server B (Hz).
    pub edge_bandwidth_hz: f64,
    /// Per-UE allocated bandwidth B_n (Hz) under the fixed-allocation
    /// policy used by the association sub-problem (constraint (13e)).
    pub ue_bandwidth_hz: f64,
    /// Max UE CPU frequency f_n^max (Hz). Paper: 2 GHz.
    pub f_max_hz: f64,
    /// Max UE transmit power p_n^max (dBm). Paper: 10 dBm.
    pub p_max_dbm: f64,
    /// CPU cycles per sample C_n, drawn uniformly from this range.
    pub cycles_per_sample: (f64, f64),
    /// Local dataset size D_n (samples), drawn uniformly from this range.
    pub samples_per_ue: (u64, u64),
    /// Local model size d_n (bits). LeNet: 44426 f32 = 1.42 Mbit.
    pub model_bits: f64,
    /// Edge model size d_m (bits). Same architecture => same size.
    pub edge_model_bits: f64,
    /// Edge→cloud backhaul rate r_m (bit/s). The paper never states
    /// its backhaul; 1 Mb/s (a constrained wireless backhaul) places the
    /// optimizer in the paper's operating regime (b* ≈ 3-7, Fig. 2/4/6).
    /// With a fast wired backhaul (e.g. 150 Mb/s) b* pins to 1 — see
    /// EXPERIMENTS.md §Fig2.
    pub edge_cloud_rate_bps: f64,
    /// Loss-geometry constant γ of Eq. (7). Paper: random int 1..10.
    pub gamma: f64,
    /// Loss-geometry constant ζ of Eq. (2). Paper: random int 1..10.
    pub zeta: f64,
    /// Constant C of Eq. (14).
    pub c_const: f64,
    /// Large-scale propagation model (paper: free space).
    pub path_loss: PathLossModel,
    /// Small-scale fading (extension; paper: none).
    pub fading: FadingModel,
}

/// Large-scale path-loss models. The paper uses free space (§V-A);
/// log-distance is the standard urban generalization [Goldsmith, ch. 2]
/// provided as an extension for sensitivity studies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PathLossModel {
    /// `g = (λ / 4πd)²` — the paper's model.
    FreeSpace,
    /// Free-space gain at `ref_dist_m`, then decay with `exponent`:
    /// `g(d) = g_fs(d0) · (d0/d)^exponent`.
    LogDistance { exponent: f64, ref_dist_m: f64 },
}

/// Small-scale fading applied multiplicatively to the channel gain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FadingModel {
    /// Deterministic gains (the paper's setting).
    None,
    /// Rayleigh block fading: per-link power `|h|² ~ Exp(1)` (unit mean),
    /// drawn once per topology from `seed` — models a static snapshot of
    /// a scattering environment.
    Rayleigh { seed: u64 },
}

impl Default for SystemParams {
    fn default() -> Self {
        SystemParams {
            area_m: 500.0,
            carrier_hz: 28e9,
            noise_dbm_per_hz: -174.0,
            edge_bandwidth_hz: 20e6,
            ue_bandwidth_hz: 1e6,
            f_max_hz: 2e9,
            p_max_dbm: 10.0,
            cycles_per_sample: (1e4, 3e4),
            samples_per_ue: (300, 700),
            model_bits: 44426.0 * 32.0,
            edge_model_bits: 44426.0 * 32.0,
            edge_cloud_rate_bps: 1e6,
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            path_loss: PathLossModel::FreeSpace,
            fading: FadingModel::None,
        }
    }
}

impl SystemParams {
    /// Wavelength (m) of the carrier.
    pub fn wavelength_m(&self) -> f64 {
        299_792_458.0 / self.carrier_hz
    }

    /// Noise power (W) over a band of `bandwidth_hz`.
    pub fn noise_w(&self, bandwidth_hz: f64) -> f64 {
        dbm_to_w(self.noise_dbm_per_hz) * bandwidth_hz
    }

    /// Max UEs one edge server can host under constraint (13e) with the
    /// fixed per-UE bandwidth allocation.
    pub fn edge_capacity(&self) -> usize {
        (self.edge_bandwidth_hz / self.ue_bandwidth_hz).floor() as usize
    }

    /// Draw γ, ζ as the paper does ("random integers between 1 to 10").
    pub fn randomize_loss_constants(&mut self, rng: &mut Rng) {
        self.gamma = rng.int_range(1, 10) as f64;
        self.zeta = rng.int_range(1, 10) as f64;
    }
}

/// Convert dBm to watts.
pub fn dbm_to_w(dbm: f64) -> f64 {
    1e-3 * 10f64.powf(dbm / 10.0)
}

/// A user equipment (paper: UE n).
#[derive(Debug, Clone)]
pub struct Ue {
    pub id: usize,
    pub pos: Position,
    /// CPU frequency f_n (Hz); optimal solution pins it to f_max (§IV-C.1).
    pub cpu_hz: f64,
    /// Transmit power p_n (W); pinned to p_max by the optimizer.
    pub tx_power_w: f64,
    /// Cycles to process one sample, C_n.
    pub cycles_per_sample: f64,
    /// Local dataset size D_n.
    pub num_samples: u64,
    /// Local model size d_n (bits).
    pub model_bits: f64,
}

/// An edge server (paper: m).
#[derive(Debug, Clone)]
pub struct EdgeServer {
    pub id: usize,
    pub pos: Position,
    /// Total uplink bandwidth B (Hz).
    pub bandwidth_hz: f64,
    /// Backhaul rate to the cloud r_m (bit/s).
    pub cloud_rate_bps: f64,
    /// Edge model size d_m (bits).
    pub model_bits: f64,
}

/// A sampled deployment: N UEs + M edge servers + the scenario constants.
#[derive(Debug, Clone)]
pub struct Topology {
    pub params: SystemParams,
    pub ues: Vec<Ue>,
    pub edges: Vec<EdgeServer>,
}

impl Topology {
    /// [`Self::sample`] with heterogeneous device classes: the base fleet
    /// is drawn exactly as the homogeneous sampler draws it (same RNG,
    /// same order), then a **separate** class stream forked off the seed
    /// assigns each UE a class and scales `f_n`, `p_n` and `C_n`. Because
    /// the class stream never touches the base stream, positions and
    /// dataset sizes are bitwise-identical with or without classes, and
    /// an identity class spec reproduces [`Self::sample`] bit for bit
    /// (the strict-generalization property `tests/hetero.rs` pins).
    pub fn sample_with_devices(
        params: &SystemParams,
        devices: &crate::net::DeviceClassSpec,
        num_edges: usize,
        num_ues: usize,
        seed: u64,
    ) -> Topology {
        let mut topo = Topology::sample(params, num_edges, num_ues, seed);
        if devices.is_empty() {
            return topo;
        }
        // hfl-lint: allow(R4, device-class stream is rooted at the topology seed)
        let mut class_rng = Rng::new(seed ^ 0xDE71_CEC1_A55E_5EED);
        for ue in topo.ues.iter_mut() {
            let c = &devices.classes[devices.pick(&mut class_rng)];
            // Multiplication by an exact 1.0 is the identity under
            // IEEE-754, so identity classes leave the fleet bitwise
            // untouched even though the pass runs.
            ue.cpu_hz = params.f_max_hz * c.f_cpu_scale;
            ue.tx_power_w = dbm_to_w(params.p_max_dbm) * c.power_scale;
            ue.cycles_per_sample *= c.cycles_scale;
        }
        topo
    }

    /// Sample a deployment: UEs uniform in the square; edge servers on a
    /// regular sub-grid ("located in the center" of their cells, §V-A).
    pub fn sample(params: &SystemParams, num_edges: usize, num_ues: usize, seed: u64) -> Topology {
        // hfl-lint: allow(R4, deployment sampling is rooted at the scenario seed)
        let mut rng = Rng::new(seed);
        let a = params.area_m;

        // Edge grid: ceil(sqrt(M)) columns; centers of equal cells.
        let cols = (num_edges as f64).sqrt().ceil() as usize;
        let rows = num_edges.div_ceil(cols);
        let mut edges = Vec::with_capacity(num_edges);
        for m in 0..num_edges {
            let (r, c) = (m / cols, m % cols);
            edges.push(EdgeServer {
                id: m,
                pos: Position {
                    x: (c as f64 + 0.5) * a / cols as f64,
                    y: (r as f64 + 0.5) * a / rows as f64,
                },
                bandwidth_hz: params.edge_bandwidth_hz,
                cloud_rate_bps: params.edge_cloud_rate_bps,
                model_bits: params.edge_model_bits,
            });
        }

        let ues = (0..num_ues)
            .map(|n| {
                let (c_lo, c_hi) = params.cycles_per_sample;
                let (s_lo, s_hi) = params.samples_per_ue;
                Ue {
                    id: n,
                    pos: Position {
                        x: rng.range(0.0, a),
                        y: rng.range(0.0, a),
                    },
                    cpu_hz: params.f_max_hz,
                    tx_power_w: dbm_to_w(params.p_max_dbm),
                    cycles_per_sample: rng.range(c_lo, c_hi),
                    num_samples: rng.int_range(s_lo as i64, s_hi as i64) as u64,
                    model_bits: params.model_bits,
                }
            })
            .collect();

        Topology {
            params: params.clone(),
            ues,
            edges,
        }
    }

    pub fn num_ues(&self) -> usize {
        self.ues.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Total data volume D across all UEs (Eq. (10) denominator).
    pub fn total_samples(&self) -> u64 {
        self.ues.iter().map(|u| u.num_samples).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_is_deterministic() {
        let p = SystemParams::default();
        let a = Topology::sample(&p, 5, 50, 7);
        let b = Topology::sample(&p, 5, 50, 7);
        assert_eq!(a.ues.len(), 50);
        assert_eq!(a.edges.len(), 5);
        for (x, y) in a.ues.iter().zip(&b.ues) {
            assert_eq!(x.pos, y.pos);
            assert_eq!(x.num_samples, y.num_samples);
        }
    }

    #[test]
    fn ues_inside_area() {
        let p = SystemParams::default();
        let t = Topology::sample(&p, 4, 200, 3);
        for u in &t.ues {
            assert!(u.pos.x >= 0.0 && u.pos.x <= p.area_m);
            assert!(u.pos.y >= 0.0 && u.pos.y <= p.area_m);
        }
        for e in &t.edges {
            assert!(e.pos.x > 0.0 && e.pos.x < p.area_m);
        }
    }

    #[test]
    fn single_edge_is_centered() {
        let p = SystemParams::default();
        let t = Topology::sample(&p, 1, 10, 1);
        assert!((t.edges[0].pos.x - 250.0).abs() < 1e-9);
        assert!((t.edges[0].pos.y - 250.0).abs() < 1e-9);
    }

    #[test]
    fn physical_constants() {
        let p = SystemParams::default();
        // 28 GHz -> wavelength ~ 10.7 mm (paper uses 3/280 m ≈ 10.714 mm).
        assert!((p.wavelength_m() - 3.0 / 280.0).abs() < 1e-4);
        // 10 dBm = 10 mW.
        assert!((dbm_to_w(10.0) - 0.01).abs() < 1e-12);
        // Capacity: 20 MHz / 1 MHz = 20 UEs per edge.
        assert_eq!(p.edge_capacity(), 20);
    }

    #[test]
    fn device_classes_scale_only_the_class_fields() {
        use crate::net::DeviceClassSpec;
        let p = SystemParams::default();
        let plain = Topology::sample(&p, 3, 40, 11);
        let spec = DeviceClassSpec::new()
            .class("fast", 1.0, 1.0, 1.0, 1.0)
            .class("slow", 1.0, 0.25, 0.5, 2.0);
        let hetero = Topology::sample_with_devices(&p, &spec, 3, 40, 11);
        let mut saw_slow = false;
        for (a, b) in plain.ues.iter().zip(&hetero.ues) {
            // Base draws untouched: position + dataset size bitwise equal.
            assert_eq!(a.pos, b.pos);
            assert_eq!(a.num_samples, b.num_samples);
            assert_eq!(a.model_bits.to_bits(), b.model_bits.to_bits());
            // Class fields are one of the two class values exactly.
            let slow = b.cpu_hz == p.f_max_hz * 0.25;
            let fast = b.cpu_hz == p.f_max_hz;
            assert!(slow || fast, "cpu {:.3e}", b.cpu_hz);
            if slow {
                saw_slow = true;
                assert_eq!(b.cycles_per_sample.to_bits(), (a.cycles_per_sample * 2.0).to_bits());
                assert_eq!(b.tx_power_w.to_bits(), (a.tx_power_w * 0.5).to_bits());
            } else {
                assert_eq!(b.cycles_per_sample.to_bits(), a.cycles_per_sample.to_bits());
                assert_eq!(b.tx_power_w.to_bits(), a.tx_power_w.to_bits());
            }
        }
        assert!(saw_slow, "40 draws at weight 1:1 must hit the slow class");
        // Deterministic per seed.
        let again = Topology::sample_with_devices(&p, &spec, 3, 40, 11);
        for (a, b) in hetero.ues.iter().zip(&again.ues) {
            assert_eq!(a.cpu_hz.to_bits(), b.cpu_hz.to_bits());
        }
    }

    #[test]
    fn identity_device_class_reproduces_plain_sample_bitwise() {
        use crate::net::DeviceClassSpec;
        let p = SystemParams::default();
        let plain = Topology::sample(&p, 4, 60, 9);
        let one = Topology::sample_with_devices(
            &p,
            &DeviceClassSpec::new().class("only", 1.0, 1.0, 1.0, 1.0),
            4,
            60,
            9,
        );
        for (a, b) in plain.ues.iter().zip(&one.ues) {
            assert_eq!(a.pos, b.pos);
            assert_eq!(a.cpu_hz.to_bits(), b.cpu_hz.to_bits());
            assert_eq!(a.tx_power_w.to_bits(), b.tx_power_w.to_bits());
            assert_eq!(a.cycles_per_sample.to_bits(), b.cycles_per_sample.to_bits());
            assert_eq!(a.num_samples, b.num_samples);
        }
    }

    #[test]
    fn randomize_loss_constants_in_range() {
        let mut p = SystemParams::default();
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            p.randomize_loss_constants(&mut rng);
            assert!((1.0..=10.0).contains(&p.gamma));
            assert!((1.0..=10.0).contains(&p.zeta));
            assert_eq!(p.gamma.fract(), 0.0);
        }
    }
}
