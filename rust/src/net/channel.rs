//! Channel model: free-space path loss + Shannon capacity (paper Eq. (4)
//! and §V-A).
//!
//! The paper adopts the free-space model from Goldsmith [24]:
//! `g_{n,m} = (wavelength / (4π · distance))²` at 28 GHz, and the uplink
//! rate `r_{n,m} = B_n log2(1 + g_{n,m} p_n / N_0)` with OFDMA (no
//! intra-cell interference).

use super::topology::{EdgeServer, FadingModel, PathLossModel, SystemParams, Ue};
use crate::util::Rng;

/// Free-space path-loss channel gain between two points `dist_m` apart.
///
/// A minimum distance of 1 m is enforced (the far-field assumption of the
/// model; also keeps the gain finite when a UE is sampled on top of an
/// edge server).
pub fn path_loss_gain(wavelength_m: f64, dist_m: f64) -> f64 {
    let d = dist_m.max(1.0);
    let x = wavelength_m / (4.0 * std::f64::consts::PI * d);
    x * x
}

/// Channel gain under a configurable large-scale model.
pub fn model_gain(model: PathLossModel, wavelength_m: f64, dist_m: f64) -> f64 {
    match model {
        PathLossModel::FreeSpace => path_loss_gain(wavelength_m, dist_m),
        PathLossModel::LogDistance {
            exponent,
            ref_dist_m,
        } => {
            let d0 = ref_dist_m.max(1.0);
            let g0 = path_loss_gain(wavelength_m, d0);
            g0 * (d0 / dist_m.max(d0)).powf(exponent)
        }
    }
}

/// Uplink SNR `g p / N0` for a UE→edge link over `bandwidth_hz`.
pub fn snr(params: &SystemParams, ue: &Ue, edge: &EdgeServer, bandwidth_hz: f64) -> f64 {
    let g = path_loss_gain(params.wavelength_m(), ue.pos.dist(&edge.pos));
    g * ue.tx_power_w / params.noise_w(bandwidth_hz)
}

/// Shannon rate (bit/s): `B log2(1 + snr)`.
pub fn shannon_rate(bandwidth_hz: f64, snr: f64) -> f64 {
    bandwidth_hz * (1.0 + snr).log2()
}

/// Large-scale (deterministic) gain of one UE→edge link.
#[inline]
fn large_scale_gain(params: &SystemParams, wavelength_m: f64, ue: &Ue, edge: &EdgeServer) -> f64 {
    model_gain(params.path_loss, wavelength_m, ue.pos.dist(&edge.pos))
}

/// SNR + Shannon rate of a link with (possibly faded) gain `g`. Shared by
/// [`Channel::compute`] and [`Channel::recompute_ue`] so the link physics
/// cannot diverge between full and incremental table builds.
#[inline]
fn snr_and_rate(g: f64, tx_power_w: f64, noise_w: f64, bandwidth_hz: f64) -> (f64, f64) {
    let s = g * tx_power_w / noise_w;
    (s, shannon_rate(bandwidth_hz, s))
}

/// Precomputed N x M channel tables for one topology: gains, SNRs and
/// uplink rates under the *fixed per-UE bandwidth* policy (the one the
/// association sub-problem optimizes over; see `BandwidthPolicy` for the
/// equal-share alternative).
#[derive(Debug, Clone)]
pub struct Channel {
    pub num_ues: usize,
    pub num_edges: usize,
    /// Row-major [ue][edge] channel gains g_{n,m}.
    pub gain: Vec<f64>,
    /// Row-major [ue][edge] SNR at B_n bandwidth.
    pub snr: Vec<f64>,
    /// Row-major [ue][edge] uplink rate (bit/s) at B_n bandwidth.
    pub rate_bps: Vec<f64>,
}

impl Channel {
    pub fn compute(params: &SystemParams, ues: &[Ue], edges: &[EdgeServer]) -> Channel {
        let (n, m) = (ues.len(), edges.len());
        let mut gain = Vec::with_capacity(n * m);
        let mut snr_v = Vec::with_capacity(n * m);
        let mut rate = Vec::with_capacity(n * m);
        let bn = params.ue_bandwidth_hz;
        let noise = params.noise_w(bn);
        let wl = params.wavelength_m();
        let mut fade_rng = match params.fading {
            FadingModel::None => None,
            // hfl-lint: allow(R4, fading stream is rooted at the spec-level fading seed)
            FadingModel::Rayleigh { seed } => Some(Rng::new(seed ^ 0xFAD1_2345)),
        };
        for ue in ues {
            for edge in edges {
                let mut g = large_scale_gain(params, wl, ue, edge);
                if let Some(rng) = fade_rng.as_mut() {
                    // Rayleigh power: |h|^2 ~ Exp(1), unit mean.
                    g *= rng.exponential(1.0);
                }
                let (s, r) = snr_and_rate(g, ue.tx_power_w, noise, bn);
                gain.push(g);
                snr_v.push(s);
                rate.push(r);
            }
        }
        Channel {
            num_ues: n,
            num_edges: m,
            gain,
            snr: snr_v,
            rate_bps: rate,
        }
    }

    #[inline]
    pub fn gain_of(&self, ue: usize, edge: usize) -> f64 {
        self.gain[ue * self.num_edges + edge]
    }

    #[inline]
    pub fn snr_of(&self, ue: usize, edge: usize) -> f64 {
        self.snr[ue * self.num_edges + edge]
    }

    #[inline]
    pub fn rate_of(&self, ue: usize, edge: usize) -> f64 {
        self.rate_bps[ue * self.num_edges + edge]
    }

    /// One UE's gain row (all edges) — the unit `recompute_ue` rewrites.
    #[inline]
    pub fn gain_row(&self, ue: usize) -> &[f64] {
        &self.gain[ue * self.num_edges..(ue + 1) * self.num_edges]
    }

    /// One UE's SNR row — the association scoring core copies this
    /// instead of `num_edges` indexed `snr_of` calls on the hot path.
    #[inline]
    pub fn snr_row(&self, ue: usize) -> &[f64] {
        &self.snr[ue * self.num_edges..(ue + 1) * self.num_edges]
    }

    /// One UE's uplink-rate row.
    #[inline]
    pub fn rate_row(&self, ue: usize) -> &[f64] {
        &self.rate_bps[ue * self.num_edges..(ue + 1) * self.num_edges]
    }

    /// Recompute the table row of one UE in place — the mobility hot path:
    /// when an epoch moves a UE, only its N-row of gains/SNRs/rates
    /// changes. Uses the same expressions in the same order as
    /// [`Channel::compute`], so for an unmoved UE the row is reproduced
    /// bit-for-bit. Small-scale fading is *not* redrawn (a per-call redraw
    /// would break the static-snapshot semantics of `FadingModel::Rayleigh`);
    /// time-varying scenarios pair mobility with `FadingModel::None`.
    pub fn recompute_ue(&mut self, params: &SystemParams, ue: &Ue, edges: &[EdgeServer]) {
        debug_assert_eq!(edges.len(), self.num_edges);
        let bn = params.ue_bandwidth_hz;
        let noise = params.noise_w(bn);
        let wl = params.wavelength_m();
        let row = ue.id * self.num_edges;
        for (j, edge) in edges.iter().enumerate() {
            let g = large_scale_gain(params, wl, ue, edge);
            let (s, r) = snr_and_rate(g, ue.tx_power_w, noise, bn);
            self.gain[row + j] = g;
            self.snr[row + j] = s;
            self.rate_bps[row + j] = r;
        }
    }

    /// Rate if the edge's bandwidth is equally shared among `k` UEs
    /// (Eq. (4) with B_n = B/k). Noise scales with the allocated band.
    pub fn rate_equal_share(
        &self,
        params: &SystemParams,
        ue: usize,
        edge: usize,
        k: usize,
    ) -> f64 {
        let bn = params.edge_bandwidth_hz / k.max(1) as f64;
        let snr = self.gain_of(ue, edge) * params_tx_power(params)
            / params.noise_w(bn);
        shannon_rate(bn, snr)
    }
}

// All UEs transmit at p_max in the optimal solution (§IV-C.1); keep the
// helper local so `rate_equal_share` does not need the Ue list again.
fn params_tx_power(params: &SystemParams) -> f64 {
    super::topology::dbm_to_w(params.p_max_dbm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::Topology;

    fn topo() -> Topology {
        Topology::sample(&SystemParams::default(), 3, 30, 42)
    }

    #[test]
    fn gain_decreases_with_distance() {
        let wl = 3.0 / 280.0;
        assert!(path_loss_gain(wl, 10.0) > path_loss_gain(wl, 100.0));
        assert!(path_loss_gain(wl, 100.0) > path_loss_gain(wl, 400.0));
    }

    #[test]
    fn gain_matches_paper_formula() {
        // g = ((3/280) / (4π·250))² at 250 m.
        let wl = 3.0 / 280.0;
        let g = path_loss_gain(wl, 250.0);
        let expect = (wl / (4.0 * std::f64::consts::PI * 250.0)).powi(2);
        assert!((g - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn min_distance_clamped() {
        let wl = 3.0 / 280.0;
        assert_eq!(path_loss_gain(wl, 0.0), path_loss_gain(wl, 0.5));
    }

    #[test]
    fn rate_monotone_in_snr() {
        assert!(shannon_rate(1e6, 100.0) > shannon_rate(1e6, 10.0));
        assert!(shannon_rate(2e6, 10.0) > shannon_rate(1e6, 10.0));
        assert_eq!(shannon_rate(1e6, 0.0), 0.0);
    }

    #[test]
    fn channel_tables_consistent() {
        let t = topo();
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        for n in 0..t.num_ues() {
            for m in 0..t.num_edges() {
                let s = snr(&t.params, &t.ues[n], &t.edges[m], t.params.ue_bandwidth_hz);
                assert!((ch.snr_of(n, m) - s).abs() / s < 1e-9);
                let r = shannon_rate(t.params.ue_bandwidth_hz, s);
                assert!((ch.rate_of(n, m) - r).abs() / r < 1e-9);
                assert!(ch.rate_of(n, m) > 0.0);
            }
        }
    }

    #[test]
    fn equal_share_rate_decreases_with_more_ues() {
        let t = topo();
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        let r1 = ch.rate_equal_share(&t.params, 0, 0, 1);
        let r10 = ch.rate_equal_share(&t.params, 0, 0, 10);
        assert!(r1 > r10, "{r1} vs {r10}");
    }

    #[test]
    fn log_distance_decays_faster_than_free_space() {
        let wl = 3.0 / 280.0;
        let model = crate::net::topology::PathLossModel::LogDistance {
            exponent: 3.5,
            ref_dist_m: 10.0,
        };
        // Equal at the reference distance...
        let g_ref = model_gain(model, wl, 10.0);
        assert!((g_ref - path_loss_gain(wl, 10.0)).abs() / g_ref < 1e-12);
        // ...and below free space beyond it.
        assert!(model_gain(model, wl, 200.0) < path_loss_gain(wl, 200.0));
        // Monotone decreasing.
        assert!(model_gain(model, wl, 100.0) > model_gain(model, wl, 400.0));
    }

    #[test]
    fn rayleigh_fading_is_seeded_and_unit_mean() {
        let mut params = SystemParams::default();
        params.fading = crate::net::topology::FadingModel::Rayleigh { seed: 9 };
        let t = Topology::sample(&params, 2, 400, 1);
        let faded1 = Channel::compute(&params, &t.ues, &t.edges);
        let faded2 = Channel::compute(&params, &t.ues, &t.edges);
        assert_eq!(faded1.gain, faded2.gain, "same seed, same fading");
        let mut base = params.clone();
        base.fading = crate::net::topology::FadingModel::None;
        let clean = Channel::compute(&base, &t.ues, &t.edges);
        // Fading is multiplicative with unit mean: the gain ratios must
        // average close to 1 over many links.
        let ratios: Vec<f64> = faded1
            .gain
            .iter()
            .zip(&clean.gain)
            .map(|(f, c)| f / c)
            .collect();
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((mean - 1.0).abs() < 0.1, "mean fading power {mean}");
        assert!(ratios.iter().any(|&r| r < 0.5) && ratios.iter().any(|&r| r > 1.5));
    }

    #[test]
    fn recompute_ue_matches_full_compute() {
        let t = topo();
        let mut moved = t.clone();
        moved.ues[4].pos = crate::net::Position { x: 77.0, y: 410.0 };
        // Full recompute on the moved topology is the reference.
        let reference = Channel::compute(&moved.params, &moved.ues, &moved.edges);
        // Incremental: start from the original table, patch one row.
        let mut incremental = Channel::compute(&t.params, &t.ues, &t.edges);
        incremental.recompute_ue(&moved.params, &moved.ues[4], &moved.edges);
        assert_eq!(incremental.gain, reference.gain);
        assert_eq!(incremental.snr, reference.snr);
        assert_eq!(incremental.rate_bps, reference.rate_bps);
    }

    #[test]
    fn row_accessors_match_scalar_lookups() {
        let t = topo();
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        for n in [0usize, 7, 29] {
            for m in 0..t.num_edges() {
                assert_eq!(ch.gain_row(n)[m].to_bits(), ch.gain_of(n, m).to_bits());
                assert_eq!(ch.snr_row(n)[m].to_bits(), ch.snr_of(n, m).to_bits());
                assert_eq!(ch.rate_row(n)[m].to_bits(), ch.rate_of(n, m).to_bits());
            }
        }
    }

    #[test]
    fn realistic_magnitudes() {
        // At ~250 m, 1 MHz, 10 dBm the uplink should land in the single-
        // digit Mbit/s range — the regime the paper's latency numbers live in.
        let t = topo();
        let ch = Channel::compute(&t.params, &t.ues, &t.edges);
        let r = ch.rate_of(0, 0);
        assert!(r > 1e5 && r < 1e8, "rate {r}");
    }
}
