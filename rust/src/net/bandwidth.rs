//! Bandwidth allocation policies for constraint (13e).
//!
//! The paper states both that "the bandwidth is equally allocated to all
//! the UEs associated with the edge server" (§III-A.2) and that the
//! association algorithms reason about a fixed per-UE block B_n with the
//! cap `Σ_n χ_{n,m} B_n ≤ B` (Algorithm 3's `B/B_n` comparisons). Both
//! policies are implemented; scenarios pick one.

use super::topology::SystemParams;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BandwidthPolicy {
    /// Every associated UE gets B / |N_m| (paper §III-A.2).
    EqualShare,
    /// Every UE gets a fixed block B_n; an edge hosts at most B/B_n UEs
    /// (the capacity semantics Algorithm 3 uses).
    FixedPerUe,
}

impl BandwidthPolicy {
    /// Bandwidth (Hz) each UE gets when `k` UEs share edge `m`'s band.
    pub fn per_ue_hz(&self, params: &SystemParams, k: usize) -> f64 {
        match self {
            BandwidthPolicy::EqualShare => params.edge_bandwidth_hz / k.max(1) as f64,
            BandwidthPolicy::FixedPerUe => params.ue_bandwidth_hz,
        }
    }

    /// Max UEs an edge can host under this policy (usize::MAX = unbounded).
    pub fn capacity(&self, params: &SystemParams) -> usize {
        match self {
            BandwidthPolicy::EqualShare => usize::MAX,
            BandwidthPolicy::FixedPerUe => params.edge_capacity(),
        }
    }

    /// Check constraint (13e) for an edge hosting `k` UEs.
    pub fn feasible(&self, params: &SystemParams, k: usize) -> bool {
        match self {
            BandwidthPolicy::EqualShare => true,
            BandwidthPolicy::FixedPerUe => {
                k as f64 * params.ue_bandwidth_hz <= params.edge_bandwidth_hz + 1e-9
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_share_splits() {
        let p = SystemParams::default();
        let pol = BandwidthPolicy::EqualShare;
        assert_eq!(pol.per_ue_hz(&p, 4), p.edge_bandwidth_hz / 4.0);
        assert_eq!(pol.per_ue_hz(&p, 0), p.edge_bandwidth_hz);
        assert!(pol.feasible(&p, 10_000));
    }

    #[test]
    fn fixed_caps_at_capacity() {
        let p = SystemParams::default(); // 20 MHz / 1 MHz => 20
        let pol = BandwidthPolicy::FixedPerUe;
        assert_eq!(pol.capacity(&p), 20);
        assert!(pol.feasible(&p, 20));
        assert!(!pol.feasible(&p, 21));
        assert_eq!(pol.per_ue_hz(&p, 7), p.ue_bandwidth_hz);
    }
}
