//! Algorithm 2 — the paper's iterative Lagrangian/KKT solver.
//!
//! Implements the paper faithfully: the closed forms (31)/(32) for
//! (a*, b*) given the dual variables, the slack recomputation (33)/(34),
//! the subgradients (36) and the projection updates (37), with two
//! stabilizations recorded in EXPERIMENTS.md (§Deviations):
//!
//! 1. The paper writes `λ(t+1) = λ(t) − η∇λ(t)` — *descent* on the dual,
//!    which diverges; dual maximization requires *ascent* followed by
//!    projection onto the nonnegative orthant. We ascend
//!    (`λ ← max(0, λ + η∇λ)`), the standard subgradient-projection step.
//! 2. (a*, b*) from (31)/(32) are clamped to the feasible box
//!    `[1, a_max] x [1, b_max]` and guarded against the degenerate
//!    `Σ_m λ_m τ_m = 0` / `Σ_n μ_n t_n^cmp = 0` denominators at t = 0.
//!
//! Because the closed forms are only stationarity conditions of the
//! *relaxed* problem, the solver tracks the best primal-feasible (a, b)
//! seen so far and returns that (a standard primal-recovery practice for
//! dual methods); convergence is declared when the best objective stops
//! improving by more than ε₂ (Algorithm 2's stopping rule).

use crate::delay::DelayInstance;

/// Convergence trace of one run (consumed by `benches/alg2_convergence.rs`).
#[derive(Debug, Clone, Default)]
pub struct SubgradientTrace {
    /// Best primal objective after each iteration.
    pub best_objective: Vec<f64>,
    /// Raw (a, b) iterate per iteration.
    pub iterates: Vec<(f64, f64)>,
    /// Dual-variable norms per iteration (‖λ‖₁, ‖μ‖₁).
    pub dual_norms: Vec<(f64, f64)>,
}

#[derive(Debug, Clone)]
pub struct SubgradientSolver {
    /// Initial step size η₀; the schedule is η₀/√t.
    pub eta0: f64,
    /// Stopping accuracy ε₂ on the best objective.
    pub eps2: f64,
    /// Hard iteration cap K.
    pub max_iters: usize,
    /// Feasible box (mirrors `SolveOptions`).
    pub a_max: f64,
    pub b_max: f64,
    /// Stabilization 3: polish the best dual-recovered iterate with two
    /// primal coordinate-descent line searches before returning. The raw
    /// (unpolished) objective is preserved in `raw_objective` so the
    /// Algorithm-2 optimality gap stays measurable
    /// (`benches/alg2_convergence.rs`).
    pub polish: bool,
}

impl Default for SubgradientSolver {
    fn default() -> Self {
        SubgradientSolver {
            eta0: 0.5,
            eps2: 1e-6,
            max_iters: 2000,
            a_max: 200.0,
            b_max: 100.0,
            polish: true,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SubgradientResult {
    pub a: f64,
    pub b: f64,
    pub objective: f64,
    /// Best objective reached by the pure dual iteration (before polish).
    pub raw_objective: f64,
    pub iterations: usize,
    pub trace: SubgradientTrace,
}

impl SubgradientSolver {
    pub fn solve(&self, inst: &DelayInstance) -> SubgradientResult {
        let m_edges = inst.per_edge.len();
        let n_ues = inst.num_ues();
        assert!(m_edges > 0 && n_ues > 0, "empty instance");

        // Dual variables: λ_m per edge, μ_n per UE (flattened edge-major).
        let mut lambda = vec![1.0 / m_edges as f64; m_edges];
        let mut mu = vec![1.0 / n_ues as f64; n_ues];

        // Primal iterate.
        let (mut a, mut b) = (1.0f64, 1.0f64);
        let mut trace = SubgradientTrace::default();
        let (mut best_a, mut best_b, mut best_j) = (a, b, inst.total_time(a, b));

        let mut stall = 0usize;
        let mut iters = 0usize;
        for t in 1..=self.max_iters {
            iters = t;
            // --- (33): τ_m at the current a.
            let taus = inst.taus(a);
            // --- (34): T at the current (a, b).
            let t_cap = inst.round_time(a, b);

            // Σ_m λ_m τ_m and Σ_n μ_n t_n^cmp.
            let s_lambda_tau: f64 = lambda.iter().zip(&taus).map(|(l, t)| l * t).sum();
            let s_mu_cmp: f64 = {
                let mut acc = 0.0;
                let mut idx = 0;
                for e in &inst.per_edge {
                    for &(cmp, _) in &e.ue {
                        acc += mu[idx] * cmp;
                        idx += 1;
                    }
                }
                acc
            };

            // --- (31): a* = ζ ln( Σλτ / (ζ Σμ t_cmp) + 1 ).
            if s_lambda_tau > 0.0 && s_mu_cmp > 0.0 {
                a = (inst.zeta * ((s_lambda_tau / (inst.zeta * s_mu_cmp)) + 1.0).ln())
                    .clamp(1.0, self.a_max);
            }

            // --- (32): b* with A = C·T·ln(1/ε), Y = 1 − e^{−a/ζ}.
            let cap_a = inst.c_const * t_cap * (1.0 / inst.eps).ln();
            let y = 1.0 - (-a / inst.zeta).exp();
            if s_lambda_tau > 0.0 && y > 0.0 && cap_a > 0.0 {
                let disc = 4.0 * cap_a * y * s_lambda_tau + cap_a * cap_a * y * y;
                let frac = (cap_a * y - disc.sqrt()) / (2.0 * s_lambda_tau);
                let arg = frac + 1.0;
                if arg > 0.0 && arg < 1.0 {
                    b = (inst.gamma * arg.ln() / (-y)).clamp(1.0, self.b_max);
                }
            }

            // Primal recovery: keep the best feasible iterate.
            let j = inst.total_time(a, b);
            if j < best_j - self.eps2 {
                (best_a, best_b, best_j) = (a, b, j);
                stall = 0;
            } else {
                if j < best_j {
                    (best_a, best_b, best_j) = (a, b, j);
                }
                stall += 1;
            }

            // --- (36)/(37): subgradient ascent with projection.
            let eta = self.eta0 / (t as f64).sqrt();
            let taus_new = inst.taus(a);
            let t_new = inst.round_time(a, b);
            for (m, l) in lambda.iter_mut().enumerate() {
                let g = b * taus_new[m] + inst.per_edge[m].backhaul_s - t_new;
                *l = (*l + eta * g).max(0.0);
            }
            {
                let mut idx = 0;
                for (m, e) in inst.per_edge.iter().enumerate() {
                    for &(cmp, com) in &e.ue {
                        let g = a * cmp + com - taus_new[m];
                        mu[idx] = (mu[idx] + eta * g).max(0.0);
                        idx += 1;
                    }
                }
            }
            // Keep duals from collapsing to all-zero (λ=μ=0 freezes (31)).
            let l1: f64 = lambda.iter().sum();
            if l1 < 1e-12 {
                lambda.iter_mut().for_each(|l| *l = 1.0 / m_edges as f64);
            }
            let m1: f64 = mu.iter().sum();
            if m1 < 1e-12 {
                mu.iter_mut().for_each(|v| *v = 1.0 / n_ues as f64);
            }

            trace.best_objective.push(best_j);
            trace.iterates.push((a, b));
            trace.dual_norms.push((lambda.iter().sum(), mu.iter().sum()));

            // Stopping rule: ε₂ accuracy (no improvement for a window).
            if stall >= 50 {
                break;
            }
        }

        let raw_objective = best_j;
        if self.polish {
            let (mut a, mut b, mut obj) = (best_a, best_b, best_j);
            for _ in 0..8 {
                let (na, _) =
                    super::exact::line_min(&|x| inst.total_time(x, b), 1.0, self.a_max, 1e-4);
                let (nb, nv) =
                    super::exact::line_min(&|x| inst.total_time(na, x), 1.0, self.b_max, 1e-4);
                let gain = obj - nv;
                if nv < obj {
                    (a, b, obj) = (na, nb, nv);
                }
                if gain < 1e-10 {
                    break;
                }
            }
            (best_a, best_b, best_j) = (a, b, obj);
        }

        SubgradientResult {
            a: best_a,
            b: best_b,
            objective: best_j,
            raw_objective,
            iterations: iters,
            trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayInstance, EdgeDelays};
    use crate::opt::exact::{solve_continuous, SolveOptions};

    fn synthetic(eps: f64) -> DelayInstance {
        DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.005, 0.3), (0.008, 0.2), (0.003, 0.5)],
                    backhaul_s: 0.01,
                },
                EdgeDelays {
                    ue: vec![(0.004, 0.25), (0.010, 0.15)],
                    backhaul_s: 0.012,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps,
        }
    }

    #[test]
    fn converges_near_exact_solver() {
        let inst = synthetic(0.25);
        let exact = solve_continuous(&inst, &SolveOptions::default());
        let res = SubgradientSolver::default().solve(&inst);
        assert!(
            res.objective <= exact.objective * 1.02 + 1e-9,
            "alg2 {} vs exact {}",
            res.objective,
            exact.objective
        );
        // The raw dual iteration is weaker but must stay in the ballpark.
        assert!(
            res.raw_objective <= exact.objective * 2.0,
            "raw alg2 {} vs exact {}",
            res.raw_objective,
            exact.objective
        );
    }

    #[test]
    fn objective_trace_monotone_nonincreasing() {
        let inst = synthetic(0.1);
        let res = SubgradientSolver::default().solve(&inst);
        for w in res.trace.best_objective.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn iterates_stay_in_box() {
        let inst = synthetic(0.25);
        let solver = SubgradientSolver::default();
        let res = solver.solve(&inst);
        for &(a, b) in &res.trace.iterates {
            assert!((1.0..=solver.a_max).contains(&a));
            assert!((1.0..=solver.b_max).contains(&b));
        }
    }

    #[test]
    fn duals_stay_nonnegative() {
        let inst = synthetic(0.25);
        let res = SubgradientSolver::default().solve(&inst);
        for &(l1, m1) in &res.trace.dual_norms {
            assert!(l1 >= 0.0 && m1 >= 0.0);
        }
    }

    #[test]
    fn terminates_before_cap_on_easy_instance() {
        let inst = synthetic(0.5);
        let res = SubgradientSolver::default().solve(&inst);
        assert!(res.iterations < 2000, "took {} iters", res.iterations);
    }
}
