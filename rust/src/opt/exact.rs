//! Reference solvers for sub-problem I, plus the warm-started variants
//! the scenario engine re-runs every epoch (consecutive optima of a
//! slowly-drifting world are close, so the previous `(a*, b*)` is an
//! excellent incumbent).

use crate::delay::{DelayInstance, MaintainedInstance};

/// Options shared by the solvers.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Search box for a (local iterations).
    pub a_max: f64,
    /// Search box for b (edge iterations).
    pub b_max: f64,
    /// Golden-section tolerance (absolute, in iterations).
    pub tol: f64,
    /// Coarse grid resolution used to seed the golden-section search.
    pub grid: usize,
    /// Half-width of the neighborhood the warm integer solve scans around
    /// the previous optimum before the (pruned) exactness sweep.
    pub warm_window: u64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            a_max: 200.0,
            b_max: 100.0,
            tol: 1e-4,
            grid: 32,
            warm_window: 8,
        }
    }
}

/// Continuous solution of the relaxed problem.
#[derive(Debug, Clone, Copy)]
pub struct Solution {
    pub a: f64,
    pub b: f64,
    pub objective: f64,
    pub rounds: f64,
    pub round_time: f64,
}

/// Integer solution (constraint (13f)) under the ⌈R⌉ objective.
#[derive(Debug, Clone, Copy)]
pub struct IntSolution {
    pub a: u64,
    pub b: u64,
    pub objective: f64,
    pub rounds: u64,
    pub round_time: f64,
}

/// Golden-section search for the minimum of a unimodal `f` on [lo, hi].
pub(crate) fn golden_min<F: Fn(f64) -> f64>(
    f: &F,
    mut lo: f64,
    mut hi: f64,
    tol: f64,
) -> (f64, f64) {
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let mut x1 = hi - INV_PHI * (hi - lo);
    let mut x2 = lo + INV_PHI * (hi - lo);
    let (mut f1, mut f2) = (f(x1), f(x2));
    while hi - lo > tol {
        if f1 <= f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - INV_PHI * (hi - lo);
            f1 = f(x1);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + INV_PHI * (hi - lo);
            f2 = f(x2);
        }
    }
    let x = 0.5 * (lo + hi);
    (x, f(x))
}

/// Robust 1-D minimizer: coarse log-spaced scan to bracket the minimum,
/// then golden-section inside the bracketing cell. Tolerates the mild
/// non-unimodality the paper's Lemma-2 proof glosses over (the τ_m max
/// makes T piecewise, so R·T can have shallow secondary dips).
pub(crate) fn line_min<F: Fn(f64) -> f64>(f: &F, lo: f64, hi: f64, tol: f64) -> (f64, f64) {
    line_min_scanned(f, lo, hi, tol, 64)
}

/// [`line_min`] with a configurable scan density — the warm path uses a
/// sparse scan over a shrunken bracket.
pub(crate) fn line_min_scanned<F: Fn(f64) -> f64>(
    f: &F,
    lo: f64,
    hi: f64,
    tol: f64,
    scan: usize,
) -> (f64, f64) {
    let scan = scan.max(2);
    let ratio = (hi / lo).max(1.0 + 1e-12);
    let xs: Vec<f64> = (0..scan)
        .map(|i| lo * ratio.powf(i as f64 / (scan - 1) as f64))
        .collect();
    let mut best_i = 0;
    let mut best_v = f64::INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        let v = f(x);
        if v < best_v {
            best_v = v;
            best_i = i;
        }
    }
    let blo = xs[best_i.saturating_sub(1)];
    let bhi = xs[(best_i + 1).min(scan - 1)];
    let (x, v) = golden_min(f, blo, bhi, tol);
    if v <= best_v {
        (x, v)
    } else {
        (xs[best_i], best_v)
    }
}

/// Minimize `J(a,b)` on the continuous box `[1, a_max] x [1, b_max]` by
/// seeded coordinate descent with robust line searches — validated against
/// a dense grid in the tests. (The paper proves the relaxed objective is
/// convex, Lemmas 1–3; the scan-then-golden line search also survives the
/// piecewise kinks of τ_m that the proof idealizes away.)
pub fn solve_continuous(inst: &DelayInstance, opts: &SolveOptions) -> Solution {
    let j = |a: f64, b: f64| inst.total_time(a, b);

    // Coarse grid seeding (log-spaced — the interesting region hugs the
    // lower-left of the box).
    let gp = |i: usize, n: usize, hi: f64| {
        let t = i as f64 / (n - 1) as f64;
        (hi.ln() * t).exp() // 1 .. hi log-spaced
    };
    let (mut best_a, mut best_b, mut best_j) = (1.0, 1.0, f64::INFINITY);
    for i in 0..opts.grid {
        let a = gp(i, opts.grid, opts.a_max);
        for k in 0..opts.grid {
            let b = gp(k, opts.grid, opts.b_max);
            let v = j(a, b);
            if v < best_j {
                (best_a, best_b, best_j) = (a, b, v);
            }
        }
    }

    // Coordinate descent with robust line searches.
    let (mut a, mut b, mut obj) = (best_a, best_b, best_j);
    for _ in 0..64 {
        let (na, _) = line_min(&|x| j(x, b), 1.0, opts.a_max, opts.tol);
        let (nb, nv) = line_min(&|x| j(na, x), 1.0, opts.b_max, opts.tol);
        let improved = obj - nv;
        if nv < obj {
            (a, b, obj) = (na, nb, nv);
        }
        if improved < 1e-10 {
            break;
        }
    }
    Solution {
        a,
        b,
        objective: obj,
        rounds: crate::delay::cloud_rounds(a, b, inst.eps, inst.c_const, inst.gamma, inst.zeta),
        round_time: inst.round_time(a, b),
    }
}

/// Shared core of the exact integer solvers: a canonical-order scan with
/// exactness-preserving pruning, optionally preceded by a warm
/// neighborhood pass around a previous optimum.
///
/// Pruning rests on `J(a,b) = ⌈R⌉·T ≥ T ≥ b·τ_max(a) + w ≥ τ_max(a)`,
/// with `τ_max` nondecreasing in `a`:
///
/// * inner loop: once `b·τ_max(a) ≥ best`, no larger `b` can win;
/// * outer loop: once `τ_max(a) ≥ best`, no larger `a` can win.
///
/// Both bounds only skip cells provably no better than the incumbent and
/// the incumbent updates on strict improvement, so the returned optimum
/// is the global one regardless of the warm seed — warm starting changes
/// how much gets pruned, never the answer (up to exact f64 objective
/// ties, where the warm pass may return a different cell of equal value).
pub(crate) fn integer_scan<J, T>(
    j: J,
    tau_max: T,
    a_max: u64,
    b_max: u64,
    warm: Option<(u64, u64, u64)>,
) -> (u64, u64, f64)
where
    J: Fn(u64, u64) -> f64,
    T: Fn(u64) -> f64,
{
    // Memberless instance (a fully-churned world): T ≡ 0, so J ≡ 0 and
    // every cell ties. Return the canonical corner so warm and cold
    // trajectories agree.
    let corner = j(1, 1);
    if corner <= 0.0 {
        return (1, 1, corner);
    }
    let (mut best_a, mut best_b, mut best_j) = (1u64, 1u64, f64::INFINITY);
    if let Some((a0, b0, w)) = warm {
        let (a_lo, a_hi) = (a0.saturating_sub(w).max(1), (a0 + w).min(a_max));
        let (b_lo, b_hi) = (b0.saturating_sub(w).max(1), (b0 + w).min(b_max));
        for a in a_lo..=a_hi {
            let tm = tau_max(a);
            for b in b_lo..=b_hi {
                if (b as f64) * tm >= best_j {
                    break;
                }
                let v = j(a, b);
                if v < best_j {
                    (best_a, best_b, best_j) = (a, b, v);
                }
            }
        }
    }
    for a in 1..=a_max {
        let tm = tau_max(a);
        if tm >= best_j {
            break;
        }
        for b in 1..=b_max {
            if (b as f64) * tm >= best_j {
                break;
            }
            let v = j(a, b);
            if v < best_j {
                (best_a, best_b, best_j) = (a, b, v);
            }
        }
    }
    (best_a, best_b, best_j)
}

fn int_solution(inst: &DelayInstance, a: u64, b: u64, objective: f64) -> IntSolution {
    IntSolution {
        a,
        b,
        objective,
        rounds: crate::delay::cloud_rounds_int(
            a as f64,
            b as f64,
            inst.eps,
            inst.c_const,
            inst.gamma,
            inst.zeta,
        ),
        round_time: inst.round_time(a as f64, b as f64),
    }
}

/// Exhaustive integer solve under the protocol-real objective
/// `⌈R(a,b,ε)⌉ · T(a,b)` (see `delay` module docs for why the ceiling is
/// what makes the Fig. 2 ε-sweep meaningful).
pub fn solve_integer(inst: &DelayInstance, opts: &SolveOptions) -> IntSolution {
    let (a, b, objective) = integer_scan(
        |a, b| inst.total_time_int(a as f64, b as f64),
        |a| inst.tau_max(a as f64),
        (opts.a_max as u64).max(1),
        (opts.b_max as u64).max(1),
        None,
    );
    int_solution(inst, a, b, objective)
}

/// Warm-started exact integer solve: a bounded neighborhood scan around
/// the previous epoch's optimum seeds the incumbent, then the pruned
/// exactness sweep confirms (or escapes) it. Guaranteed to return the
/// same optimum as [`solve_integer`] — warm starting is a pure speedup.
pub fn solve_integer_warm(
    inst: &DelayInstance,
    opts: &SolveOptions,
    prev: &IntSolution,
) -> IntSolution {
    let a_max = (opts.a_max as u64).max(1);
    let b_max = (opts.b_max as u64).max(1);
    let (a, b, objective) = integer_scan(
        |a, b| inst.total_time_int(a as f64, b as f64),
        |a| inst.tau_max(a as f64),
        a_max,
        b_max,
        Some((
            prev.a.clamp(1, a_max),
            prev.b.clamp(1, b_max),
            opts.warm_window.max(1),
        )),
    );
    int_solution(inst, a, b, objective)
}

/// Exact integer solve over a [`MaintainedInstance`]: evaluates the
/// objective through the cached per-edge Pareto frontiers (bitwise equal
/// to the full-scan objective) and optionally warm-starts from the
/// previous epoch's `(a*, b*)`. This is the scenario engine's per-epoch
/// re-solve path.
pub fn solve_integer_maintained(
    maintained: &mut MaintainedInstance,
    opts: &SolveOptions,
    warm: Option<(u64, u64)>,
) -> IntSolution {
    maintained.refresh();
    let m: &MaintainedInstance = maintained;
    let a_max = (opts.a_max as u64).max(1);
    let b_max = (opts.b_max as u64).max(1);
    let (a, b, objective) = integer_scan(
        |a, b| m.total_time_int(a as f64, b as f64),
        |a| m.tau_max(a as f64),
        a_max,
        b_max,
        warm.map(|(a0, b0)| (a0.clamp(1, a_max), b0.clamp(1, b_max), opts.warm_window.max(1))),
    );
    int_solution(m.instance(), a, b, objective)
}

/// Warm-started continuous solve with a cold-fallback check; see
/// [`solve_warm`]. Returns the solution and whether the cold grid solve
/// ran (the "warm objective regressed" fallback).
pub fn solve_warm_checked(
    inst: &DelayInstance,
    opts: &SolveOptions,
    prev: &Solution,
) -> (Solution, bool) {
    let j = |a: f64, b: f64| inst.total_time(a, b);
    // Coordinate descent seeded at the previous optimum, with shrunken
    // log-brackets and a sparse scan (the optimum of a drifted world is
    // close, so a wide bracket and dense scan are wasted work).
    const BRACKET: f64 = 4.0;
    const SCAN: usize = 16;
    let (mut a, mut b) = (prev.a.clamp(1.0, opts.a_max), prev.b.clamp(1.0, opts.b_max));
    let mut obj = j(a, b);
    for _ in 0..32 {
        let (na, _) = line_min_scanned(
            &|x| j(x, b),
            (a / BRACKET).max(1.0),
            (a * BRACKET).min(opts.a_max),
            opts.tol,
            SCAN,
        );
        let (nb, nv) = line_min_scanned(
            &|x| j(na, x),
            (b / BRACKET).max(1.0),
            (b * BRACKET).min(opts.b_max),
            opts.tol,
            SCAN,
        );
        let improved = obj - nv;
        if nv < obj {
            (a, b, obj) = (na, nb, nv);
        }
        if improved < 1e-10 {
            break;
        }
    }
    // Drift detector: a sparse log-spaced probe grid. Any probe beating
    // the warm optimum beyond round-off means the optimum jumped basins —
    // regress to the cold grid solve.
    let probes = (opts.grid / 4).max(4);
    let gp = |i: usize, n: usize, hi: f64| {
        let t = i as f64 / (n - 1) as f64;
        (hi.ln() * t).exp()
    };
    for i in 0..probes {
        for k in 0..probes {
            if j(gp(i, probes, opts.a_max), gp(k, probes, opts.b_max)) < obj * (1.0 - 1e-9) {
                return (solve_continuous(inst, opts), true);
            }
        }
    }
    (
        Solution {
            a,
            b,
            objective: obj,
            rounds: crate::delay::cloud_rounds(a, b, inst.eps, inst.c_const, inst.gamma, inst.zeta),
            round_time: inst.round_time(a, b),
        },
        false,
    )
}

/// Warm-started continuous solve: coordinate descent seeded from the
/// previous epoch's `(a*, b*)` with a shrunken bracket, falling back to
/// the cold grid ([`solve_continuous`]) only when a sparse probe grid
/// shows the warm objective regressed (the optimum left the local basin).
/// Unlike the integer warm path this is tolerance-bounded, not exact: the
/// sparse bracket may land within `opts.tol`/probe-grid resolution of the
/// cold answer rather than on it.
pub fn solve_warm(inst: &DelayInstance, opts: &SolveOptions, prev: &Solution) -> Solution {
    solve_warm_checked(inst, opts, prev).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayInstance, EdgeDelays};

    /// A small synthetic instance with known structure.
    pub fn synthetic(eps: f64) -> DelayInstance {
        DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.005, 0.3), (0.008, 0.2), (0.003, 0.5)],
                    backhaul_s: 0.01,
                },
                EdgeDelays {
                    ue: vec![(0.004, 0.25), (0.010, 0.15)],
                    backhaul_s: 0.012,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps,
        }
    }

    #[test]
    fn continuous_beats_grid_corners() {
        let inst = synthetic(0.25);
        let sol = solve_continuous(&inst, &SolveOptions::default());
        for &(a, b) in &[(1.0, 1.0), (200.0, 100.0), (1.0, 100.0), (200.0, 1.0)] {
            assert!(sol.objective <= inst.total_time(a, b) + 1e-9);
        }
        assert!(sol.a >= 1.0 && sol.b >= 1.0);
    }

    #[test]
    fn continuous_matches_dense_grid() {
        let inst = synthetic(0.25);
        let sol = solve_continuous(&inst, &SolveOptions::default());
        // Dense grid cross-check over the feasible box (a, b >= 1 per the
        // relaxation of constraint (13f)).
        let mut best = f64::INFINITY;
        for ai in 2..=400 {
            for bi in 2..=200 {
                best = best.min(inst.total_time(ai as f64 * 0.5, bi as f64 * 0.5));
            }
        }
        assert!(
            sol.objective <= best * 1.001 + 1e-12,
            "golden {} vs grid {}",
            sol.objective,
            best
        );
    }

    #[test]
    fn integer_solution_feasible_and_consistent() {
        let inst = synthetic(0.25);
        let sol = solve_integer(&inst, &SolveOptions::default());
        assert!(sol.a >= 1 && sol.b >= 1);
        let direct = inst.total_time_int(sol.a as f64, sol.b as f64);
        assert!((direct - sol.objective).abs() < 1e-12);
    }

    #[test]
    fn integer_exhaustive_is_exact() {
        let inst = synthetic(0.1);
        let opts = SolveOptions {
            a_max: 60.0,
            b_max: 40.0,
            ..Default::default()
        };
        let sol = solve_integer(&inst, &opts);
        // Brute force without the early-exit pruning.
        let mut best = f64::INFINITY;
        for a in 1..=60u64 {
            for b in 1..=40u64 {
                best = best.min(inst.total_time_int(a as f64, b as f64));
            }
        }
        assert!((sol.objective - best).abs() < 1e-12);
    }

    #[test]
    fn tighter_eps_costs_more_time() {
        let opts = SolveOptions::default();
        let loose = solve_integer(&synthetic(0.5), &opts);
        let tight = solve_integer(&synthetic(0.05), &opts);
        assert!(tight.objective > loose.objective);
        assert!(tight.rounds >= loose.rounds);
    }

    #[test]
    fn warm_integer_tracks_cold_under_drift() {
        // The warm path is exact by construction: over a drifting
        // instance it must reproduce the cold optimum cell-for-cell.
        let mut inst = synthetic(0.25);
        let opts = SolveOptions::default();
        let mut prev = solve_integer(&inst, &opts);
        for step in 0..12usize {
            let wobble = if step % 2 == 0 { 1.02 } else { 0.985 };
            for e in &mut inst.per_edge {
                for ue in &mut e.ue {
                    ue.1 *= wobble;
                }
            }
            let cold = solve_integer(&inst, &opts);
            let warm = solve_integer_warm(&inst, &opts, &prev);
            assert_eq!((warm.a, warm.b), (cold.a, cold.b), "step {step}");
            assert_eq!(warm.objective.to_bits(), cold.objective.to_bits());
            prev = warm;
        }
    }

    #[test]
    fn warm_integer_escapes_a_bad_seed() {
        // A garbage incumbent must not trap the warm solver: the
        // exactness sweep recovers the global optimum.
        let inst = synthetic(0.25);
        let opts = SolveOptions::default();
        let cold = solve_integer(&inst, &opts);
        let junk = IntSolution {
            a: 200,
            b: 100,
            objective: f64::INFINITY,
            rounds: 1,
            round_time: 0.0,
        };
        let warm = solve_integer_warm(&inst, &opts, &junk);
        assert_eq!((warm.a, warm.b), (cold.a, cold.b));
        assert_eq!(warm.objective.to_bits(), cold.objective.to_bits());
    }

    #[test]
    fn memberless_instance_solves_to_canonical_corner() {
        // A fully-churned world has J ≡ 0; warm and cold must agree on
        // the canonical (1, 1) so re-solve trajectories stay identical.
        let inst = DelayInstance {
            per_edge: vec![EdgeDelays {
                ue: vec![],
                backhaul_s: 4.0,
            }],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps: 0.25,
        };
        let opts = SolveOptions::default();
        let cold = solve_integer(&inst, &opts);
        assert_eq!((cold.a, cold.b, cold.objective), (1, 1, 0.0));
        let warm = solve_integer_warm(&inst, &opts, &cold);
        assert_eq!((warm.a, warm.b, warm.objective), (1, 1, 0.0));
        let seeded = solve_integer_warm(
            &inst,
            &opts,
            &IntSolution {
                a: 40,
                b: 20,
                objective: 0.0,
                rounds: 1,
                round_time: 0.0,
            },
        );
        assert_eq!((seeded.a, seeded.b), (1, 1));
    }

    #[test]
    fn warm_continuous_close_to_cold_and_falls_back() {
        let mut inst = synthetic(0.25);
        let opts = SolveOptions::default();
        let mut prev = solve_continuous(&inst, &opts);
        // Gentle drift: warm stays within a whisker of cold.
        for _ in 0..6 {
            for e in &mut inst.per_edge {
                for ue in &mut e.ue {
                    ue.1 *= 1.015;
                }
            }
            let cold = solve_continuous(&inst, &opts);
            let (warm, _fell_back) = solve_warm_checked(&inst, &opts, &prev);
            assert!(
                warm.objective <= cold.objective * (1.0 + 1e-6) + 1e-12,
                "warm {} vs cold {}",
                warm.objective,
                cold.objective
            );
            prev = warm;
        }
        // A hopeless seed triggers the probe-grid fallback and still
        // lands on (essentially) the cold answer.
        let junk = Solution {
            a: opts.a_max,
            b: opts.b_max,
            objective: f64::INFINITY,
            rounds: 1.0,
            round_time: 0.0,
        };
        let cold = solve_continuous(&inst, &opts);
        let warm = solve_warm(&inst, &opts, &junk);
        assert!(warm.objective <= cold.objective * (1.0 + 1e-6) + 1e-12);
    }

    #[test]
    fn integer_close_to_continuous_relaxation() {
        let inst = synthetic(0.25);
        let c = solve_continuous(&inst, &SolveOptions::default());
        let i = solve_integer(&inst, &SolveOptions::default());
        // ⌈R⌉ ≥ R so the integer objective is ≥ the relaxation, but the
        // rounding gap should stay modest on this smooth instance.
        assert!(i.objective >= c.objective - 1e-9);
        assert!(i.objective <= 1.5 * c.objective);
    }
}
