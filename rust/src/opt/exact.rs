//! Reference solvers for sub-problem I.

use crate::delay::DelayInstance;

/// Options shared by the solvers.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Search box for a (local iterations).
    pub a_max: f64,
    /// Search box for b (edge iterations).
    pub b_max: f64,
    /// Golden-section tolerance (absolute, in iterations).
    pub tol: f64,
    /// Coarse grid resolution used to seed the golden-section search.
    pub grid: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            a_max: 200.0,
            b_max: 100.0,
            tol: 1e-4,
            grid: 32,
        }
    }
}

/// Continuous solution of the relaxed problem.
#[derive(Debug, Clone, Copy)]
pub struct Solution {
    pub a: f64,
    pub b: f64,
    pub objective: f64,
    pub rounds: f64,
    pub round_time: f64,
}

/// Integer solution (constraint (13f)) under the ⌈R⌉ objective.
#[derive(Debug, Clone, Copy)]
pub struct IntSolution {
    pub a: u64,
    pub b: u64,
    pub objective: f64,
    pub rounds: u64,
    pub round_time: f64,
}

/// Golden-section search for the minimum of a unimodal `f` on [lo, hi].
pub(crate) fn golden_min<F: Fn(f64) -> f64>(
    f: &F,
    mut lo: f64,
    mut hi: f64,
    tol: f64,
) -> (f64, f64) {
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let mut x1 = hi - INV_PHI * (hi - lo);
    let mut x2 = lo + INV_PHI * (hi - lo);
    let (mut f1, mut f2) = (f(x1), f(x2));
    while hi - lo > tol {
        if f1 <= f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - INV_PHI * (hi - lo);
            f1 = f(x1);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + INV_PHI * (hi - lo);
            f2 = f(x2);
        }
    }
    let x = 0.5 * (lo + hi);
    (x, f(x))
}

/// Robust 1-D minimizer: coarse log-spaced scan to bracket the minimum,
/// then golden-section inside the bracketing cell. Tolerates the mild
/// non-unimodality the paper's Lemma-2 proof glosses over (the τ_m max
/// makes T piecewise, so R·T can have shallow secondary dips).
pub(crate) fn line_min<F: Fn(f64) -> f64>(f: &F, lo: f64, hi: f64, tol: f64) -> (f64, f64) {
    const SCAN: usize = 64;
    let ratio = (hi / lo).max(1.0 + 1e-12);
    let xs: Vec<f64> = (0..SCAN)
        .map(|i| lo * ratio.powf(i as f64 / (SCAN - 1) as f64))
        .collect();
    let mut best_i = 0;
    let mut best_v = f64::INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        let v = f(x);
        if v < best_v {
            best_v = v;
            best_i = i;
        }
    }
    let blo = xs[best_i.saturating_sub(1)];
    let bhi = xs[(best_i + 1).min(SCAN - 1)];
    let (x, v) = golden_min(f, blo, bhi, tol);
    if v <= best_v {
        (x, v)
    } else {
        (xs[best_i], best_v)
    }
}

/// Minimize `J(a,b)` on the continuous box `[1, a_max] x [1, b_max]` by
/// seeded coordinate descent with robust line searches — validated against
/// a dense grid in the tests. (The paper proves the relaxed objective is
/// convex, Lemmas 1–3; the scan-then-golden line search also survives the
/// piecewise kinks of τ_m that the proof idealizes away.)
pub fn solve_continuous(inst: &DelayInstance, opts: &SolveOptions) -> Solution {
    let j = |a: f64, b: f64| inst.total_time(a, b);

    // Coarse grid seeding (log-spaced — the interesting region hugs the
    // lower-left of the box).
    let gp = |i: usize, n: usize, hi: f64| {
        let t = i as f64 / (n - 1) as f64;
        (hi.ln() * t).exp() // 1 .. hi log-spaced
    };
    let (mut best_a, mut best_b, mut best_j) = (1.0, 1.0, f64::INFINITY);
    for i in 0..opts.grid {
        let a = gp(i, opts.grid, opts.a_max);
        for k in 0..opts.grid {
            let b = gp(k, opts.grid, opts.b_max);
            let v = j(a, b);
            if v < best_j {
                (best_a, best_b, best_j) = (a, b, v);
            }
        }
    }

    // Coordinate descent with robust line searches.
    let (mut a, mut b, mut obj) = (best_a, best_b, best_j);
    for _ in 0..64 {
        let (na, _) = line_min(&|x| j(x, b), 1.0, opts.a_max, opts.tol);
        let (nb, nv) = line_min(&|x| j(na, x), 1.0, opts.b_max, opts.tol);
        let improved = obj - nv;
        if nv < obj {
            (a, b, obj) = (na, nb, nv);
        }
        if improved < 1e-10 {
            break;
        }
    }
    Solution {
        a,
        b,
        objective: obj,
        rounds: crate::delay::cloud_rounds(a, b, inst.eps, inst.c_const, inst.gamma, inst.zeta),
        round_time: inst.round_time(a, b),
    }
}

/// Exhaustive integer solve under the protocol-real objective
/// `⌈R(a,b,ε)⌉ · T(a,b)` (see `delay` module docs for why the ceiling is
/// what makes the Fig. 2 ε-sweep meaningful).
pub fn solve_integer(inst: &DelayInstance, opts: &SolveOptions) -> IntSolution {
    let a_max = opts.a_max as u64;
    let b_max = opts.b_max as u64;
    let (mut best_a, mut best_b, mut best_j) = (1u64, 1u64, f64::INFINITY);
    for a in 1..=a_max {
        // T(a,b) = max_m (b τ_m + w_m) is affine-increasing in b and
        // ⌈R⌉ is non-increasing in b, so scan b with early exit: once
        // b τ_min exceeds the incumbent objective no larger b can win.
        let taus = inst.taus(a as f64);
        let min_tau = taus.iter().cloned().fold(f64::INFINITY, f64::min);
        for b in 1..=b_max {
            if (b as f64) * min_tau >= best_j {
                break;
            }
            let v = inst.total_time_int(a as f64, b as f64);
            if v < best_j {
                (best_a, best_b, best_j) = (a, b, v);
            }
        }
    }
    IntSolution {
        a: best_a,
        b: best_b,
        objective: best_j,
        rounds: crate::delay::cloud_rounds_int(
            best_a as f64,
            best_b as f64,
            inst.eps,
            inst.c_const,
            inst.gamma,
            inst.zeta,
        ),
        round_time: inst.round_time(best_a as f64, best_b as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayInstance, EdgeDelays};

    /// A small synthetic instance with known structure.
    pub fn synthetic(eps: f64) -> DelayInstance {
        DelayInstance {
            per_edge: vec![
                EdgeDelays {
                    ue: vec![(0.005, 0.3), (0.008, 0.2), (0.003, 0.5)],
                    backhaul_s: 0.01,
                },
                EdgeDelays {
                    ue: vec![(0.004, 0.25), (0.010, 0.15)],
                    backhaul_s: 0.012,
                },
            ],
            gamma: 4.0,
            zeta: 6.0,
            c_const: 1.0,
            eps,
        }
    }

    #[test]
    fn continuous_beats_grid_corners() {
        let inst = synthetic(0.25);
        let sol = solve_continuous(&inst, &SolveOptions::default());
        for &(a, b) in &[(1.0, 1.0), (200.0, 100.0), (1.0, 100.0), (200.0, 1.0)] {
            assert!(sol.objective <= inst.total_time(a, b) + 1e-9);
        }
        assert!(sol.a >= 1.0 && sol.b >= 1.0);
    }

    #[test]
    fn continuous_matches_dense_grid() {
        let inst = synthetic(0.25);
        let sol = solve_continuous(&inst, &SolveOptions::default());
        // Dense grid cross-check over the feasible box (a, b >= 1 per the
        // relaxation of constraint (13f)).
        let mut best = f64::INFINITY;
        for ai in 2..=400 {
            for bi in 2..=200 {
                best = best.min(inst.total_time(ai as f64 * 0.5, bi as f64 * 0.5));
            }
        }
        assert!(
            sol.objective <= best * 1.001 + 1e-12,
            "golden {} vs grid {}",
            sol.objective,
            best
        );
    }

    #[test]
    fn integer_solution_feasible_and_consistent() {
        let inst = synthetic(0.25);
        let sol = solve_integer(&inst, &SolveOptions::default());
        assert!(sol.a >= 1 && sol.b >= 1);
        let direct = inst.total_time_int(sol.a as f64, sol.b as f64);
        assert!((direct - sol.objective).abs() < 1e-12);
    }

    #[test]
    fn integer_exhaustive_is_exact() {
        let inst = synthetic(0.1);
        let opts = SolveOptions {
            a_max: 60.0,
            b_max: 40.0,
            ..Default::default()
        };
        let sol = solve_integer(&inst, &opts);
        // Brute force without the early-exit pruning.
        let mut best = f64::INFINITY;
        for a in 1..=60u64 {
            for b in 1..=40u64 {
                best = best.min(inst.total_time_int(a as f64, b as f64));
            }
        }
        assert!((sol.objective - best).abs() < 1e-12);
    }

    #[test]
    fn tighter_eps_costs_more_time() {
        let opts = SolveOptions::default();
        let loose = solve_integer(&synthetic(0.5), &opts);
        let tight = solve_integer(&synthetic(0.05), &opts);
        assert!(tight.objective > loose.objective);
        assert!(tight.rounds >= loose.rounds);
    }

    #[test]
    fn integer_close_to_continuous_relaxation() {
        let inst = synthetic(0.25);
        let c = solve_continuous(&inst, &SolveOptions::default());
        let i = solve_integer(&inst, &SolveOptions::default());
        // ⌈R⌉ ≥ R so the integer objective is ≥ the relaxation, but the
        // rounding gap should stay modest on this smooth instance.
        assert!(i.objective >= c.objective - 1e-9);
        assert!(i.objective <= 1.5 * c.objective);
    }
}
