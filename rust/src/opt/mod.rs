//! Sub-problem I: optimal local-iteration count `a` and edge-iteration
//! count `b` (paper §IV-B/C).
//!
//! Three solvers over the same [`DelayInstance`] objective
//! `J(a,b) = R(a,b,ε) · T(a,b)`:
//!
//! * [`exact::solve_continuous`] — nested golden-section on the relaxed
//!   (continuous) problem, exploiting the convexity the paper proves in
//!   Lemmas 1–3. The reference the other solvers are validated against.
//! * [`exact::solve_integer`] — exhaustive scan over the integer grid
//!   (constraint (13f)) with the protocol-real ⌈R⌉ round count. The
//!   instance sizes of the paper (a ≤ ~100, b ≤ ~50) make this exact
//!   solver microseconds-fast, so it is also the production path.
//! * [`lagrangian::SubgradientSolver`] — the paper's Algorithm 2: KKT
//!   closed forms (31)/(32) for (a*, b*) inside a subgradient-projection
//!   loop on the Lagrange dual variables (36)/(37).
//!
//! **Warm starts** (the scenario engine's per-epoch re-solve path): a
//! slowly-drifting world keeps consecutive optima close, so
//! [`exact::solve_integer_warm`] / [`exact::solve_integer_maintained`]
//! seed the exact scan's incumbent from the previous `(a*, b*)` (a pure
//! speedup — the pruned sweep still certifies global optimality), and
//! [`exact::solve_warm`] seeds the continuous coordinate descent with a
//! shrunken bracket, regressing to the cold grid when a probe grid shows
//! the optimum jumped basins.

pub mod exact;
pub mod lagrangian;

pub use exact::{
    solve_continuous, solve_integer, solve_integer_maintained, solve_integer_warm, solve_warm,
    solve_warm_checked, IntSolution, Solution, SolveOptions,
};
pub use lagrangian::{SubgradientSolver, SubgradientTrace};
