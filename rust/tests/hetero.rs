//! Heterogeneity / outage / deadline fault-surface harness (ISSUE 5).
//!
//! Three layers of property tests over the new subsystem:
//!
//! 1. **Strict generalization** — a single identity device class, no
//!    outages and an infinite deadline reproduce the homogeneous stack
//!    *bitwise* at the delay level (channel tables, `DelayInstance`,
//!    frontiers), the sim level (event streams) and the scenario level
//!    (whole `ScenarioOutcome`s).
//! 2. **Outage equivalence** — failing an edge is observationally the
//!    same as churn-departing its members and re-associating them with
//!    the edge masked; warm == masked-cold for every policy.
//! 3. **Degenerate device classes** — zero-weight classes, one-UE
//!    fleets and 1000× `f_cpu` spreads keep `τ_max(a)` monotone in `a`,
//!    which is exactly what the warm integer solver's pruned sweep needs
//!    to stay exact.

use hfl::assoc::{self, cold_reference_map_masked};
use hfl::config::AssocStrategy;
use hfl::delay::{DelayInstance, MaintainedInstance};
use hfl::net::{Channel, DeviceClassSpec, SystemParams, Topology};
use hfl::opt::{
    solve_integer, solve_integer_maintained, solve_integer_warm, IntSolution, SolveOptions,
};
use hfl::scenario::{run_instance, ResolveMode, ScenarioOutcome, ScenarioSpec};
use hfl::sim::{simulate, SimConfig};
use hfl::util::proptest::check;

/// The identity class spec: one class, every scale 1.0.
fn identity_devices() -> DeviceClassSpec {
    DeviceClassSpec::new().class("only", 1.0, 1.0, 1.0, 1.0)
}

/// A deliberately extreme fleet: flagship + 1000×-slower IoT nodes.
fn spread_devices() -> DeviceClassSpec {
    DeviceClassSpec::new()
        .class("flagship", 1.0, 1.0, 1.0, 1.0)
        .class("iot", 1.0, 0.001, 0.5, 2.0)
}

fn world_pair(
    devices: &DeviceClassSpec,
    edges: usize,
    ues: usize,
    seed: u64,
) -> (Topology, Channel) {
    let p = SystemParams::default();
    let topo = Topology::sample_with_devices(&p, devices, edges, ues, seed);
    let ch = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    (topo, ch)
}

// ---------------------------------------------------------------------------
// 1. Strict generalization: identity classes reproduce homogeneity bitwise.
// ---------------------------------------------------------------------------

#[test]
fn prop_identity_class_reproduces_homogeneous_delay_and_sim_bitwise() {
    check("identity device class == homogeneous, delay+sim", 16, |rng| {
        let edges = rng.int_range(2, 5) as usize;
        let ues = rng.int_range(edges as i64, (edges * 18) as i64) as usize;
        let seed = rng.next_u64();
        let p = SystemParams::default();
        let plain = Topology::sample(&p, edges, ues, seed);
        let single = Topology::sample_with_devices(&p, &identity_devices(), edges, ues, seed);
        let ch_a = Channel::compute(&p, &plain.ues, &plain.edges);
        let ch_b = Channel::compute(&p, &single.ues, &single.edges);
        for (x, y) in ch_a.rate_bps.iter().zip(&ch_b.rate_bps) {
            assert_eq!(x.to_bits(), y.to_bits(), "channel rates must match bitwise");
        }
        let cap = p.edge_capacity();
        let assoc_a = assoc::time_minimized(&ch_a, cap).unwrap();
        let assoc_b = assoc::time_minimized(&ch_b, cap).unwrap();
        assert_eq!(assoc_a.edge_of, assoc_b.edge_of);
        let ia = DelayInstance::build(&plain, &ch_a, &assoc_a, 0.25);
        let ib = DelayInstance::build(&single, &ch_b, &assoc_b, 0.25);
        for (ea, eb) in ia.per_edge.iter().zip(&ib.per_edge) {
            assert_eq!(ea.ue, eb.ue, "per-UE delay pairs must match bitwise");
        }
        for a in [1.0, 7.0, 40.0] {
            assert_eq!(ia.tau_max(a).to_bits(), ib.tau_max(a).to_bits());
            for b in [1.0, 5.0] {
                assert_eq!(ia.round_time(a, b).to_bits(), ib.round_time(a, b).to_bits());
            }
        }
        // Sim level, jitter + dropout + (disabled) deadline: identical
        // event streams and makespans.
        let cfg = SimConfig {
            jitter_sigma: 0.2,
            dropout_prob: 0.1,
            seed: seed ^ 0x51,
            rounds: Some(3),
            ..SimConfig::deterministic(10, 3)
        };
        let ra = simulate(&ia, &cfg);
        let rb = simulate(&ib, &cfg);
        assert_eq!(ra.total_time_s.to_bits(), rb.total_time_s.to_bits());
        assert_eq!(ra.events, rb.events);
        assert_eq!(ra.dropped_uploads, rb.dropped_uploads);
        assert_eq!(ra.late_uploads, 0);
        assert_eq!(rb.late_uploads, 0);
    });
}

fn assert_outcomes_identical(x: &ScenarioOutcome, y: &ScenarioOutcome) {
    assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits());
    assert_eq!(x.closed_form_s.to_bits(), y.closed_form_s.to_bits());
    assert_eq!(x.rounds, y.rounds);
    assert_eq!(x.epochs, y.epochs);
    assert_eq!(x.converged, y.converged);
    assert_eq!((x.a, x.b), (y.a, y.b));
    assert_eq!(x.ab_per_epoch, y.ab_per_epoch);
    assert_eq!(x.handovers, y.handovers);
    assert_eq!(x.arrivals, y.arrivals);
    assert_eq!(x.departures, y.departures);
    assert_eq!(x.dropped_uploads, y.dropped_uploads);
    assert_eq!(x.late_uploads, y.late_uploads);
    assert_eq!(x.scheduled_uploads, y.scheduled_uploads);
    assert_eq!(x.participation_rate.to_bits(), y.participation_rate.to_bits());
    assert_eq!(x.events, y.events);
    assert_eq!(x.ue_barrier_wait_s.to_bits(), y.ue_barrier_wait_s.to_bits());
    assert_eq!(x.edge_barrier_wait_s.to_bits(), y.edge_barrier_wait_s.to_bits());
    assert_eq!(x.reassociations, y.reassociations);
}

#[test]
fn scenario_single_class_no_outage_no_deadline_is_the_homogeneous_run_bitwise() {
    // The whole-stack strict-generalization property: a spec that *names*
    // the new subsystem but configures it to the identity (one identity
    // class, outage off, deadline = ∞) reproduces the plain spec's
    // trajectory bit for bit — dynamics, failures and all.
    let plain = ScenarioSpec::new()
        .edges(3)
        .ues(36)
        .eps(0.1)
        .seed(13)
        .mobility(1.0, 4.0)
        .churn(1.0, 0.08)
        .jitter(0.15)
        .dropout(0.05)
        .epoch_rounds(1)
        .max_epochs(48);
    let with_identity = plain
        .clone()
        .devices(identity_devices())
        .outage(0.0, 0.0)
        .deadline(f64::INFINITY);
    for seed in [3u64, 1009] {
        let a = run_instance(&plain, seed).unwrap();
        let b = run_instance(&with_identity, seed).unwrap();
        assert_outcomes_identical(&a, &b);
        assert_eq!(b.outages, 0);
        assert_eq!(b.down_edge_epochs, 0);
        assert_eq!(b.late_uploads, 0);
        assert_eq!(b.participation_rate, a.participation_rate);
    }
}

// ---------------------------------------------------------------------------
// 2. Outage equivalence + scenario-level outage behavior.
// ---------------------------------------------------------------------------

#[test]
fn prop_outage_warm_equals_masked_cold_for_every_policy_and_hysteresis() {
    check("outage warm == masked cold", 10, |rng| {
        let edges = rng.int_range(3, 6) as usize;
        // Leave an edge's worth of slack so any single outage is feasible.
        let ues = rng.int_range(edges as i64, ((edges - 1) * 18) as i64) as usize;
        let seed = rng.next_u64();
        let hysteresis = if rng.f64() < 0.5 {
            0.0
        } else {
            rng.range(0.1, 1.5)
        };
        let (topo, channel) = world_pair(&spread_devices(), edges, ues, seed);
        let active = vec![true; ues];
        let victim = rng.below(edges as u64) as usize;
        let mut up = vec![true; edges];
        up[victim] = false;
        for strategy in [AssocStrategy::Proposed, AssocStrategy::Greedy, AssocStrategy::Exact] {
            let mut ma = assoc::MaintainedAssociation::new(
                strategy,
                &topo,
                &channel,
                &active,
                20,
                hysteresis,
                20.0,
            )
            .unwrap();
            let before = ma.edge_of_global();
            ma.sync(
                &topo,
                &channel,
                &active,
                &assoc::WorldDelta {
                    downed: vec![victim],
                    ..Default::default()
                },
                20.0,
            )
            .unwrap();
            let cold = cold_reference_map_masked(
                strategy,
                &topo,
                &channel,
                &active,
                Some(&up),
                20,
                20.0,
            )
            .unwrap();
            assert_eq!(ma.edge_of_global(), cold, "{strategy:?} seed {seed}");
            assert!(cold.iter().flatten().all(|&e| e != victim));
            ma.sync(
                &topo,
                &channel,
                &active,
                &assoc::WorldDelta {
                    restored: vec![victim],
                    ..Default::default()
                },
                20.0,
            )
            .unwrap();
            assert_eq!(ma.edge_of_global(), before, "{strategy:?} recovery");
        }
    });
}

#[test]
fn outage_scenario_warm_equals_cold_and_fires() {
    // Warm (incremental assoc + maintained delay + warm solver) and cold
    // (from-scratch everything) trajectories must agree bit for bit on an
    // outage-heavy churning world, and outages must actually happen.
    let spec = ScenarioSpec::new()
        .edges(4)
        .ues(40)
        .eps(0.02)
        .seed(7)
        .churn(0.5, 0.05)
        .outage(0.4, 0.6)
        .epoch_rounds(1)
        .max_epochs(96);
    for seed in [11u64, 46] {
        let warm = run_instance(
            &spec
                .clone()
                .resolve(ResolveMode::Warm)
                .assoc_resolve(ResolveMode::Warm),
            seed,
        )
        .unwrap();
        let cold = run_instance(
            &spec
                .clone()
                .resolve(ResolveMode::Cold)
                .assoc_resolve(ResolveMode::Cold),
            seed,
        )
        .unwrap();
        assert_eq!(warm.ab_per_epoch, cold.ab_per_epoch, "seed {seed}");
        assert_eq!(warm.makespan_s.to_bits(), cold.makespan_s.to_bits());
        assert_eq!(warm.closed_form_s.to_bits(), cold.closed_form_s.to_bits());
        assert_eq!(warm.outages, cold.outages);
        assert_eq!(warm.recoveries, cold.recoveries);
        assert_eq!(warm.down_edge_epochs, cold.down_edge_epochs);
        assert_eq!(warm.handovers, cold.handovers);
        assert!(
            warm.outages > 0,
            "4 edges x 0.4 fail over {} epochs never failed once (seed {seed})",
            warm.epochs
        );
        assert!(warm.down_edge_epochs >= warm.outages);
    }
}

#[test]
fn outage_without_churn_or_mobility_still_fires() {
    // The outage process alone must force epoching (no explicit
    // epoch_rounds, no other dynamics) — regression for the chunking
    // rule that would otherwise run everything in one epoch.
    let spec = ScenarioSpec::new()
        .edges(3)
        .ues(24)
        .eps(0.05)
        .seed(5)
        .outage(0.6, 0.4)
        .max_epochs(128);
    let out = run_instance(&spec, 19).unwrap();
    assert!(out.epochs > 1, "outage spec must epoch round by round");
    assert!(out.outages > 0, "outages must fire without churn/mobility");
    assert!(out.converged);
    // Determinism.
    let again = run_instance(&spec, 19).unwrap();
    assert_eq!(out.makespan_s.to_bits(), again.makespan_s.to_bits());
    assert_eq!(out.outages, again.outages);
}

// ---------------------------------------------------------------------------
// 3. Degenerate device classes; τ_max monotonicity; warm-solver exactness.
// ---------------------------------------------------------------------------

#[test]
fn zero_weight_class_is_never_sampled() {
    let p = SystemParams::default();
    let spec = DeviceClassSpec::new()
        .class("main", 1.0, 1.0, 1.0, 1.0)
        .class("ghost", 0.0, 0.001, 0.1, 10.0);
    let t = Topology::sample_with_devices(&p, &spec, 3, 50, 5);
    for ue in &t.ues {
        assert_eq!(ue.cpu_hz.to_bits(), p.f_max_hz.to_bits(), "ghost class leaked");
    }
    // And the fleet is bitwise the homogeneous one.
    let plain = Topology::sample(&p, 3, 50, 5);
    for (a, b) in plain.ues.iter().zip(&t.ues) {
        assert_eq!(a.cycles_per_sample.to_bits(), b.cycles_per_sample.to_bits());
        assert_eq!(a.tx_power_w.to_bits(), b.tx_power_w.to_bits());
    }
}

#[test]
fn one_ue_fleet_with_classes_solves() {
    let p = SystemParams::default();
    let t = Topology::sample_with_devices(&p, &spread_devices(), 1, 1, 3);
    let ch = Channel::compute(&p, &t.ues, &t.edges);
    let a = assoc::time_minimized(&ch, p.edge_capacity()).unwrap();
    let inst = DelayInstance::build(&t, &ch, &a, 0.25);
    let sol = solve_integer(&inst, &SolveOptions::default());
    assert!(sol.a >= 1 && sol.b >= 1);
    assert!(sol.objective.is_finite() && sol.objective > 0.0);
}

#[test]
fn prop_extreme_spread_keeps_tau_max_monotone_and_warm_solver_exact() {
    check("1000x f_cpu spread: τ_max monotone, warm == cold", 12, |rng| {
        let edges = rng.int_range(2, 5) as usize;
        let ues = rng.int_range(edges as i64, (edges * 15) as i64) as usize;
        let seed = rng.next_u64();
        let (topo, channel) = world_pair(&spread_devices(), edges, ues, seed);
        let cap = topo.params.edge_capacity();
        let association = assoc::time_minimized(&channel, cap).unwrap();
        let inst = DelayInstance::build(&topo, &channel, &association, 0.25);

        // τ_max(a) = max over per-UE lines with nonnegative slopes: it
        // must stay nondecreasing in a no matter how wild the spread —
        // the premise of the warm integer solver's pruning bounds.
        let mut prev = f64::NEG_INFINITY;
        for a in 1..=80u64 {
            let tau = inst.tau_max(a as f64);
            assert!(
                tau >= prev,
                "τ_max not monotone at a={a}: {tau} < {prev} (seed {seed})"
            );
            prev = tau;
        }

        // Warm integer re-solve stays exactness-preserving on the
        // heterogeneous instance, from good and garbage seeds alike.
        let opts = SolveOptions::default();
        let cold = solve_integer(&inst, &opts);
        for warm_seed in [
            (1u64, 1u64),
            (cold.a, cold.b),
            (200, 100),
            (cold.a + 5, cold.b.saturating_sub(2).max(1)),
        ] {
            let prev_sol = IntSolution {
                a: warm_seed.0,
                b: warm_seed.1,
                objective: f64::INFINITY,
                rounds: 1,
                round_time: 0.0,
            };
            let warm = solve_integer_warm(&inst, &opts, &prev_sol);
            assert_eq!((warm.a, warm.b), (cold.a, cold.b), "seed {seed}");
            assert_eq!(warm.objective.to_bits(), cold.objective.to_bits());
        }

        // The maintained (frontier-cached) evaluation agrees bitwise with
        // the full per-UE scan on the heterogeneous fleet, and the
        // maintained warm solver lands on the same cell.
        let edge_of: Vec<Option<usize>> = association.edge_of.iter().map(|&e| Some(e)).collect();
        let mut maintained = MaintainedInstance::build(&topo, &channel, &edge_of, 0.25);
        maintained.refresh();
        for a in [1.0, 9.0, 33.0, 77.0] {
            assert_eq!(maintained.tau_max(a).to_bits(), inst.tau_max(a).to_bits());
            for b in [1.0, 4.0, 21.0] {
                assert_eq!(
                    maintained.round_time(a, b).to_bits(),
                    inst.round_time(a, b).to_bits()
                );
            }
        }
        let warm = solve_integer_maintained(&mut maintained, &opts, Some((cold.a, cold.b)));
        assert_eq!((warm.a, warm.b), (cold.a, cold.b));
        assert_eq!(warm.objective.to_bits(), cold.objective.to_bits());
    });
}

#[test]
fn hetero_fleet_slows_rounds_relative_to_uniform() {
    // Same seed, same positions, same association (power scale 1 keeps
    // SNR untouched): slowing half the fleet's CPUs can only raise τ and
    // the round time at any fixed (a, b). The 100x slowdown makes the
    // strict inequality certain as soon as a single UE lands in the slow
    // class (its compute line alone dwarfs the whole uniform τ_max).
    let p = SystemParams::default();
    let devices = DeviceClassSpec::new()
        .class("fast", 1.0, 1.0, 1.0, 1.0)
        .class("slow", 1.0, 0.01, 1.0, 1.0);
    let plain = Topology::sample(&p, 3, 45, 17);
    let hetero = Topology::sample_with_devices(&p, &devices, 3, 45, 17);
    let ch_a = Channel::compute(&p, &plain.ues, &plain.edges);
    let ch_b = Channel::compute(&p, &hetero.ues, &hetero.edges);
    let cap = p.edge_capacity();
    let assoc_a = assoc::time_minimized(&ch_a, cap).unwrap();
    let assoc_b = assoc::time_minimized(&ch_b, cap).unwrap();
    assert_eq!(assoc_a.edge_of, assoc_b.edge_of, "SNR untouched => same map");
    let ia = DelayInstance::build(&plain, &ch_a, &assoc_a, 0.25);
    let ib = DelayInstance::build(&hetero, &ch_b, &assoc_b, 0.25);
    for a in [5.0, 20.0, 60.0] {
        assert!(ib.tau_max(a) >= ia.tau_max(a));
        assert!(ib.round_time(a, 3.0) >= ia.round_time(a, 3.0));
    }
    assert!(
        ib.tau_max(60.0) > ia.tau_max(60.0),
        "a 100x CPU slowdown on half the fleet must bite at large a"
    );
}

// ---------------------------------------------------------------------------
// Deadline-aware aggregation at the scenario level.
// ---------------------------------------------------------------------------

#[test]
fn deadline_scenario_records_partial_participation() {
    let base = ScenarioSpec::new()
        .edges(3)
        .ues(30)
        .eps(0.25)
        .seed(2)
        .devices(spread_devices());
    let nodl = run_instance(&base, 5).unwrap();
    assert_eq!(nodl.late_uploads, 0);
    assert_eq!(nodl.participation_rate, 1.0);
    assert!(nodl.scheduled_uploads > 0);

    // τ_max is the slowest member's full round duration at the solved a:
    // half of it is a deadline some member must miss (the argmax one),
    // while t > 0 members still make it on a spread fleet.
    let tight = base.clone().deadline(nodl.tau_max_s * 0.5);
    let dl = run_instance(&tight, 5).unwrap();
    assert!(dl.late_uploads > 0, "a τ_max/2 deadline must drop the slowest member");
    assert!(dl.participation_rate < 1.0);
    assert!(dl.participation_rate > 0.0, "the fast class still participates");
    assert_eq!(dl.scheduled_uploads, nodl.scheduled_uploads);
    // Closing barriers early can only shorten the run.
    assert!(dl.makespan_s <= nodl.makespan_s + 1e-9);
    // Deterministic.
    let again = run_instance(&tight, 5).unwrap();
    assert_eq!(dl.makespan_s.to_bits(), again.makespan_s.to_bits());
    assert_eq!(dl.late_uploads, again.late_uploads);
}
