//! End-to-end wire tests for `hfl serve`: the headline guarantee is that
//! a job submitted over TCP produces *byte-identical* deterministic
//! results to an in-process `ScenarioRun` on the same spec layers — for
//! any worker count and with concurrent tenants — plus graceful-shutdown
//! and backpressure semantics.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use hfl::scenario::{strip_measured, BatchReport, ScenarioRun};
use hfl::serve::checkpoint::Journal;
use hfl::serve::{protocol, resolve_request, JobRequest, ServeConfig, Server};
use hfl::util::json::Json;

/// Small dynamic spec: multiple epochs (so `epoch` frames stream),
/// multiple instances on 2 shards (so scheduling interleaves).
const SPEC_TOML: &str = "\
[scenario]
num_edges = 2
num_ues = 30
eps = 0.25
seed = 42

[dynamics]
speed_min_mps = 0.5
speed_max_mps = 2.0
arrival_rate = 0.5
departure_prob = 0.02
epoch_rounds = 1
max_epochs = 6

[batch]
instances = 3
shards = 2
";

/// Heavy spec for shutdown/backpressure tests: long enough that the job
/// is reliably still running while the test submits more work.
const SLOW_TOML: &str = "\
[scenario]
num_edges = 3
num_ues = 80
eps = 0.25
seed = 7

[dynamics]
speed_min_mps = 0.5
speed_max_mps = 2.0
arrival_rate = 1.0
departure_prob = 0.02
epoch_rounds = 1
max_epochs = 192

[batch]
instances = 3
shards = 1
";

fn start_server(workers: usize, queue_depth: usize) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        queue_depth,
        checkpoint: None,
    };
    let server = Server::bind(cfg).unwrap();
    let addr = server.addr();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

fn send_shutdown(addr: SocketAddr) {
    let mut sock = TcpStream::connect(addr).unwrap();
    writeln!(sock, "{}", protocol::shutdown_cmd_line()).unwrap();
    let mut line = String::new();
    BufReader::new(sock).read_line(&mut line).unwrap();
    assert!(line.contains("\"ev\":\"shutdown\""), "got '{line}'");
}

fn req(spec_toml: &str, stream: bool) -> JobRequest {
    JobRequest {
        spec_toml: Some(spec_toml.to_string()),
        env: Vec::new(),
        args: Vec::new(),
        stream,
    }
}

/// Submit and read frames until a terminal frame (done/error/busy/
/// invalid/rejected) arrives; returns every frame parsed.
fn submit_and_collect(addr: SocketAddr, request: &JobRequest) -> Vec<Json> {
    let sock = TcpStream::connect(addr).unwrap();
    let mut writer = sock.try_clone().unwrap();
    writeln!(writer, "{}", protocol::submit_line(request)).unwrap();
    collect_frames(sock)
}

fn collect_frames(sock: TcpStream) -> Vec<Json> {
    let reader = BufReader::new(sock);
    let mut frames = Vec::new();
    for line in reader.lines() {
        let line = line.unwrap();
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).unwrap_or_else(|e| panic!("bad frame '{line}': {e}"));
        let ev = ev_of(&v).to_string();
        frames.push(v);
        if matches!(ev.as_str(), "done" | "error" | "busy" | "invalid" | "rejected") {
            break;
        }
    }
    frames
}

fn ev_of(v: &Json) -> &str {
    v.get("ev").and_then(Json::as_str).unwrap_or("?")
}

/// The reference: resolve the request through the same layered path and
/// run it in-process; return the report JSON text.
fn in_process_report(request: &JobRequest) -> String {
    let spec = resolve_request(request).unwrap();
    let batch = ScenarioRun::new(&spec).run_batch().unwrap();
    BatchReport::from_outcomes(&batch.outcomes).to_json(Some(&spec)).to_string()
}

/// Deterministic view of a job's frames: epoch frames sorted by
/// (instance, epoch) with measured fields stripped, then outcome frames
/// in arrival (= instance) order.
fn canonical_stream(frames: &[Json]) -> Vec<String> {
    let mut epochs: Vec<(u64, u64, String)> = frames
        .iter()
        .filter(|f| ev_of(f) == "epoch")
        .map(|f| {
            let instance = f.get("instance").and_then(Json::as_f64).unwrap() as u64;
            let epoch = f.get("epoch").and_then(Json::as_f64).unwrap() as u64;
            (instance, epoch, strip_measured(&f.to_string()).unwrap())
        })
        .collect();
    epochs.sort();
    let mut out: Vec<String> = epochs.into_iter().map(|(_, _, s)| s).collect();
    let outcomes = frames.iter().filter(|f| ev_of(f) == "outcome");
    out.extend(outcomes.map(|f| f.to_string()));
    out
}

#[test]
fn wire_job_is_bitwise_identical_to_in_process_batch_for_any_worker_count() {
    let request = req(SPEC_TOML, true);
    let expected_report = strip_measured(&in_process_report(&request)).unwrap();

    // In-process reference outcome frames (job id is 1 on a fresh server).
    let spec = resolve_request(&request).unwrap();
    let reference = ScenarioRun::new(&spec).run_batch().unwrap();
    let expected_outcomes: Vec<String> = reference
        .outcomes
        .iter()
        .map(|o| protocol::outcome_line(1, o))
        .collect();

    let mut streams = Vec::new();
    for workers in [1usize, 4] {
        let (addr, handle) = start_server(workers, 8);
        let frames = submit_and_collect(addr, &request);
        send_shutdown(addr);
        handle.join().unwrap();

        assert_eq!(ev_of(&frames[0]), "accepted", "workers={workers}");
        let done = frames.last().unwrap();
        assert_eq!(ev_of(done), "done", "workers={workers}");
        let report = strip_measured(&done.get("report").unwrap().to_string()).unwrap();
        assert_eq!(
            report,
            expected_report,
            "workers={workers}: wire report != in-process report"
        );

        let wire_outcomes: Vec<String> = frames
            .iter()
            .filter(|f| ev_of(f) == "outcome")
            .map(|f| f.to_string())
            .collect();
        assert_eq!(
            wire_outcomes,
            expected_outcomes,
            "workers={workers}: outcome frames differ from in-process outcomes"
        );

        let epochs = frames.iter().filter(|f| ev_of(f) == "epoch").count();
        assert!(epochs > 0, "workers={workers}: streaming produced no epoch frames");
        streams.push(canonical_stream(&frames));
    }
    assert_eq!(
        streams[0],
        streams[1],
        "epoch/outcome streams must not depend on the server worker count"
    );
}

#[test]
fn concurrent_tenants_get_independent_bitwise_correct_results() {
    // Two tenants, different seeds, racing on a 4-worker server.
    let toml_a = SPEC_TOML.replace("seed = 42", "seed = 11");
    let toml_b = SPEC_TOML.replace("seed = 42", "seed = 99");
    let (addr, handle) = start_server(4, 8);
    let threads: Vec<_> = [toml_a, toml_b]
        .into_iter()
        .map(|toml| {
            std::thread::spawn(move || {
                let request = req(&toml, true);
                let frames = submit_and_collect(addr, &request);
                let expected = strip_measured(&in_process_report(&request)).unwrap();
                let done = frames.last().unwrap();
                assert_eq!(ev_of(done), "done");
                let got = strip_measured(&done.get("report").unwrap().to_string()).unwrap();
                assert_eq!(got, expected, "tenant report corrupted under concurrency");
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    send_shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn graceful_shutdown_drains_inflight_rejects_queued_and_backpressures() {
    // One worker, queue depth 1: job A runs, job B queues, job C bounces.
    let (addr, handle) = start_server(1, 1);

    // Tenant A: slow streaming job; wait for its first epoch frame so we
    // know the single worker has claimed it.
    let sock_a = TcpStream::connect(addr).unwrap();
    let mut writer_a = sock_a.try_clone().unwrap();
    writeln!(writer_a, "{}", protocol::submit_line(&req(SLOW_TOML, true))).unwrap();
    let mut reader_a = BufReader::new(sock_a);
    let mut saw_epoch = false;
    let mut line = String::new();
    while !saw_epoch {
        line.clear();
        assert!(reader_a.read_line(&mut line).unwrap() > 0, "server hung up on A");
        if line.contains("\"ev\":\"epoch\"") {
            saw_epoch = true;
        }
    }

    // Tenant B: accepted but queued behind A.
    let sock_b = TcpStream::connect(addr).unwrap();
    let mut writer_b = sock_b.try_clone().unwrap();
    writeln!(writer_b, "{}", protocol::submit_line(&req(SLOW_TOML, false))).unwrap();
    let mut reader_b = BufReader::new(sock_b);
    let mut line_b = String::new();
    reader_b.read_line(&mut line_b).unwrap();
    assert!(line_b.contains("\"ev\":\"accepted\""), "B got '{line_b}'");

    // Tenant C: the queue is full — explicit busy, not silent buffering.
    let frames_c = submit_and_collect(addr, &req(SLOW_TOML, false));
    assert_eq!(ev_of(frames_c.last().unwrap()), "busy", "C frames: {frames_c:?}");

    // Shutdown: A (in flight) drains to `done`, B (queued) is rejected.
    // Read until the expected frame (not EOF: the server's per-connection
    // reader thread keeps each socket open until the client hangs up).
    send_shutdown(addr);

    loop {
        line_b.clear();
        assert!(
            reader_b.read_line(&mut line_b).unwrap() > 0,
            "connection closed before B's rejection frame"
        );
        if line_b.contains("\"ev\":\"rejected\"") {
            break;
        }
    }

    loop {
        line.clear();
        assert!(
            reader_a.read_line(&mut line).unwrap() > 0,
            "connection closed before A's done frame"
        );
        if line.contains("\"ev\":\"done\"") {
            break;
        }
    }

    drop(reader_a);
    drop(reader_b);
    handle.join().unwrap();
}

#[test]
fn invalid_submissions_fail_fast_with_context() {
    let (addr, handle) = start_server(1, 2);

    // Typo'd CLI layer.
    let bad = JobRequest {
        spec_toml: Some(SPEC_TOML.to_string()),
        env: Vec::new(),
        args: vec!["--instancez".to_string(), "7".to_string()],
        stream: false,
    };
    let frames = submit_and_collect(addr, &bad);
    let last = frames.last().unwrap();
    assert_eq!(ev_of(last), "invalid");
    let err = last.get("error").and_then(Json::as_str).unwrap();
    assert!(err.contains("instancez"), "error should name the typo: {last}");

    // Garbage frame.
    let sock = TcpStream::connect(addr).unwrap();
    let mut w = sock.try_clone().unwrap();
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    BufReader::new(sock).read_line(&mut line).unwrap();
    assert!(line.contains("\"ev\":\"invalid\""), "got '{line}'");

    // Ping still answers.
    let sock = TcpStream::connect(addr).unwrap();
    let mut w = sock.try_clone().unwrap();
    writeln!(w, "{}", protocol::ping_line()).unwrap();
    let mut line = String::new();
    BufReader::new(sock).read_line(&mut line).unwrap();
    assert!(line.contains("\"ev\":\"pong\""), "got '{line}'");

    send_shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn checkpointed_pending_jobs_resume_and_write_reports() {
    let dir = std::env::temp_dir().join(format!("hfl_serve_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal_path = dir.join("jobs.jsonl");

    // Simulate a crashed server: one job journaled as submitted, never done.
    let request = req(SPEC_TOML, false);
    {
        let (mut journal, pending, _) = Journal::open(&journal_path).unwrap();
        assert!(pending.is_empty());
        journal.record_submitted(1, &request).unwrap();
    }

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_depth: 2,
        checkpoint: Some(journal_path.display().to_string()),
    };
    let server = Server::bind(cfg).unwrap();
    assert_eq!(server.resumed_jobs(), 1, "pending job must be picked up");
    let addr = server.addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    // The resumed job's report lands next to the journal.
    let report_path = PathBuf::from(format!("{}.job1.json", journal_path.display()));
    let deadline = Instant::now() + Duration::from_secs(60);
    while !report_path.exists() {
        assert!(Instant::now() < deadline, "resumed job never wrote its report");
        std::thread::sleep(Duration::from_millis(20));
    }
    send_shutdown(addr);
    handle.join().unwrap();

    let written = std::fs::read_to_string(&report_path).unwrap();
    let expected = in_process_report(&request);
    assert_eq!(
        strip_measured(&written).unwrap(),
        strip_measured(&expected).unwrap(),
        "resumed job must reproduce the in-process report bitwise (modulo walls)"
    );

    // After completion the journal marks it done: a restart resumes nothing.
    let (_j, pending, max_id) = Journal::open(&journal_path).unwrap();
    assert!(pending.is_empty(), "completed job must not resume again");
    assert_eq!(max_id, 1);
    let _ = std::fs::remove_dir_all(&dir);
}
