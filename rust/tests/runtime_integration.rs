//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! visible message) when artifacts are missing so `cargo test` stays
//! usable in a fresh checkout.

use hfl::coordinator::run_hfl;
use hfl::data::synthetic::{generate_split, SyntheticConfig};
use hfl::fl::{HflEngine, LocalSolver, TrainRun};
use hfl::runtime::{find_artifacts, Engine};
use hfl::util::Rng;

fn engine_or_skip() -> Option<Engine> {
    match find_artifacts(None) {
        Ok(dir) => Some(Engine::load(&dir).expect("artifacts exist but failed to load")),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e}");
            None
        }
    }
}

fn batchify(engine: &Engine, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let hw = engine.meta.image_hw;
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * hw * hw).map(|_| rng.f64() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    (x, y)
}

#[test]
fn load_and_meta_consistent() {
    let Some(engine) = engine_or_skip() else { return };
    assert_eq!(engine.meta.param_count, 44426);
    assert_eq!(engine.init_params().len(), 44426);
    assert_eq!(engine.meta.image_hw, 28);
}

#[test]
fn train_step_decreases_loss_and_changes_params() {
    let Some(engine) = engine_or_skip() else { return };
    let (x, y) = batchify(&engine, engine.meta.train_batch, 1);
    let mut params = engine.init_params();
    let mut losses = Vec::new();
    for _ in 0..8 {
        let (next, loss) = engine.train_step(&params, &x, &y, 0.1).unwrap();
        assert_ne!(next, params, "params must move");
        params = next;
        losses.push(loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "{losses:?}"
    );
    // CE at init must be near ln(10).
    assert!((1.5..3.5).contains(&losses[0]), "init loss {}", losses[0]);
}

#[test]
fn zero_lr_train_step_is_identity() {
    let Some(engine) = engine_or_skip() else { return };
    let (x, y) = batchify(&engine, engine.meta.train_batch, 2);
    let params = engine.init_params();
    let (next, _) = engine.train_step(&params, &x, &y, 0.0).unwrap();
    assert_eq!(next, params);
}

#[test]
fn grad_step_matches_train_step() {
    let Some(engine) = engine_or_skip() else { return };
    let (x, y) = batchify(&engine, engine.meta.train_batch, 3);
    let params = engine.init_params();
    let lr = 0.05f32;
    let (grad, loss_g) = engine.grad_step(&params, &x, &y).unwrap();
    let (stepped, loss_t) = engine.train_step(&params, &x, &y, lr).unwrap();
    assert!((loss_g - loss_t).abs() < 1e-5);
    for i in (0..params.len()).step_by(997) {
        let manual = params[i] - lr * grad[i];
        assert!(
            (stepped[i] - manual).abs() < 1e-5,
            "param {i}: {} vs {}",
            stepped[i],
            manual
        );
    }
}

#[test]
fn eval_step_counts_bounded() {
    let Some(engine) = engine_or_skip() else { return };
    let (x, y) = batchify(&engine, engine.meta.eval_batch, 4);
    let params = engine.init_params();
    let (loss_sum, correct) = engine.eval_step(&params, &x, &y).unwrap();
    assert!(loss_sum > 0.0);
    assert!((0.0..=engine.meta.eval_batch as f32).contains(&correct));
}

#[test]
fn evaluate_handles_ragged_test_sets() {
    let Some(engine) = engine_or_skip() else { return };
    let params = engine.init_params();
    // A test set that is NOT a multiple of eval_batch.
    let n = engine.meta.eval_batch + 37;
    let (x, y) = batchify(&engine, n, 5);
    let (loss, acc) = engine.evaluate(&params, &x, &y).unwrap();
    assert!(loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));

    // Cross-check against a direct eval_step on an exact multiple.
    let m = engine.meta.eval_batch;
    let (x2, y2) = batchify(&engine, m, 6);
    let (l2, a2) = engine.evaluate(&params, &x2, &y2).unwrap();
    let (ls, cc) = engine.eval_step(&params, &x2, &y2).unwrap();
    assert!((l2 - ls / m as f32).abs() < 1e-4);
    assert!((a2 - cc / m as f32).abs() < 1e-6);
}

#[test]
fn engine_is_concurrency_safe() {
    let Some(engine) = engine_or_skip() else { return };
    let engine = std::sync::Arc::new(engine);
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let e = engine.clone();
        handles.push(std::thread::spawn(move || {
            let (x, y) = batchify(&e, e.meta.train_batch, 100 + t);
            let params = e.init_params();
            let (p1, l1) = e.train_step(&params, &x, &y, 0.05).unwrap();
            // Same inputs, same outputs — even under contention.
            let (p2, l2) = e.train_step(&params, &x, &y, 0.05).unwrap();
            assert_eq!(p1, p2);
            assert_eq!(l1, l2);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

/// The threaded coordinator must reproduce the sequential engine exactly.
#[test]
fn coordinator_matches_sequential_engine() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = SyntheticConfig::default();
    let n_ues = 4;
    let shards: Vec<_> = (0..n_ues)
        .map(|i| generate_split(&cfg, 64, 42, 1000 + i as u64))
        .collect();
    let members = vec![vec![0, 1], vec![2, 3]];
    let test = generate_split(&cfg, 128, 42, 99);
    let run = TrainRun {
        a: 2,
        b: 2,
        cloud_rounds: 2,
        round_time_s: 10.0,
        eval_every: 1,
    };
    let solver = LocalSolver::Gd { lr: 0.05 };

    let mut seq = HflEngine::new(
        &engine,
        solver,
        shards.clone(),
        members.clone(),
        test.clone(),
        7,
    );
    let seq_curve = seq.train(&run).unwrap();

    let outcome = run_hfl(&engine, solver, shards, members, &test, &run, 2, 7).unwrap();

    assert_eq!(outcome.final_model, seq.global, "models diverged");
    assert_eq!(outcome.curve.points.len(), seq_curve.points.len());
    for (p, q) in outcome.curve.points.iter().zip(&seq_curve.points) {
        assert_eq!(p.test_acc, q.test_acc);
        assert_eq!(p.cloud_round, q.cloud_round);
    }
}

/// End-to-end learning: on the structured synthetic task, a short HFL run
/// must lift accuracy well above the 10% chance level.
#[test]
fn hfl_learns_synthetic_task() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = SyntheticConfig::default();
    let n_ues = 4;
    let shards: Vec<_> = (0..n_ues)
        .map(|i| generate_split(&cfg, 128, 42, 2000 + i as u64))
        .collect();
    let members = vec![vec![0, 1], vec![2, 3]];
    let test = generate_split(&cfg, 256, 42, 555);
    let run = TrainRun {
        a: 8,
        b: 2,
        cloud_rounds: 3,
        round_time_s: 1.0,
        eval_every: 1,
    };
    let outcome = run_hfl(
        &engine,
        LocalSolver::Gd { lr: 0.1 },
        shards,
        members,
        &test,
        &run,
        0,
        3,
    )
    .unwrap();
    let acc = outcome.curve.final_acc();
    assert!(acc > 0.5, "accuracy {acc} after 3 cloud rounds");
}

#[test]
fn dane_solver_also_learns() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = SyntheticConfig::default();
    let shards: Vec<_> = (0..2).map(|i| generate_split(&cfg, 96, 42, 3000 + i as u64)).collect();
    let members = vec![vec![0, 1]];
    let test = generate_split(&cfg, 128, 42, 777);
    let run = TrainRun {
        a: 6,
        b: 2,
        cloud_rounds: 2,
        round_time_s: 1.0,
        eval_every: 1,
    };
    let outcome = run_hfl(
        &engine,
        LocalSolver::Dane { lr: 0.1 },
        shards,
        members,
        &test,
        &run,
        0,
        5,
    )
    .unwrap();
    let first = outcome.curve.points.first().unwrap().test_acc;
    let last = outcome.curve.final_acc();
    assert!(last > first, "DANE did not improve: {first} -> {last}");
}
