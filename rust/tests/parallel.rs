//! Intra-instance parallelism tests: epoch maintenance over the
//! SoA-sharded engines must be bitwise-identical for every
//! `intra_threads` value — warm and cold, plain and fully loaded (hetero
//! device classes, edge outages, aggregation deadlines) — and the trace
//! counters folded from per-shard partials must equal the serial totals.

use hfl::config::AssocStrategy;
use hfl::net::DeviceClassSpec;
use hfl::scenario::{run_instance, run_instance_traced, ResolveMode, ScenarioOutcome, ScenarioSpec};
use hfl::trace::{Counter, StatsSink};
use hfl::util::proptest::check;

fn dynamic_spec() -> ScenarioSpec {
    ScenarioSpec::new()
        .edges(3)
        .ues(40)
        .eps(0.1)
        .seed(17)
        .mobility(1.0, 5.0)
        .churn(1.0, 0.1)
        .jitter(0.1)
        .dropout(0.05)
        .epoch_rounds(1)
        .max_epochs(48)
}

/// Every optional subsystem on at once: heterogeneous device classes,
/// Markov edge outages, and an aggregation deadline. The parallel
/// maintenance pass must stay bitwise-exact under all of them.
fn loaded_spec() -> ScenarioSpec {
    dynamic_spec()
        .devices(
            DeviceClassSpec::new()
                .class("fast", 3.0, 1.0, 1.0, 1.0)
                .class("slow", 1.0, 0.3, 0.7, 1.5),
        )
        .outage(0.05, 0.5)
        .deadline(2.5)
}

fn assert_bitwise(x: &ScenarioOutcome, y: &ScenarioOutcome, what: &str) {
    assert_eq!(x.seed, y.seed, "{what}");
    assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits(), "{what}");
    assert_eq!(x.closed_form_s.to_bits(), y.closed_form_s.to_bits(), "{what}");
    assert_eq!(x.rounds, y.rounds, "{what}");
    assert_eq!(x.epochs, y.epochs, "{what}");
    assert_eq!(x.converged, y.converged, "{what}");
    assert_eq!((x.a, x.b), (y.a, y.b), "{what}");
    assert_eq!(x.handovers, y.handovers, "{what}");
    assert_eq!(x.arrivals, y.arrivals, "{what}");
    assert_eq!(x.departures, y.departures, "{what}");
    assert_eq!(x.dropped_uploads, y.dropped_uploads, "{what}");
    assert_eq!(x.late_uploads, y.late_uploads, "{what}");
    assert_eq!(x.scheduled_uploads, y.scheduled_uploads, "{what}");
    assert_eq!(
        x.participation_rate.to_bits(),
        y.participation_rate.to_bits(),
        "{what}"
    );
    assert_eq!(x.outages, y.outages, "{what}");
    assert_eq!(x.recoveries, y.recoveries, "{what}");
    assert_eq!(x.down_edge_epochs, y.down_edge_epochs, "{what}");
    assert_eq!(x.events, y.events, "{what}");
    assert_eq!(x.ab_per_epoch, y.ab_per_epoch, "{what}");
    assert_eq!(x.resolves, y.resolves, "{what}");
    assert_eq!(x.cold_resolves, y.cold_resolves, "{what}");
    assert_eq!(x.reassociations, y.reassociations, "{what}");
    // Trace counters are part of the trajectory (folded from per-shard
    // partials in shard order); wall_s spans are measured and exempt.
    assert_eq!(x.phase.counters, y.phase.counters, "{what}");
}

#[test]
fn epoch_maintenance_is_bitwise_identical_across_intra_threads() {
    for (name, spec) in [("plain", dynamic_spec()), ("loaded", loaded_spec())] {
        for strategy in [AssocStrategy::Proposed, AssocStrategy::Greedy] {
            for mode in [ResolveMode::Warm, ResolveMode::Cold] {
                let base = spec.clone().assoc(strategy).assoc_resolve(mode);
                let serial = run_instance(&base.clone().intra_threads(1), 23).unwrap();
                assert!(serial.epochs > 1, "dynamic run must span epochs");
                for threads in [2usize, 8] {
                    let par = run_instance(&base.clone().intra_threads(threads), 23).unwrap();
                    assert_bitwise(
                        &serial,
                        &par,
                        &format!("{name} {strategy:?} {mode:?} threads={threads}"),
                    );
                }
            }
        }
    }
}

#[test]
fn auto_thread_count_is_bitwise_identical_too() {
    // `intra_threads = 0` resolves to the machine's core count — whatever
    // that is here, the trajectory must match the serial one.
    let spec = loaded_spec();
    let serial = run_instance(&spec.clone().intra_threads(1), 29).unwrap();
    let auto = run_instance(&spec.clone().intra_threads(0), 29).unwrap();
    assert_bitwise(&serial, &auto, "auto thread count");
}

#[test]
fn sharded_counters_fold_to_serial_totals() {
    // The engines emit counters folded from per-shard partials; a sink
    // must observe the exact serial stream for any thread count.
    let spec = loaded_spec();
    let mut s1 = StatsSink::default();
    let one = run_instance_traced(&spec.clone().intra_threads(1), 31, &mut s1).unwrap();
    let mut s8 = StatsSink::default();
    let eight = run_instance_traced(&spec.clone().intra_threads(8), 31, &mut s8).unwrap();
    assert_eq!(s1.stats.counters, s8.stats.counters);
    assert_eq!(one.phase.counters, eight.phase.counters);
    assert!(
        one.phase.count(Counter::AssocDirty) >= 40,
        "the first epoch marks the whole fleet dirty"
    );
    assert!(one.phase.count(Counter::DelayTouched) > 0);
}

#[test]
fn prop_intra_threads_never_perturbs_trajectories() {
    check("intra_threads bitwise invariance", 12, |rng| {
        let edges = rng.int_range(2, 4) as usize;
        // <= 13 UEs per edge keeps the default capacity feasible.
        let ues = rng.int_range(8, edges as i64 * 13) as usize;
        let mut spec = ScenarioSpec::new()
            .edges(edges)
            .ues(ues)
            .eps(rng.range(0.05, 0.4))
            .mobility(0.5, rng.range(1.0, 6.0))
            .churn(rng.range(0.0, 2.0), rng.range(0.0, 0.2))
            .epoch_rounds(1)
            .max_epochs(24);
        if rng.f64() < 0.5 {
            spec = spec.assoc(AssocStrategy::Greedy);
        }
        if rng.f64() < 0.5 {
            spec = spec.outage(0.1, 0.5);
        }
        if rng.f64() < 0.5 {
            spec = spec.deadline(rng.range(0.5, 5.0));
        }
        let seed = rng.next_u64();
        let serial = run_instance(&spec.clone().intra_threads(1), seed).unwrap();
        for threads in [2usize, 8] {
            let par = run_instance(&spec.clone().intra_threads(threads), seed).unwrap();
            assert_bitwise(&serial, &par, &format!("seed {seed} threads {threads}"));
        }
    });
}
