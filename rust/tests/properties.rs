//! Property-based tests over the L3 substrates (seeded harness — replay
//! any failure with HFL_PROP_SEED=<seed>).
//!
//! These are the paper's invariants, checked on random instances:
//! association feasibility (constraints (3)/(13c)-(13e)), the min-max
//! dominance ordering exact ≤ {proposed, greedy, random}, monotonicity of
//! R(a,b,ε), the closed-form/simulator identity, and optimizer sanity.

use hfl::assoc::{self, LatencyTable};
use hfl::data::synthetic::{generate_split, SyntheticConfig};
use hfl::data::{partition_dirichlet, partition_iid};
use hfl::delay::{cloud_rounds, DelayInstance, EdgeDelays};
use hfl::net::{Channel, DeviceClassSpec, SystemParams, Topology};
use hfl::opt::{solve_continuous, solve_integer, SolveOptions, SubgradientSolver};
use hfl::sim::{simulate, SimConfig};
use hfl::util::proptest::check;
use hfl::util::Rng;

/// Random wireless world (feasible by construction).
fn random_world(rng: &mut Rng) -> (Topology, Channel, usize) {
    let edges = rng.int_range(2, 6) as usize;
    let cap_each = rng.int_range(4, 25) as usize;
    // Keep N within 80% of total capacity so every strategy can place all.
    let max_ues = (edges * cap_each) as i64;
    let ues = rng.int_range(edges as i64, (max_ues * 4 / 5).max(edges as i64)) as usize;
    let mut params = SystemParams::default();
    params.ue_bandwidth_hz = params.edge_bandwidth_hz / cap_each as f64;
    let topo = Topology::sample(&params, edges, ues, rng.next_u64());
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    (topo, channel, cap_each)
}

fn random_instance(rng: &mut Rng) -> DelayInstance {
    let edges = rng.int_range(1, 5) as usize;
    let per_edge = (0..edges)
        .map(|_| {
            let n = rng.int_range(1, 8) as usize;
            EdgeDelays {
                ue: (0..n)
                    .map(|_| (rng.range(1e-4, 0.05), rng.range(0.01, 1.0)))
                    .collect(),
                backhaul_s: rng.range(0.001, 0.1),
            }
        })
        .collect();
    DelayInstance {
        per_edge,
        gamma: rng.int_range(1, 10) as f64,
        zeta: rng.int_range(1, 10) as f64,
        c_const: 1.0,
        eps: rng.range(0.02, 0.8),
    }
}

#[test]
fn prop_associations_always_feasible() {
    check("associations feasible", 64, |rng| {
        let (topo, channel, cap) = random_world(rng);
        let n = topo.num_ues();
        let m = topo.num_edges();
        let prop = assoc::time_minimized(&channel, cap).expect("alg3 feasible");
        prop.validate(cap).unwrap();
        assert_eq!(prop.num_ues(), n);
        let gre = assoc::greedy(&channel, cap).expect("greedy feasible");
        gre.validate(cap).unwrap();
        let ran = assoc::random(n, m, cap, rng).expect("random feasible");
        ran.validate(cap).unwrap();
    });
}

#[test]
fn prop_exact_dominates_heuristics() {
    check("exact <= heuristics", 48, |rng| {
        let (topo, channel, cap) = random_world(rng);
        let a = rng.range(1.0, 50.0);
        let table = LatencyTable::build(&topo, &channel, a);
        let exact = assoc::solve_exact_matching(&table, cap).unwrap();
        let opt = table.max_latency(&exact);
        for assoc_ in [
            assoc::time_minimized(&channel, cap).unwrap(),
            assoc::greedy(&channel, cap).unwrap(),
            assoc::random(topo.num_ues(), topo.num_edges(), cap, rng).unwrap(),
        ] {
            assert!(
                opt <= table.max_latency(&assoc_) + 1e-9,
                "exact {opt} beaten by {}",
                table.max_latency(&assoc_)
            );
        }
    });
}

#[test]
fn prop_bnb_agrees_with_matching_on_small_instances() {
    check("bnb == matching", 24, |rng| {
        let edges = rng.int_range(2, 3) as usize;
        let ues = rng.int_range(4, 10) as usize;
        let cap = ues.div_ceil(edges) + 1;
        let mut params = SystemParams::default();
        params.ue_bandwidth_hz = params.edge_bandwidth_hz / cap as f64;
        let topo = Topology::sample(&params, edges, ues, rng.next_u64());
        let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let table = LatencyTable::build(&topo, &channel, 15.0);
        let bnb = assoc::solve_exact_bnb(&table, cap, None).unwrap();
        let mat = assoc::solve_exact_matching(&table, cap).unwrap();
        let (a, b) = (table.max_latency(&bnb), table.max_latency(&mat));
        assert!((a - b).abs() < 1e-9, "bnb {a} vs matching {b}");
    });
}

#[test]
fn prop_flow_bound_certifies_every_policy() {
    // The tentpole's soundness property: on random worlds — heterogeneous
    // fleets and edge outages included — the flow lower bound sits at or
    // below the max-latency every policy achieves.
    check("flow bound <= achieved", 48, |rng| {
        let (topo, channel, cap) = {
            let edges = rng.int_range(2, 6) as usize;
            let cap_each = rng.int_range(4, 25) as usize;
            let max_ues = (edges * cap_each) as i64;
            let ues = rng.int_range(edges as i64, (max_ues * 4 / 5).max(edges as i64)) as usize;
            let mut params = SystemParams::default();
            params.ue_bandwidth_hz = params.edge_bandwidth_hz / cap_each as f64;
            // Half the worlds get an extreme device spread (flagship +
            // 1000x-slower IoT): the bound must not care where the
            // latency mass comes from.
            let topo = if rng.next_u64() % 2 == 0 {
                let devices = DeviceClassSpec::new()
                    .class("flagship", 1.0, 1.0, 1.0, 1.0)
                    .class("iot", 1.0, 0.001, 0.5, 2.0);
                Topology::sample_with_devices(&params, &devices, edges, ues, rng.next_u64())
            } else {
                Topology::sample(&params, edges, ues, rng.next_u64())
            };
            let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
            (topo, channel, cap_each)
        };
        let (n, m) = (topo.num_ues(), topo.num_edges());
        let a = rng.range(1.0, 50.0);
        let mut table = LatencyTable::build(&topo, &channel, a);
        // Sometimes knock one edge out, the way the scenario's down-edge
        // masking poisons its column to +inf — feasibility permitting.
        if n <= (m - 1) * cap && rng.next_u64() % 2 == 0 {
            let down = rng.below(m as u64) as usize;
            for ue in 0..n {
                table.latency_s[ue * m + down] = f64::INFINITY;
            }
        }
        let bound = assoc::flow_lower_bound(&table, cap).expect("feasible bound");
        assert!(bound.is_finite());
        for assoc_ in [
            assoc::time_minimized(&channel, cap).unwrap(),
            assoc::greedy(&channel, cap).unwrap(),
            assoc::random(n, m, cap, rng).unwrap(),
            assoc::solve_exact_matching(&table, cap).unwrap(),
            assoc::solve_flow(&table, cap).unwrap(),
        ] {
            // Heuristics solved the un-poisoned channel, so under an
            // outage their achieved latency may be +inf — the bound must
            // hold (and the gap stay non-negative) regardless.
            let cert = assoc::certify(&table, cap, &assoc_).expect("certificate");
            assert!(
                cert.holds(),
                "bound {} above achieved {}",
                cert.lower_bound,
                cert.achieved
            );
            assert!(cert.gap >= 0.0, "negative gap {}", cert.gap);
            assert_eq!(cert.lower_bound.to_bits(), bound.to_bits());
        }
    });
}

#[test]
fn prop_flow_bound_equals_exact_matching() {
    // The tentpole's tightness property: total unimodularity makes the
    // LP bound *exact*, so where the threshold-matching solver is
    // tractable the two must agree bitwise — both land on the same
    // latency-table entry, not merely nearby values.
    check("flow bound == exact objective", 48, |rng| {
        let (topo, channel, cap) = random_world(rng);
        let a = rng.range(1.0, 50.0);
        let table = LatencyTable::build(&topo, &channel, a);
        let bound = assoc::flow_lower_bound(&table, cap).unwrap();
        let exact = assoc::solve_exact_matching(&table, cap).unwrap();
        assert_eq!(
            bound.to_bits(),
            table.max_latency(&exact).to_bits(),
            "bound {bound} vs exact {}",
            table.max_latency(&exact)
        );
        // And the flow solver itself closes the gap exactly.
        let flow = assoc::solve_flow(&table, cap).unwrap();
        flow.validate(cap).unwrap();
        assert_eq!(table.max_latency(&flow).to_bits(), bound.to_bits());
    });
}

#[test]
fn prop_cloud_rounds_monotone() {
    check("R(a,b,eps) monotonicity", 128, |rng| {
        let (g, z, c) = (
            rng.int_range(1, 10) as f64,
            rng.int_range(1, 10) as f64,
            1.0,
        );
        let eps = rng.range(0.01, 0.9);
        let a = rng.range(1.0, 100.0);
        let b = rng.range(1.0, 50.0);
        let r = cloud_rounds(a, b, eps, c, g, z);
        assert!(r > 0.0);
        // Non-increasing in a and b.
        assert!(cloud_rounds(a * 1.5, b, eps, c, g, z) <= r + 1e-9);
        assert!(cloud_rounds(a, b * 1.5, eps, c, g, z) <= r + 1e-9);
        // Increasing as eps shrinks.
        assert!(cloud_rounds(a, b, eps * 0.5, c, g, z) >= r - 1e-9);
    });
}

#[test]
fn prop_simulator_matches_closed_form() {
    check("sim == R_int * T", 64, |rng| {
        let inst = random_instance(rng);
        let a = rng.int_range(1, 40) as u64;
        let b = rng.int_range(1, 12) as u64;
        let res = simulate(&inst, &SimConfig::deterministic(a, b));
        let expect = res.rounds as f64 * inst.round_time(a as f64, b as f64);
        assert!(
            (res.total_time_s - expect).abs() < 1e-6 * expect.max(1.0),
            "sim {} vs closed {expect}",
            res.total_time_s
        );
    });
}

#[test]
fn prop_integer_solver_is_exact_on_its_grid() {
    check("solve_integer exactness", 24, |rng| {
        let inst = random_instance(rng);
        let opts = SolveOptions {
            a_max: 40.0,
            b_max: 20.0,
            ..Default::default()
        };
        let sol = solve_integer(&inst, &opts);
        for a in 1..=40u64 {
            for b in 1..=20u64 {
                let v = inst.total_time_int(a as f64, b as f64);
                assert!(
                    sol.objective <= v + 1e-9,
                    "({a},{b}) beats solver: {v} < {}",
                    sol.objective
                );
            }
        }
    });
}

#[test]
fn prop_continuous_solver_below_integer() {
    check("relaxation <= integer objective", 48, |rng| {
        let inst = random_instance(rng);
        let opts = SolveOptions::default();
        let c = solve_continuous(&inst, &opts);
        let i = solve_integer(&inst, &opts);
        // ⌈R⌉ ≥ R pointwise, so the integer optimum can't undercut the
        // relaxation's optimum by more than numerical noise.
        assert!(i.objective >= c.objective - 1e-6 * c.objective);
    });
}

#[test]
fn prop_alg2_within_factor_of_exact() {
    check("alg2 near exact", 16, |rng| {
        let inst = random_instance(rng);
        let exact = solve_continuous(&inst, &SolveOptions::default());
        let res = SubgradientSolver::default().solve(&inst);
        assert!(
            res.objective <= exact.objective * 1.05 + 1e-9,
            "alg2 {} vs exact {}",
            res.objective,
            exact.objective
        );
    });
}

#[test]
fn prop_partitions_conserve_and_validate() {
    check("partitions valid", 24, |rng| {
        let cfg = SyntheticConfig::default();
        let n = rng.int_range(100, 400) as usize;
        let ds = generate_split(&cfg, n, 42, rng.next_u64());
        let ues = rng.int_range(2, 10) as usize;
        let per = (n / ues).min(rng.int_range(5, 50) as usize);
        let iid = partition_iid(&ds, ues, per, rng).unwrap();
        assert_eq!(iid.len(), ues);
        for s in &iid {
            assert_eq!(s.len(), per);
            s.validate().unwrap();
        }
        let alpha = rng.range(0.05, 5.0);
        let dir = partition_dirichlet(&ds, ues, per, alpha, rng).unwrap();
        for s in &dir {
            assert_eq!(s.len(), per);
            s.validate().unwrap();
        }
    });
}

#[test]
fn prop_tau_and_round_time_monotone() {
    check("tau/T monotone in a,b", 64, |rng| {
        let inst = random_instance(rng);
        let a = rng.range(1.0, 50.0);
        let b = rng.range(1.0, 20.0);
        let t = inst.round_time(a, b);
        assert!(t > 0.0);
        assert!(inst.round_time(a + 1.0, b) >= t);
        assert!(inst.round_time(a, b + 1.0) >= t);
        for tau in inst.taus(a) {
            assert!(tau >= 0.0);
        }
    });
}
