//! Cross-module integration tests (no PJRT required): the full
//! optimize → associate → simulate pipeline over sampled topologies, the
//! scenario config system, and the CLI plumbing.

use hfl::assoc::{self, LatencyTable};
use hfl::config::{Args, AssocStrategy, Scenario};
use hfl::delay::DelayInstance;
use hfl::net::{BandwidthPolicy, Channel, SystemParams, Topology};
use hfl::opt::{solve_continuous, solve_integer, SolveOptions, SubgradientSolver};
use hfl::sim::{simulate, SimConfig};
use hfl::util::Rng;

fn args(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from)).unwrap()
}

/// The paper's §V-B pipeline end to end: deploy, associate, optimize,
/// verify the simulated protocol matches the optimizer's objective.
#[test]
fn full_pipeline_closed_loop() {
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 5, 100, 42);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let association = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();
    association.validate(params.edge_capacity()).unwrap();

    let inst = DelayInstance::build(&topo, &channel, &association, 0.25);
    let sol = solve_integer(&inst, &SolveOptions::default());
    assert!(sol.a >= 1 && sol.b >= 1);

    let sim = simulate(&inst, &SimConfig::deterministic(sol.a, sol.b));
    assert!(
        (sim.total_time_s - sol.objective).abs() < 1e-6 * sol.objective,
        "simulator {} vs optimizer {}",
        sim.total_time_s,
        sol.objective
    );
}

/// The association strategies must show the paper's Fig. 5 ordering on
/// the default scenario (averaged over seeds to kill noise).
#[test]
fn fig5_ordering_on_default_scenario() {
    let params = SystemParams::default();
    let (mut p_tot, mut g_tot, mut r_tot) = (0.0, 0.0, 0.0);
    for seed in 0..8u64 {
        let topo = Topology::sample(&params, 8, 100, seed * 7 + 1);
        let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let cap = params.edge_capacity();
        let table = LatencyTable::build(&topo, &channel, 20.0);
        p_tot += table.max_latency(&assoc::time_minimized(&channel, cap).unwrap());
        g_tot += table.max_latency(&assoc::greedy(&channel, cap).unwrap());
        r_tot += table.max_latency(&assoc::random(100, 8, cap, &mut Rng::new(seed)).unwrap());
    }
    assert!(
        p_tot <= g_tot,
        "proposed {p_tot} should beat greedy {g_tot} on average"
    );
    assert!(
        g_tot <= r_tot,
        "greedy {g_tot} should beat random {r_tot} on average"
    );
}

/// More edge servers => lower (or equal) optimal latency, as in Fig. 5.
#[test]
fn latency_decreases_with_more_edges() {
    let params = SystemParams::default();
    let lat = |edges: usize| -> f64 {
        let mut acc = 0.0;
        for seed in 0..6u64 {
            let topo = Topology::sample(&params, edges, 100, 1000 + seed);
            let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
            let table = LatencyTable::build(&topo, &channel, 20.0);
            let exact = assoc::solve_exact_matching(&table, params.edge_capacity()).unwrap();
            acc += table.max_latency(&exact);
        }
        acc / 6.0
    };
    let l6 = lat(6);
    let l12 = lat(12);
    assert!(l12 <= l6, "12 edges {l12} should beat 6 edges {l6}");
}

/// Fig. 2 trend: under the integer objective, tightening ε never
/// decreases the number of cloud rounds or the total time. (The paper
/// additionally claims a·b grows monotonically; that does NOT follow
/// from its own Eq. (15) — see EXPERIMENTS.md §Fig. 2 / §Deviations 1 —
/// so it is intentionally not asserted here.)
#[test]
fn fig2_trend_rounds_and_ab() {
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 5, 100, 42);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let association = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();

    let mut prev_rounds = 0u64;
    let mut prev_total = 0.0f64;
    for eps in [0.5, 0.25, 0.1, 0.05] {
        let inst = DelayInstance::build(&topo, &channel, &association, eps);
        let sol = solve_integer(&inst, &SolveOptions::default());
        assert!(
            sol.rounds >= prev_rounds,
            "rounds must grow as eps shrinks"
        );
        assert!(
            sol.objective >= prev_total,
            "tighter accuracy cannot be cheaper"
        );
        assert!(sol.a >= 1 && sol.b >= 1);
        prev_rounds = sol.rounds;
        prev_total = sol.objective;
    }
}

/// Algorithm 2 and the exact solver agree on realistic world instances.
#[test]
fn alg2_vs_exact_on_world_instances() {
    let params = SystemParams::default();
    for seed in 0..4u64 {
        let topo = Topology::sample(&params, 4, 60, 99 + seed);
        let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let association = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();
        let inst = DelayInstance::build(&topo, &channel, &association, 0.2);
        let exact = solve_continuous(&inst, &SolveOptions::default());
        let alg2 = SubgradientSolver::default().solve(&inst);
        assert!(
            alg2.objective <= exact.objective * 1.05,
            "seed {seed}: alg2 {} vs exact {}",
            alg2.objective,
            exact.objective
        );
    }
}

#[test]
fn scenario_roundtrip_toml_plus_cli() {
    let dir = std::env::temp_dir().join(format!("hfl_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenario.toml");
    std::fs::write(
        &path,
        r#"
[scenario]
num_edges = 4
num_ues = 60
eps = 0.1
assoc = "greedy"
[system]
gamma = 3
zeta = 7
[train]
a = 30
b = 5
lr = 0.1
"#,
    )
    .unwrap();
    // CLI overrides the file.
    let a = args("optimize --eps 0.05 --assoc proposed");
    let sc = Scenario::load(path.to_str(), &a).unwrap();
    assert_eq!(sc.num_edges, 4);
    assert_eq!(sc.num_ues, 60);
    assert_eq!(sc.eps, 0.05); // CLI wins
    assert_eq!(sc.assoc, AssocStrategy::Proposed); // CLI wins
    assert_eq!(sc.system.gamma, 3.0);
    assert_eq!(sc.train.a, Some(30));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_infeasible_rejected() {
    let a = args("optimize --ues 10000 --edges 2");
    assert!(Scenario::load(None, &a).is_err());
}

#[test]
fn equal_share_policy_changes_rates() {
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 2, 30, 5);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    // Balanced 15/15 association: each member shares 20 MHz 15 ways
    // (1.33 MHz) under equal-share vs the fixed 1 MHz block.
    let assoc_ = assoc::Association::new((0..30).map(|n| n % 2).collect(), 2);
    let fixed = DelayInstance::build(&topo, &channel, &assoc_, 0.25);
    let shared = DelayInstance::build_equal_share(&topo, &channel, &assoc_, 0.25);
    // 15 UEs/edge sharing 20 MHz get 1.33 MHz > fixed 1 MHz per UE, so
    // upload times differ between the policies.
    let (f, s) = (fixed.round_time(10.0, 2.0), shared.round_time(10.0, 2.0));
    assert!(
        (f - s).abs() > 1e-9,
        "policies should differ: fixed {f} vs shared {s}"
    );
    // Bandwidth policy helpers agree with capacity semantics.
    assert_eq!(
        BandwidthPolicy::FixedPerUe.capacity(&params),
        params.edge_capacity()
    );
}

/// Deterministic reproducibility of the whole pipeline per seed.
#[test]
fn pipeline_is_deterministic() {
    let run = || {
        let params = SystemParams::default();
        let topo = Topology::sample(&params, 5, 80, 7);
        let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
        let association = assoc::time_minimized(&channel, params.edge_capacity()).unwrap();
        let inst = DelayInstance::build(&topo, &channel, &association, 0.25);
        let sol = solve_integer(&inst, &SolveOptions::default());
        (association.edge_of.clone(), sol.a, sol.b, sol.objective)
    };
    assert_eq!(run(), run());
}
